"""Serving-path microbench: tokens/s through the continuum on the smoke
configs, offload-policy comparison at fixed wall budget, the
batched-vs-serial scheduler comparison, the continuous-vs-wave scheduler
comparison on a mixed-length workload, the bucketed-vs-padded prefill
comparison, a closed-loop (submit-while-serving) driver, and a 3-tier
chain with per-tier request counts.

This is the live-engine counterpart of the simulator benches: real jitted
prefill/decode steps, real controller, one CPU device — numbers are
CPU-relative but the POLICY ordering mirrors the paper's Table 2.  The
"batched" arm of ``bench_scheduler`` is the continuous-batching scheduler
(the runtime default) against the serial ``serve_one``-per-request
baseline; ``bench_continuous_vs_wave`` holds the legacy run-to-completion
wave scheduler as the baseline and reports the interactive-class tail
latency win.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.core import offload
from repro.core.policy import StaticSplit
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.platform import (Continuum, LinkSpec, Request, TierConfig,
                            TierSpec, Topology)
from repro.serving.engine import Endpoint
from repro.serving.tiers import _Queued
from repro.workloads.trace import request_rounds


def bench_engine(arch: str = "stablelm-1.6b", steps: int = 30):
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    ep = Endpoint(cfg, params, slots=4, max_len=128)
    slot = ep.try_claim()
    ep.prefill_one(slot, np.arange(16, dtype=np.int32))
    toks = {slot: 1}
    t0 = time.perf_counter()
    for _ in range(steps):
        toks = {slot: ep.decode_all(toks)[slot]}
    dt = (time.perf_counter() - t0) / steps
    return {"arch": arch, "decode_step_ms": dt * 1e3,
            "tokens_per_s_per_slot": 1.0 / dt}


# the shared request schedule lives in repro.workloads.trace now
# (bit-identical to the private copy this file used to carry)
_workload = request_rounds


def _mk_continuum(policy_cfg: offload.OffloadConfig, seed: int,
                  policy="auto", **kwargs) -> Continuum:
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(seed), cfg)
    cc = Continuum(edge=TierConfig(slots=2, max_len=64),
                   cloud=TierConfig(slots=8, max_len=64),
                   policy=policy, offload_cfg=policy_cfg, seed=seed,
                   **kwargs)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    return cc


def bench_policies(rounds: int = 12, seed: int = 0):
    """Offload-policy comparison at fixed workload (Table-2 ordering)."""
    sched = _workload(rounds, seed)
    out = {}
    for policy in ("edge_only", "auto"):
        ocfg = offload.OffloadConfig(
            c_soft=999.0 if policy == "edge_only" else 1.25)
        cc = _mk_continuum(ocfg, seed)
        rid = 0
        t0 = time.perf_counter()
        for rnd in range(rounds):
            for r, toks, max_new in sched:
                if r == rnd:
                    cc.submit("fn", Request(rid=rid, tokens=toks,
                                            max_new=max_new))
                    rid += 1
            cc.tick()
        wall = time.perf_counter() - t0
        lat, valid = cc.edge.metrics.latency_windows(256)
        lats = lat[0][valid[0]]
        out[policy] = {
            "served": int(sum(r["edge"] + r["cloud"] for r in cc.log)),
            "cloud_frac": float(sum(r["cloud"] for r in cc.log) / max(rid, 1)),
            "wall_s": wall,
            "edge_p50_ms": float(np.percentile(lats, 50) * 1e3) if len(lats) else None,
            "edge_p95_ms": float(np.percentile(lats, 95) * 1e3) if len(lats) else None,
        }
    return out


def _warmup(cc):
    """Compile prefill/decode on both tiers before timing — every
    power-of-two wave shape the bucketed prefill can hit — plus the
    router's padded batch shapes, then drop the (compile-skewed)
    warmup latencies from the scraped metrics."""
    for tier in (cc.edge, cc.cloud):
        g = 1
        while g <= tier.cfg.slots:
            reqs = [(Request(rid=-1 - i, tokens=np.zeros(6, np.int32),
                             max_new=2), time.perf_counter())
                    for i in range(g)]
            tier.serve_batch("fn", reqs)
            g *= 2
        tier.metrics.clear()
    key = jax.random.PRNGKey(0)
    for n in (1, 2, 4, 8, 16):
        cc.control.route_tiers(key, np.zeros(n, np.int32))
        cc.control.route(key, np.zeros(n, np.int32))


def bench_scheduler(rounds: int = 12, seed: int = 0):
    """Same workload through (a) the continuous-batching scheduler and
    (b) the serial ``serve_one``-per-request baseline, under an identical
    *fixed* 50% split (so routing cannot diverge between the two paths).

    The batched path packs admissions into shared prefill + ``decode_all``
    streams, so B co-scheduled requests cost ~max_new decode steps instead
    of B * max_new — that is the req/s win reported here.
    """
    sched = _workload(rounds, seed)
    out = {}

    # (a) batched: submit per round, tick drains continuously
    cc = _mk_continuum(offload.OffloadConfig(), seed, policy=50.0)
    _warmup(cc)
    rid = 0
    t0 = time.perf_counter()
    for rnd in range(rounds):
        for r, toks, max_new in sched:
            if r == rnd:
                cc.submit("fn", Request(rid=rid, tokens=toks,
                                        max_new=max_new))
                rid += 1
        cc.tick()
    wall_batched = time.perf_counter() - t0
    lat, valid = cc.edge.metrics.latency_windows(256)
    lats = lat[0][valid[0]]
    out["batched"] = {
        "served": int(sum(r["edge"] + r["cloud"] for r in cc.log)),
        "cloud_frac": float(sum(r["cloud"] for r in cc.log) / max(rid, 1)),
        "waves": int(sum(r["waves"] for r in cc.log)),
        "wall_s": wall_batched,
        "req_per_s": rid / wall_batched,
        "edge_p50_ms": float(np.percentile(lats, 50) * 1e3) if len(lats) else None,
        "edge_p95_ms": float(np.percentile(lats, 95) * 1e3) if len(lats) else None,
    }

    # (b) serial: identical requests + routing policy, but each request is
    # served alone (serve_one) — the pre-batching code path.
    cc = _mk_continuum(offload.OffloadConfig(), seed, policy=50.0)
    _warmup(cc)
    rid = 0
    served_edge = served_cloud = 0
    t0 = time.perf_counter()
    for rnd in range(rounds):
        batch = [(toks, max_new) for r, toks, max_new in sched if r == rnd]
        R = cc.controller_update()
        fn_ids = np.zeros(len(batch), np.int32)
        cc.key, sub = jax.random.split(cc.key)
        to_cloud = cc.control.route(sub, fn_ids)
        for (toks, max_new), cloudward in zip(batch, to_cloud):
            req = Request(rid=rid, tokens=toks, max_new=max_new)
            tier = cc.cloud if bool(cloudward) else cc.edge
            tier.serve_one("fn", req)
            if bool(cloudward):
                served_cloud += 1
            else:
                served_edge += 1
            rid += 1
    wall_serial = time.perf_counter() - t0
    out["serial"] = {
        "served": served_edge + served_cloud,
        "cloud_frac": served_cloud / max(rid, 1),
        "wall_s": wall_serial,
        "req_per_s": rid / wall_serial,
    }
    out["batched_speedup"] = wall_serial / wall_batched
    return out


def bench_continuous_vs_wave(rounds: int = 5, seed: int = 0):
    """Mixed-length workload through (a) the continuous-batching decode
    loop and (b) the legacy run-to-completion wave scheduler, under an
    identical fixed 50% split.

    Each round submits one long request alongside a burst of short ones —
    more than the edge has slots.  The wave scheduler runs every wave to
    completion, so the backlogged short requests wait out the long
    request's whole decode; the continuous loop retires finished rows
    mid-stream and admits queued requests into the freed slots the same
    step.  The headline is the tail (p95) latency of the short-heavy mix.
    """
    rng = np.random.default_rng(seed)
    sched = []
    for rnd in range(rounds):
        sched.append((rnd, rng.integers(0, 128, 6).astype(np.int32), 20))
        for _ in range(6):
            sched.append((rnd, rng.integers(0, 128, 6).astype(np.int32), 2))
    out = {}
    for mode in ("wave", "continuous"):
        cc = _mk_continuum(offload.OffloadConfig(), seed, policy=50.0,
                           scheduler=mode)
        _warmup(cc)
        reqs, rid = [], 0
        t0 = time.perf_counter()
        for rnd in range(rounds):
            for r, toks, max_new in sched:
                if r == rnd:
                    req = Request(rid=rid, tokens=toks, max_new=max_new)
                    cc.submit("fn", req)
                    reqs.append(req)
                    rid += 1
            cc.tick()
        wall = time.perf_counter() - t0
        # per-class latency from the request objects themselves: the
        # interactive (short) class is where head-of-line blocking shows
        by_class = {"short": [], "long": []}
        for req in reqs:
            cls = "long" if req.max_new >= 20 else "short"
            by_class[cls].append(req.t_done - req.arrival_s)
        short = np.asarray(by_class["short"])
        out[mode] = {
            "served": int(sum(sum(r["tiers"].values()) for r in cc.log)),
            "waves": int(sum(r["waves"] for r in cc.log)),
            "steps": int(sum(r["steps"] for r in cc.log)),
            "wall_s": wall,
            "req_per_s": rid / wall,
            "short_p50_ms": float(np.percentile(short, 50) * 1e3),
            "short_p95_ms": float(np.percentile(short, 95) * 1e3),
            "long_p95_ms": float(np.percentile(by_class["long"], 95) * 1e3),
        }
    out["p95_speedup"] = (out["wave"]["short_p95_ms"]
                          / out["continuous"]["short_p95_ms"])
    out["p50_speedup"] = (out["wave"]["short_p50_ms"]
                          / out["continuous"]["short_p50_ms"])
    return out


def bench_prefill_bucketing(arch: str = "stablelm-1.6b", slots: int = 8,
                            reps: int = 20):
    """Length-bucketed packed prefill vs the legacy pad-to-pool path.

    A small wave (1-2 prompts) on a ``slots``-wide pool used to pay a
    batch=slots prefill; the bucketed path runs it at the next
    power-of-two batch on a fresh cache and scatters the rows back."""
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    out = {}
    for mode, bucket in (("bucketed", True), ("padded", False)):
        ep = Endpoint(cfg, params, slots=slots, max_len=128,
                      bucket_prefill=bucket)
        prompt = np.arange(12, dtype=np.int32)

        def wave(n):
            claimed = [ep.try_claim() for _ in range(n)]
            ep.prefill_batch({s: prompt + s for s in claimed})
            for s in claimed:
                ep.release(s)

        wave(1)                       # compile
        wave(2)
        t0 = time.perf_counter()
        for _ in range(reps):
            wave(1)
            wave(2)
        dt = (time.perf_counter() - t0) / (2 * reps)
        out[mode] = {"small_wave_prefill_ms": dt * 1e3}
    out["bucketed_speedup"] = (out["padded"]["small_wave_prefill_ms"]
                               / out["bucketed"]["small_wave_prefill_ms"])
    return out


def bench_closed_loop(rounds: int = 24, clients: int = 8, seed: int = 0):
    """Closed-loop driver: a fixed client population resubmits as soon as
    its previous request completes, so arrivals interleave with serving
    instead of pre-loading the queue.  ``max_waves_per_tick`` throttles
    the scheduler, leaving a live backlog whose queue ages the next scrape
    mixes into Eq (1) — the live overload-onset signal."""
    rng = np.random.default_rng(seed)
    cc = _mk_continuum(offload.OffloadConfig(), seed)
    cc.max_waves_per_tick = 1
    rid = outstanding = 0
    backlog_peak = 0
    R_trace = []
    for _ in range(rounds):
        for _ in range(clients - outstanding):   # closed loop: top up
            cc.submit("fn", Request(
                rid=rid, tokens=rng.integers(0, 128, 6).astype(np.int32),
                max_new=2))
            rid += 1
        outstanding = clients
        rec = cc.tick()
        outstanding -= rec["edge"] + rec["cloud"]
        # backlog now lives in per-tier gateways, not one ingress deque
        backlog_peak = max(backlog_peak, cc.queued)
        R_trace.append(rec["R"])
    served = sum(r["edge"] + r["cloud"] for r in cc.log)
    return {
        "submitted": rid,
        "served": served,
        "backlog_peak": backlog_peak,
        "R_peak": float(max(R_trace)),
        "R_final": float(R_trace[-1]),
        # the point of the closed loop: backlog ages fire the controller
        "onset_detected": bool(max(R_trace) > 0.0),
    }


class _MigrateSplit(StaticSplit):
    """Deterministic driver for ``bench_migration``: R_t = 100 at every
    boundary (so a ``migrate_threshold`` policy migrates every eligible
    resident row the moment it can), while *routing* of new arrivals is
    pinned to a fixed edge/cloud split — the controlled comparison needs
    identical arrival routing in both arms, with migration the only
    difference."""

    def __init__(self, migrate_threshold=None, cloud_pct: float = 50.0):
        super().__init__(100.0)
        self.migrate_threshold = migrate_threshold
        self.cloud_pct = cloud_pct

    def tier_distribution(self, R_all, num_tiers):
        d = np.zeros((R_all.shape[1], num_tiers), np.float32)
        d[:, 1] = 100.0 - self.cloud_pct
        d[:, 2] = self.cloud_pct
        return d


def bench_migration(rounds: int = 24, seed: int = 0):
    """The paper's offload scenario at request granularity: the edge is
    saturated by resident long decodes while an interactive stream keeps
    arriving.

    Baseline ("route_only"): the controller can only redirect *new
    arrivals* — the resident longs hold the edge's slots hostage for
    their entire decode, so every edge-routed interactive request waits
    them out (3-tier chain: the edge gateway's backlog belongs to the
    edge — there is no ingress re-route escape).  Treatment ("migrate"):
    the same policy carries a ``migrate_threshold``, so the longs'
    KV-cache rows are shipped over the edge->cloud link (real cache
    bytes + token tail) and resume decoding in the cloud — the freed
    edge slots serve the interactive class immediately.  The headline is
    the interactive p95 recovering multi-x at equal served counts.
    """
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(seed), cfg)

    def run(threshold):
        topo = Topology(
            tiers=(TierSpec("device", slots=1, max_len=128),
                   TierSpec("edge", slots=2, max_len=128,
                            queue_depth_per_slot=32),
                   TierSpec("cloud", slots=8, max_len=128)),
            links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=50e6),
                   LinkSpec(rtt_s=0.2, bandwidth_Bps=100e6)),
            waterfall=False)
        cc = Continuum.from_topology(
            topo, policy=_MigrateSplit(threshold),
            offload_cfg=offload.OffloadConfig(), seed=seed,
            max_steps_per_tick=4)
        cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg,
                  params)
        # compile every shape off the clock: serving waves on both
        # serving tiers, the router, and the migration extract/insert
        for tier in (cc.tiers[1], cc.tiers[2]):
            g = 1
            while g <= tier.cfg.slots:
                tier.serve_batch("fn", [
                    (Request(rid=-1 - i, tokens=np.zeros(6, np.int32),
                             max_new=2), time.perf_counter())
                    for i in range(g)])
                g *= 2
            tier.metrics.clear()
        key = jax.random.PRNGKey(0)
        for n in (1, 2, 4):
            cc.control.route_tiers(key, np.zeros(n, np.int32))
        ep, dep = (cc.tiers[1].endpoints["fn"],
                   cc.tiers[2].endpoints["fn"])
        s = ep.try_claim()
        ep.prefill_one(s, np.zeros(6, np.int32))
        [state] = ep.extract_rows([s])
        ep.release(s)
        d = dep.try_claim()
        dep.insert_rows([state], [d], [6])
        dep.release(d)

        rng = np.random.default_rng(seed)
        # the long-decode burst arrived first, while the edge was
        # healthy: both long requests are slot-resident at the edge
        longs = []
        for i in range(2):
            r = Request(rid=1000 + i,
                        tokens=rng.integers(0, 128, 6).astype(np.int32),
                        max_new=96)
            cc.tiers[1].admit(
                "fn", [_Queued("fn", r, t_submit=time.perf_counter())])
            longs.append(r)
        reqs, rid = [], 0
        t0 = time.perf_counter()
        for rnd in range(rounds):
            if rnd >= 2:               # interactive stream
                for _ in range(2):
                    r = Request(rid=rid,
                                tokens=rng.integers(0, 128, 6)
                                .astype(np.int32), max_new=2)
                    cc.submit("fn", r)
                    reqs.append(r)
                    rid += 1
            cc.tick()
        cc.drain()
        wall = time.perf_counter() - t0
        short = np.asarray([r.t_done - r.arrival_s for r in reqs
                            if r.output is not None])
        tier_counts = {t.name: sum(r["tiers"][t.name] for r in cc.log)
                       for t in cc.tiers}
        return {
            "served": sum(tier_counts.values()),
            "tier_counts": tier_counts,
            "failed": int(sum(r.failed for r in reqs)),
            "migrations_completed": int(
                cc.metrics.counter("migrations_completed")),
            "migrations_aborted": int(
                cc.metrics.counter("migrations_aborted")),
            "link1_egress_MB": cc.link_bytes[1] / 1e6,
            "short_p50_ms": float(np.percentile(short, 50) * 1e3),
            "short_p95_ms": float(np.percentile(short, 95) * 1e3),
            "long_done": bool(all(l.output is not None for l in longs)),
            "wall_s": wall,
        }

    out = {"route_only": run(None), "migrate": run(50.0)}
    out["p95_speedup"] = (out["route_only"]["short_p95_ms"]
                          / out["migrate"]["short_p95_ms"])
    out["p50_speedup"] = (out["route_only"]["short_p50_ms"]
                          / out["migrate"]["short_p50_ms"])
    # the CPU-stable acceptance facts (gated by check_regression):
    # same served counts, interactive p95 strictly better, resident
    # longs actually migrated
    out["p95_improved"] = bool(out["p95_speedup"] > 1.0)
    return out


def bench_three_tier(rounds: int = 12, seed: int = 0):
    """The 3-tier device/edge/cloud chain end-to-end in the live runtime,
    reporting per-tier request counts."""
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(seed), cfg)
    topo = Topology(
        tiers=(TierSpec("device", slots=1, max_len=64),
               TierSpec("edge", slots=2, max_len=64,
                        extra_latency_s=0.005),
               TierSpec("cloud", slots=8, max_len=64,
                        extra_latency_s=0.02)),
        links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=50e6),
               LinkSpec(rtt_s=0.04, bandwidth_Bps=100e6)))
    cc = Continuum.from_topology(topo, policy="auto", seed=seed)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    sched = _workload(rounds, seed)
    rid = 0
    t0 = time.perf_counter()
    for rnd in range(rounds):
        for r, toks, max_new in sched:
            if r == rnd:
                cc.submit("fn", Request(rid=rid, tokens=toks,
                                        max_new=max_new))
                rid += 1
        cc.tick()
    wall = time.perf_counter() - t0
    tier_counts = {n: sum(r["tiers"][n] for r in cc.log) for n in topo.names}
    return {
        "tier_counts": tier_counts,
        "served": sum(tier_counts.values()),
        "submitted": rid,
        "spilled": int(sum(r["spilled"] for r in cc.log)),
        "rejected": int(sum(r["rejected"] for r in cc.log)),
        "wall_s": wall,
        "R_peak": float(max(r["R"] for r in cc.log)),
    }


def main(out_dir: str | None = None):
    eng = bench_engine()
    print(f"engine decode: {eng['decode_step_ms']:.1f} ms/step "
          f"({eng['tokens_per_s_per_slot']:.1f} tok/s/slot)")
    pol = bench_policies()
    for k, v in pol.items():
        print(f"{k:10s} served={v['served']} cloud_frac={v['cloud_frac']:.2f} "
              f"wall={v['wall_s']:.1f}s p95={v['edge_p95_ms']}")
    sched = bench_scheduler()
    for k in ("batched", "serial"):
        v = sched[k]
        print(f"{k:8s} served={v['served']} wall={v['wall_s']:.1f}s "
              f"req/s={v['req_per_s']:.2f}")
    print(f"batched speedup over serial serve_one: "
          f"{sched['batched_speedup']:.2f}x")
    cvw = bench_continuous_vs_wave()
    for k in ("wave", "continuous"):
        v = cvw[k]
        print(f"{k:10s} served={v['served']} waves={v['waves']} "
              f"steps={v['steps']} short_p50={v['short_p50_ms']:.0f}ms "
              f"short_p95={v['short_p95_ms']:.0f}ms "
              f"long_p95={v['long_p95_ms']:.0f}ms wall={v['wall_s']:.1f}s")
    print(f"continuous-batching tail-latency win over waves "
          f"(interactive class of the mixed-length workload): "
          f"p95 {cvw['p95_speedup']:.2f}x, p50 {cvw['p50_speedup']:.2f}x")
    buck = bench_prefill_bucketing()
    print(f"prefill  bucketed={buck['bucketed']['small_wave_prefill_ms']:.1f}ms "
          f"padded={buck['padded']['small_wave_prefill_ms']:.1f}ms "
          f"speedup={buck['bucketed_speedup']:.2f}x (small waves)")
    closed = bench_closed_loop()
    print(f"closed-loop: submitted={closed['submitted']} "
          f"served={closed['served']} backlog_peak={closed['backlog_peak']} "
          f"R_peak={closed['R_peak']:.1f}% "
          f"onset_detected={closed['onset_detected']}")
    mig = bench_migration()
    for k in ("route_only", "migrate"):
        v = mig[k]
        print(f"{k:10s} served={v['served']} "
              f"migrations={v['migrations_completed']} "
              f"short_p50={v['short_p50_ms']:.0f}ms "
              f"short_p95={v['short_p95_ms']:.0f}ms "
              f"link1_MB={v['link1_egress_MB']:.2f} "
              f"wall={v['wall_s']:.1f}s")
    print(f"mid-stream migration win over route-new-arrivals-only "
          f"(interactive class, edge saturated by resident longs): "
          f"p95 {mig['p95_speedup']:.2f}x, p50 {mig['p50_speedup']:.2f}x")
    three = bench_three_tier()
    per = " ".join(f"{n}={c}" for n, c in three["tier_counts"].items())
    print(f"3-tier: served={three['served']}/{three['submitted']} [{per}] "
          f"spilled={three['spilled']} rejected={three['rejected']} "
          f"R_peak={three['R_peak']:.1f}% wall={three['wall_s']:.1f}s")
    res = {"engine": eng, "policies": pol, "scheduler": sched,
           "continuous_vs_wave": cvw,
           "prefill_bucketing": buck, "closed_loop": closed,
           "migration": mig, "three_tier": three}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "serving_bench.json"), "w") as f:
            json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "results"))
