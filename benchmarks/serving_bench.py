"""Serving-path microbench: tokens/s through the two-tier continuum on the
smoke configs + offload-policy comparison at fixed wall budget.

This is the live-engine counterpart of the simulator benches: real jitted
prefill/decode steps, real controller, one CPU device — numbers are
CPU-relative but the POLICY ordering mirrors the paper's Table 2.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.core import offload
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.serving.engine import Endpoint, Request
from repro.serving.tiers import EdgeCloudContinuum, TierConfig


def bench_engine(arch: str = "stablelm-1.6b", steps: int = 30):
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    ep = Endpoint(cfg, params, slots=4, max_len=128)
    ep.prefill_one(0, np.arange(16, dtype=np.int32))
    toks = {0: 1}
    t0 = time.perf_counter()
    for _ in range(steps):
        toks = {0: ep.decode_all(toks)[0]}
    dt = (time.perf_counter() - t0) / steps
    return {"arch": arch, "decode_step_ms": dt * 1e3,
            "tokens_per_s_per_slot": 1.0 / dt}


def bench_policies(rounds: int = 12, seed: int = 0):
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(seed), cfg)
    out = {}
    for policy in ("edge_only", "auto"):
        ocfg = offload.OffloadConfig(
            c_soft=999.0 if policy == "edge_only" else 1.25)
        cc = EdgeCloudContinuum(edge=TierConfig(slots=2, max_len=64),
                                cloud=TierConfig(slots=8, max_len=64),
                                offload_cfg=ocfg, seed=seed)
        cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
        rng = np.random.default_rng(seed)
        rid = 0
        t0 = time.perf_counter()
        for rnd in range(rounds):
            for _ in range(2 if rnd < 3 else 8):
                cc.submit("fn", Request(
                    rid=rid, tokens=rng.integers(0, 128, 6).astype(np.int32),
                    max_new=2))
                rid += 1
            cc.tick()
        wall = time.perf_counter() - t0
        lat, valid = cc.edge.metrics.latency_windows(256)
        lats = lat[0][valid[0]]
        out[policy] = {
            "served": int(sum(r["edge"] + r["cloud"] for r in cc.log)),
            "cloud_frac": float(sum(r["cloud"] for r in cc.log) / max(rid, 1)),
            "wall_s": wall,
            "edge_p50_ms": float(np.percentile(lats, 50) * 1e3) if len(lats) else None,
            "edge_p95_ms": float(np.percentile(lats, 95) * 1e3) if len(lats) else None,
        }
    return out


def main(out_dir: str | None = None):
    eng = bench_engine()
    print(f"engine decode: {eng['decode_step_ms']:.1f} ms/step "
          f"({eng['tokens_per_s_per_slot']:.1f} tok/s/slot)")
    pol = bench_policies()
    for k, v in pol.items():
        print(f"{k:10s} served={v['served']} cloud_frac={v['cloud_frac']:.2f} "
              f"wall={v['wall_s']:.1f}s p95={v['edge_p95_ms']}")
    res = {"engine": eng, "policies": pol}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "serving_bench.json"), "w") as f:
            json.dump(res, f, indent=1)
    return res


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "results"))
