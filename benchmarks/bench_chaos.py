"""Chaos benchmarks: the live continuum under faults and hostile traces.

Three scenarios from the paper's availability story, each run twice over
an *identical* offered trace — a static edge/cloud split (the serverless
status quo: a fixed replication percentage) versus the adaptive
controller with mid-stream migration (``auto+migrate``, plus the
net-aware cap for the brownout scenario):

  flash_crowd     — bursty MMPP arrivals (no faults): on-phase bursts
                    overwhelm a statically-pinned edge share, the
                    adaptive arm shifts R_t cloud-ward within a tick.
  edge_brownout   — the edge->cloud link degrades mid-run (RTT x20,
                    bandwidth /200).  The static split's pinned cloud
                    share is *forced* across the browned link — a
                    charged, machine-independent latency penalty that
                    lands squarely on its interactive p95 — while its
                    pinned edge share stays clogged behind long decodes
                    all run long.  The net-aware adaptive arm caps
                    crossings by the degraded link's capacity during
                    the brownout and migrates resident long decodes
                    cloudward once it lifts, so it sheds only inside
                    the fault window and serves strictly more.
  cloud_partition — the link partitions with migrations in flight: the
                    in-transit state can never land, aborts back to the
                    source, and the conservation + migration identities
                    must survive.

Wall-clock latencies are machine-dependent, so the committed gate facts
are *flags* (adaptive served more, adaptive p95 lower, conservation and
migration identities hold), not absolute numbers.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.platform import (Continuum, LinkSpec, Request, TierSpec,
                            Topology, Trace, cloud_partition, edge_brownout)

ARCH = "stablelm-1.6b"


def _topology() -> Topology:
    """Small bounded edge, deep cloud: the shape where a fixed split can
    actually lose requests (the edge gateway is the only bounded queue)."""
    return Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64,
                        queue_depth_per_slot=8),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(rtt_s=0.05, bandwidth_Bps=50e6),))


def _warm(cc: Continuum) -> None:
    """Compile every serving shape off the clock (as bench_migration
    does), so first-wave XLA compilation does not pollute either arm's
    latency distribution."""
    for tier in cc.tiers:
        g = 1
        while g <= tier.cfg.slots:
            tier.serve_batch("fn", [
                (Request(rid=-1 - i, tokens=np.zeros(6, np.int32),
                         max_new=2), time.perf_counter())
                for i in range(g)])
            g *= 2
        tier.metrics.clear()
    key = jax.random.PRNGKey(0)
    for n in (1, 2, 4):
        cc.control.route_tiers(key, np.zeros(n, np.int32))
    # migration extract/insert path
    ep, dep = cc.tiers[0].endpoints["fn"], cc.tiers[-1].endpoints["fn"]
    s = ep.try_claim()
    ep.prefill_one(s, np.zeros(6, np.int32))
    [state] = ep.extract_rows([s])
    ep.release(s)
    d = dep.try_claim()
    dep.insert_rows([state], [d], [6])
    dep.release(d)


def _two_class_trace(inter: Trace, long_rps: float, duration_s: float,
                     seed: int, long_max_new: int = 24) -> Trace:
    """Overlay a steady stream of long decodes on an interactive trace:
    one function, two request classes told apart by ``max_new`` (the
    interactive rows keep their generator's small decode length).  The
    gated latency metric is the *interactive* p95 — the class the paper's
    offload story protects."""
    rng = np.random.default_rng(seed + 500_000)
    t, times = 0.0, []
    while True:
        t += rng.exponential(1.0 / long_rps)
        if t >= duration_s:
            break
        times.append(t)
    lt = np.asarray(times)
    order = np.argsort(np.concatenate([inter.t, lt]), kind="stable")
    cat = lambda a, b: np.concatenate([a, b])[order]  # noqa: E731
    return Trace(
        t=cat(inter.t, lt),
        fn=cat(inter.fn, np.zeros(len(lt), np.int32)),
        prompt_len=cat(inter.prompt_len, np.full(len(lt), 6, np.int32)),
        max_new=cat(inter.max_new, np.full(len(lt), long_max_new, np.int32)),
        payload_bytes=cat(inter.payload_bytes, np.full(len(lt), 2.0e5)),
        fn_names=inter.fn_names, duration_s=duration_s)


def _run_arm(policy, trace: Trace, faults, seed: int = 0) -> dict:
    cfg = configs.get_smoke_config(ARCH)
    params = model_zoo.init(jax.random.PRNGKey(seed), cfg)
    cc = Continuum.from_topology(_topology(), policy=policy, seed=seed,
                                 trace=trace, faults=faults,
                                 max_steps_per_tick=4)
    cc.deploy(FunctionSpec(name="fn", arch=ARCH), cfg, params)
    _warm(cc)
    for _ in range(int(np.ceil(trace.duration_s)) + 2):
        cc.tick()
    cc.drain()

    reqs = cc.trace_requests
    served = sum(1 for r in reqs if r.output is not None)
    failed = sum(1 for r in reqs if r.failed)
    # interactive class only: the long decodes are throughput work, the
    # shorts are the latency-sensitive stream the policies protect
    cut = int(trace.max_new.min()) + 1
    lats = np.asarray([r.latency_s for r in reqs
                       if r.output is not None and r.latency_s is not None
                       and r.max_new <= cut])
    c = cc.metrics.counter
    conserved = (served + failed == len(reqs)
                 and all((r.output is not None) != r.failed for r in reqs)
                 and cc.queued == 0 and cc.in_flight == 0
                 and cc.migrations_open == 0)
    return {
        "policy": str(policy),
        "submitted": len(reqs),
        "served": served,
        "failed": failed,
        "p95_ms": (float(np.percentile(lats, 95) * 1e3)
                   if len(lats) else float("nan")),
        "p50_ms": (float(np.percentile(lats, 50) * 1e3)
                   if len(lats) else float("nan")),
        "migrations_fired": int(c("migrations_fired")),
        "migrations_completed": int(c("migrations_completed")),
        "migrations_aborted": int(c("migrations_aborted")),
        "replayed": int(c("replayed")),
        "faults_applied": int(c("faults_applied")),
        "conserved": bool(conserved),
        "migration_identity": bool(
            c("migrations_fired") == c("migrations_completed")
            + c("migrations_aborted") + cc.migrations_open),
    }


def _scenario(name: str, trace: Trace, faults, static_pct: float = 20.0,
              adaptive: str = "auto+migrate") -> dict:
    print(f"-- {name}: {len(trace)} requests over {trace.duration_s:g}s"
          + (f", {len(faults)} fault events" if faults is not None else ""))
    static = _run_arm(static_pct, trace, faults)
    auto = _run_arm(adaptive, trace, faults)
    out = {
        "static": static,
        "adaptive": auto,
        "conserved": bool(static["conserved"] and auto["conserved"]),
        "migration_identity": bool(static["migration_identity"]
                                   and auto["migration_identity"]),
        "auto_more_served": bool(auto["served"] > static["served"]),
        "auto_better_p95": bool(auto["p95_ms"] < static["p95_ms"]),
        # the partition scenario's bite: transfers in flight when the
        # link went down really did abort (and were not lost — see
        # conserved + migration_identity above)
        "aborted_transits": bool(auto["migrations_aborted"] > 0),
    }
    print(f"   static {static_pct:g}%: served {static['served']}"
          f"/{static['submitted']}  p95 {static['p95_ms']:.0f} ms   "
          f"{adaptive}: served {auto['served']}/{auto['submitted']}  "
          f"p95 {auto['p95_ms']:.0f} ms  "
          f"(mig {auto['migrations_fired']} fired"
          f"/{auto['migrations_aborted']} aborted)")
    return out


def bench_flash_crowd() -> dict:
    inter = Trace.bursty(base_rps=2.0, burst_rps=16.0, duration_s=20.0,
                         mean_on_s=6.0, mean_off_s=5.0, fn_names=("fn",),
                         seed=0, prompt_len=6, max_new=2)
    trace = _two_class_trace(inter, long_rps=0.5, duration_s=20.0, seed=0)
    return _scenario("flash_crowd", trace, faults=None)


def bench_edge_brownout() -> dict:
    # Long decodes (1/s x 20 tokens) demand ~2x the edge's service rate,
    # so the static arm's pinned 80% edge share sheds interactives for
    # the whole run; its pinned 20% cloud share crosses the browned link
    # (rtt x20 -> a >=1 s *charged* penalty on ~8% of its served
    # interactives, comfortably above the p95 cutoff).  The adaptive arm
    # is net-aware: during the brownout the link-capacity cap pins R_t
    # near zero (crossings stay below the p95 cutoff), and once the link
    # recovers migrations evacuate the accumulated long decodes.
    inter = Trace.poisson(rps=8.0, duration_s=30.0, fn_names=("fn",),
                          seed=1, prompt_len=6, max_new=2)
    trace = _two_class_trace(inter, long_rps=1.0, duration_s=30.0,
                             seed=1, long_max_new=20)
    faults = edge_brownout(5.0, 13.0, link=0, bw_mult=0.005, rtt_mult=20.0)
    return _scenario("edge_brownout", trace, faults,
                     adaptive="auto+net+migrate")


def bench_cloud_partition() -> dict:
    trace = Trace.poisson(rps=6.0, duration_s=20.0, fn_names=("fn",),
                          seed=2, prompt_len=6, max_new=6)
    faults = cloud_partition(8.0, 14.0, link=0)
    return _scenario("cloud_partition", trace, faults)


def main(out_dir: str | None = None) -> dict:
    out = {
        "flash_crowd": bench_flash_crowd(),
        "edge_brownout": bench_edge_brownout(),
        "cloud_partition": bench_cloud_partition(),
    }
    if out_dir:
        path = os.path.join(out_dir, "bench_chaos.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"chaos results -> {path}")
    return out


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "results"))
