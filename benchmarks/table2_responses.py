"""Paper Table 2: successful responses per (workload x traffic policy).

Runs the deterministic continuum simulator (via the
``repro.platform.Continuum`` facade) for the paper's four workloads under
the six traffic policies and prints the table in the paper's format.  The
'auto' column exercises the real Eqs (1)-(4) controller through
``Policy.parse`` — the same objects the live runtime schedules with.
"""

from __future__ import annotations

import json
import os
from typing import Dict

from repro.platform import Continuum, SimConfig, Topology

POLICIES = (0.0, 25.0, 50.0, 75.0, 100.0, "auto")
WORKLOADS = ("matmult", "image_proc", "io", "mixed")
LABELS = {"matmult": "MatMult", "image_proc": "Image Proc.",
          "io": "I/O", "mixed": "Mixed"}


def run_three_tier(cfg: SimConfig = SimConfig(duration_s=300.0)) -> Dict:
    """Beyond-paper row: the auto controller over a device/edge/cloud
    chain (per-boundary Eqs (1)-(4), waterfall spill), with per-tier
    successful-response counts."""
    topo = Topology.device_edge_cloud(device_slots=2, edge_slots=4,
                                      cloud_slots=64)
    out: Dict[str, Dict] = {}
    for wl in WORKLOADS:
        r = Continuum.simulate(wl, "auto", cfg, topology=topo)
        out[wl] = {"successes": r.successes, "failures": r.failures,
                   "spilled": r.spilled, "tier_counts": r.tier_counts,
                   # per-link egress peaks, chain order — deep-link
                   # saturation is invisible in the headline net_MBps
                   "net_peak_MBps": [
                       float(r.net_links_MBps[l].max(initial=0.0))
                       for l in range(r.net_links_MBps.shape[0])]}
    return out


def run(cfg: SimConfig = SimConfig(duration_s=300.0)) -> Dict[str, Dict[str, int]]:
    table: Dict[str, Dict[str, int]] = {}
    for wl in WORKLOADS:
        sweep = Continuum.sweep(wl, POLICIES, cfg)
        table[wl] = {pol: res.successes for pol, res in sweep.items()}
    return table


def main(out_dir: str | None = None) -> Dict:
    table = run()
    header = f"{'Traffic':>8} | " + " | ".join(f"{LABELS[w]:>12}" for w in WORKLOADS)
    print(header)
    print("-" * len(header))
    for pol in POLICIES:
        name = f"{int(pol)}%" if pol != "auto" else "auto"
        row = " | ".join(f"{table[w][str(pol)]:>12}" for w in WORKLOADS)
        print(f"{name:>8} | {row}")
    # the paper's qualitative claims, checked mechanically:
    claims = {
        "offload_beats_edge_only": all(
            table[w]["50.0"] > table[w]["0.0"] for w in WORKLOADS),
        "auto_between_extremes": all(
            table[w]["auto"] >= min(table[w]["0.0"], table[w]["100.0"])
            for w in WORKLOADS),
    }
    print("\nclaims:", json.dumps(claims))
    three = run_three_tier()
    print("\n3-tier (device/edge/cloud, auto, waterfall):")
    for wl in WORKLOADS:
        row = three[wl]
        per = " ".join(f"{n}={c}" for n, c in row["tier_counts"].items())
        net = " ".join(f"l{i}={p:.1f}M"
                       for i, p in enumerate(row["net_peak_MBps"]))
        print(f"{LABELS[wl]:>12}: ok={row['successes']} "
              f"fail={row['failures']} spill={row['spilled']}  [{per}]  "
              f"net[{net}]")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "table2.json"), "w") as f:
            json.dump({"table": table, "claims": claims,
                       "three_tier": three}, f, indent=1)
    return {"table": table, "claims": claims, "three_tier": three}


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "results"))
