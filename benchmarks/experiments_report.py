"""Generate the EXPERIMENTS.md §Dry-run/§Roofline markdown tables from the
dry-run JSON artifacts (run after ``repro.launch.dryrun --all``).

    PYTHONPATH=src python -m benchmarks.experiments_report [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

ARCH_ORDER = ["qwen2.5-14b", "llama3-405b", "stablelm-1.6b",
              "nemotron-4-340b", "hymba-1.5b", "musicgen-medium",
              "internvl2-1b", "rwkv6-7b", "qwen2-moe-a2.7b", "mixtral-8x7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tagged: bool = False) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, mesh, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        is_tagged = len(parts) > 2
        if is_tagged != tagged:
            continue
        with open(path) as f:
            r = json.load(f)
        r["_tag"] = parts[2] if is_tagged else ""
        rows.append(r)
    key = lambda r: (ARCH_ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"]),
                     r["_tag"])
    return sorted(rows, key=key)


def ms(x):
    return f"{x*1e3:,.1f}"


def gib(x):
    return f"{x/2**30:.1f}"


def table(rows: List[Dict], with_tag: bool = False) -> str:
    hdr = ["arch", "shape"] + (["variant"] if with_tag else []) + \
        ["compute ms", "memory ms", "collective ms", "dominant",
         "MODEL/HLO", "roofline frac", "GiB/dev", "compile s"]
    lines = ["| " + " | ".join(hdr) + " |",
             "|" + "|".join(["---"] * len(hdr)) + "|"]
    for r in rows:
        ro = r["roofline"]
        cells = [r["arch"], r["shape"]] + ([r["_tag"]] if with_tag else []) + [
            ms(ro["compute_s"]), ms(ro["memory_s"]), ms(ro["collective_s"]),
            ro["dominant"], f"{r.get('useful_ratio', 0):.2f}",
            f"{r.get('roofline_fraction', 0):.4f}",
            gib(r.get("memory", {}).get("per_device_total", 0)),
            f"{r.get('compile_s', 0):.0f}"]
        lines.append("| " + " | ".join(str(c) for c in cells) + " |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both")
    ap.add_argument("--tagged", action="store_true",
                    help="show hillclimb variants instead of baselines")
    args = ap.parse_args()
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for mesh in meshes:
        rows = load(mesh, tagged=args.tagged)
        if not rows:
            continue
        chips = rows[0]["chips"]
        print(f"\n### mesh `{mesh}` ({chips} chips)\n")
        print(table(rows, with_tag=args.tagged))


if __name__ == "__main__":
    main()
