"""Benchmark regression gate for CI.

Runs a fresh ``serving_bench`` + ``controller_micro`` + ``bench_chaos``
+ ``bench_paged`` + ``bench_sharded_tier`` pass, then compares the
CPU-stable metrics against the committed goldens in
``benchmarks/results/*.json``.  Absolute wall-clock numbers vary wildly
across machines, so the gate checks *relative* metrics (speedup ratios:
throughput-shaped, machine-independent) and structural invariants
(served-request counts, onset detection), failing on a >25% drop:

    PYTHONPATH=src python -m benchmarks.check_regression --out fresh
    PYTHONPATH=src python -m benchmarks.check_regression \
        --fresh fresh --skip-run          # compare an existing run

Refreshing the goldens after an intentional change is one command (see
README): ``PYTHONPATH=src python -m benchmarks.run serving controller``
rewrites ``benchmarks/results/*.json`` in place; ``--json out.json``
writes the same payload as one combined file, which this gate accepts
anywhere a results directory is.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

BASELINE = os.path.join(os.path.dirname(__file__), "results")

# (bench, dotted metric path, kind) — every entry must be stable on CPU
# across machines.  kind:
#   "ratio":  higher is better; fail when fresh < golden * (1 - threshold)
#   "count":  exact match (deterministic request accounting)
#   "flag":   must be truthy whenever the golden is
STABLE_METRICS: List[Tuple[str, str, str]] = [
    ("serving_bench", "scheduler.batched_speedup", "ratio"),
    ("serving_bench", "continuous_vs_wave.p95_speedup", "ratio"),
    ("serving_bench", "continuous_vs_wave.p50_speedup", "ratio"),
    ("serving_bench", "prefill_bucketing.bucketed_speedup", "ratio"),
    ("serving_bench", "policies.edge_only.served", "count"),
    ("serving_bench", "policies.auto.served", "count"),
    ("serving_bench", "scheduler.batched.served", "count"),
    ("serving_bench", "continuous_vs_wave.continuous.served", "count"),
    ("serving_bench", "continuous_vs_wave.wave.served", "count"),
    ("serving_bench", "closed_loop.onset_detected", "flag"),
    # mid-stream migration: identical arrival routing in both arms, so
    # served counts are deterministic and must match exactly; the p95
    # win's magnitude is machine-relative, but its existence is not
    ("serving_bench", "migration.p95_improved", "flag"),
    ("serving_bench", "migration.route_only.served", "count"),
    ("serving_bench", "migration.migrate.served", "count"),
    ("serving_bench", "migration.migrate.migrations_completed", "count"),
    ("controller_micro", "route_speedup_B4096", "ratio"),
    # vectorized control plane: the batched rows kernel must stay
    # bit-identical to the per-boundary loop, the F=4096 streaming tick
    # must stay inside its 1 ms budget, and the sketch tick must keep
    # beating the exact sort-bound tick by a wide margin (a timing
    # *ratio*, so machine speed cancels out)
    ("controller_micro", "vector_bit_identical", "flag"),
    ("controller_micro", "vector_tick_under_1ms", "flag"),
    ("controller_micro", "vector_tick_speedup_F4096", "ratio"),
    # chaos scenarios: conservation + migration identities must hold in
    # every arm, the adaptive controller must serve strictly more than
    # the static split at the same offered trace, and — where the win is
    # charged (machine-independent) rather than wall-clock — its
    # interactive p95 must be lower.  cloud_partition's p95 is not
    # gated (both arms pay wall-clock recovery costs there); its bite
    # is that in-flight migrations really aborted and nothing was lost.
    ("bench_chaos", "flash_crowd.conserved", "flag"),
    ("bench_chaos", "flash_crowd.migration_identity", "flag"),
    ("bench_chaos", "flash_crowd.auto_more_served", "flag"),
    ("bench_chaos", "flash_crowd.auto_better_p95", "flag"),
    ("bench_chaos", "edge_brownout.conserved", "flag"),
    ("bench_chaos", "edge_brownout.migration_identity", "flag"),
    ("bench_chaos", "edge_brownout.auto_more_served", "flag"),
    ("bench_chaos", "edge_brownout.auto_better_p95", "flag"),
    ("bench_chaos", "edge_brownout.aborted_transits", "flag"),
    ("bench_chaos", "cloud_partition.conserved", "flag"),
    ("bench_chaos", "cloud_partition.migration_identity", "flag"),
    ("bench_chaos", "cloud_partition.auto_more_served", "flag"),
    ("bench_chaos", "cloud_partition.aborted_transits", "flag"),
    # paged KV cache: both arms serve the whole trace (deterministic
    # counts), the paged pool packs strictly more resident requests per
    # GB than the dense pool of the same bytes, the Zipf trace's prefix
    # reuse keeps the hit rate above half, and a partial row's migration
    # payload is smaller than the dense full row.
    ("bench_paged", "dense.served", "count"),
    ("bench_paged", "paged.served", "count"),
    ("bench_paged", "served_equal", "flag"),
    ("bench_paged", "paged_packs_more", "flag"),
    ("bench_paged", "hit_rate_over_half", "flag"),
    ("bench_paged", "resident_per_gb_ratio", "ratio"),
    ("bench_paged", "migration_payload.paged_smaller", "flag"),
    # sharded-tier cost model: pure arithmetic over the synthetic-HLO
    # walk plus one seeded sim run — every gated value is exact.  Slot
    # counts are the HBM-derived integers both deployments share; the
    # structural flags pin the calibration point (ingress mult == 1),
    # the honest speed inversion, and the roofline regimes (device
    # weight-streaming-bound, 256-way cloud interconnect-bound).
    ("bench_sharded_tier", "ingress_mult_is_one", "flag"),
    ("bench_sharded_tier", "speed_inversion", "flag"),
    ("bench_sharded_tier", "device_memory_bound", "flag"),
    ("bench_sharded_tier", "cloud_collective_bound", "flag"),
    ("bench_sharded_tier", "requested_slots_preserved", "flag"),
    ("bench_sharded_tier", "overrequest_clamps.clamped", "flag"),
    ("bench_sharded_tier", "overrequest_clamps.slots", "count"),
    ("bench_sharded_tier", "tiers.device.slots", "count"),
    ("bench_sharded_tier", "tiers.edge.slots", "count"),
    ("bench_sharded_tier", "tiers.cloud.slots", "count"),
    ("bench_sharded_tier", "tiers.edge.kv_fit_slots", "count"),
    ("bench_sharded_tier", "sim.failures", "count"),
    ("bench_sharded_tier", "sim.offload_onset", "flag"),
]


def dig(d: Dict, path: str):
    """Resolve a dotted path into nested dicts (None when absent)."""
    cur = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def derive(results: Dict) -> Dict:
    """Add metrics computed from raw bench output (ratios of timings are
    machine-stable even when the timings are not)."""
    cm = results.get("controller_micro")
    if cm:
        cm = dict(cm)
        if "route_batch_B4096_us" in cm:
            cm["route_speedup_B4096"] = (cm["route_batch_dense_B4096_us"]
                                         / cm["route_batch_B4096_us"])
        if "vector_controller_F4096_us" in cm:
            cm["vector_tick_speedup_F4096"] = (
                cm["exact_controller_F4096_us"]
                / cm["vector_controller_F4096_us"])
        results = dict(results)
        results["controller_micro"] = cm
    return results


def load_results(path: str) -> Dict[str, Dict]:
    """Load bench results from a directory of ``<bench>.json`` files or
    from one combined JSON (the ``benchmarks/run.py --json`` schema:
    ``{bench_name: {...}}``)."""
    if os.path.isdir(path):
        out = {}
        for name in os.listdir(path):
            if name.endswith(".json"):
                with open(os.path.join(path, name)) as f:
                    out[name[:-len(".json")]] = json.load(f)
        return out
    with open(path) as f:
        return json.load(f)


def compare(fresh: Dict[str, Dict], golden: Dict[str, Dict],
            threshold: float = 0.25) -> List[str]:
    """Return the list of regressions (empty = gate passes)."""
    fresh, golden = derive(fresh), derive(golden)
    problems: List[str] = []
    for bench, path, kind in STABLE_METRICS:
        want = dig(golden.get(bench, {}), path)
        if want is None:
            continue                    # golden predates this metric
        got = dig(fresh.get(bench, {}), path)
        name = f"{bench}:{path}"
        if got is None:
            problems.append(f"{name}: missing from fresh results")
        elif kind == "ratio":
            floor = want * (1.0 - threshold)
            if got < floor:
                problems.append(
                    f"{name}: {got:.3f} < {floor:.3f} "
                    f"(golden {want:.3f}, -{threshold:.0%} allowed)")
        elif kind == "count":
            if got != want:
                problems.append(f"{name}: {got} != golden {want}")
        elif kind == "flag":
            if bool(want) and not bool(got):
                problems.append(f"{name}: {got!r}, golden {want!r}")
    return problems


def run_benches(out_dir: str, benches: List[str]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    if "serving" in benches:
        from benchmarks import serving_bench
        serving_bench.main(out_dir)
    if "controller" in benches:
        from benchmarks import controller_micro
        controller_micro.main(out_dir)
    if "chaos" in benches:
        from benchmarks import bench_chaos
        bench_chaos.main(out_dir)
    if "paged" in benches:
        from benchmarks import bench_paged
        bench_paged.main(out_dir)
    if "sharded" in benches:
        from benchmarks import bench_sharded_tier
        bench_sharded_tier.main(out_dir)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default=BASELINE,
                    help="committed goldens (dir of <bench>.json, or one "
                         "combined JSON)")
    ap.add_argument("--out", default="fresh-results",
                    help="where the fresh bench JSONs are written")
    ap.add_argument("--fresh", default=None,
                    help="compare these results instead of --out")
    ap.add_argument("--benches", nargs="*",
                    default=["serving", "controller", "chaos", "paged",
                             "sharded"],
                    choices=["serving", "controller", "chaos", "paged",
                             "sharded"])
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max fractional drop allowed on ratio metrics")
    ap.add_argument("--skip-run", action="store_true",
                    help="only compare; do not run the benches")
    args = ap.parse_args(argv)

    if not args.skip_run:
        run_benches(args.out, args.benches)
    fresh = load_results(args.fresh or args.out)
    golden = load_results(args.baseline)
    problems = compare(fresh, golden, args.threshold)

    checked = sum(1 for b, p, _ in STABLE_METRICS
                  if dig(derive(golden).get(b, {}), p) is not None)
    if problems:
        print(f"REGRESSION GATE FAILED ({len(problems)}/{checked} metrics):")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"regression gate passed: {checked} stable metrics within "
          f"{args.threshold:.0%} of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
