"""Controller microbenchmarks: jitted Eqs (1)-(4) throughput + fused path.

The paper's controller runs as a 1 Hz Prometheus poll; ours is a jitted
array program. This bench measures (a) host-loop update latency, (b)
lax.scan throughput over a long trace, (c) the histogram-sketch path —
evidence for the beyond-paper "controller inside the serving step" claim
(its cost must be negligible vs a serve step).
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offload, quantile, router
from repro.core.policy import ControlLoop, Policy


def _time(f, *args, n=50):
    f(*args)                                    # compile + warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _wall(f, n=30):
    """Min wall-clock of a host-side tick (already-blocking call).

    Min, not mean: the tick budget is a property of the code, and on a
    shared CI core the minimum is the noise-free achievable cost while
    the mean soaks up scheduler preemptions.
    """
    f()                                         # compile + warm
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        f()
        ts.append(time.perf_counter() - t0)
    return float(min(ts))


def _vector_bit_identical(F=257, B=2, steps=6):
    """Run the batched and per-boundary control loops over the same
    inputs and require bitwise-equal R_t trajectories."""
    rng = np.random.default_rng(7)
    mk = lambda: Policy.parse("auto+net", link_bytes_per_s=2e6,
                              req_bytes=1500.0)
    vec = ControlLoop(mk(), F, window=8, num_tiers=B + 1)
    leg = ControlLoop(mk(), F, window=8, num_tiers=B + 1, vectorized=False)
    for _ in range(steps):
        lats = [rng.gamma(2.0, 0.05, (F, 8)).astype(np.float32)
                for _ in range(B)]
        valids = [rng.random((F, 8)) < 0.9 for _ in range(B)]
        arr = [rng.integers(0, 40, F) for _ in range(B)]
        Rv = vec.step_tiers(lats, valids, arrivals=arr)
        Rl = leg.step_tiers(lats, valids, arrivals=arr)
        if not np.array_equal(np.asarray(Rv), np.asarray(Rl)):
            return False
    return True


def main(out_dir: str | None = None):
    cfg = offload.OffloadConfig()
    results = {}
    for F, W in ((1, 64), (16, 256), (256, 256)):
        state = offload.OffloadState.init(F, cfg)
        lat = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (F, W))) + 0.01
        # lint: ignore[recompile-hazard] -- one wrapper per benchmarked
        # (F, W) config; _time warms it before the measured loop
        step = jax.jit(lambda s, l: offload.offload_update(s, l, cfg))
        dt = _time(step, state, lat)
        results[f"update_F{F}_W{W}_us"] = dt * 1e6
        print(f"offload_update F={F:4d} W={W:4d}: {dt*1e6:8.1f} us")

    # scan throughput over a (T, F, W) trace
    T, F, W = 512, 16, 128
    trace = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (T, F, W))) + 0.01
    scan = jax.jit(lambda tr: offload.scan_controller(cfg, tr))
    dt = _time(scan, trace, n=10)
    results["scan_steps_per_s"] = T / dt
    print(f"scan_controller: {T/dt:,.0f} controller steps/s")

    # router: sort-based O(B log B) route_batch vs the O(B^2) dense rank
    # matrix it replaced — the gap is what makes large-batch routing viable.
    for B in (256, 1024, 4096):
        F = 16
        key = jax.random.PRNGKey(3)
        fn_ids = jax.random.randint(jax.random.PRNGKey(4), (B,), 0, F)
        pct = jnp.linspace(0.0, 100.0, F)
        # lint: ignore[recompile-hazard] -- one wrapper per benchmarked
        # batch size; _time warms it before the measured loop
        fast = jax.jit(lambda k, p, f: router.route_batch(k, p, f, F))
        dt_s = _time(fast, key, pct, fn_ids)
        results[f"route_batch_B{B}_us"] = dt_s * 1e6
        # lint: ignore[recompile-hazard] -- one wrapper per benchmarked
        # batch size; _time warms it before the measured loop
        dense = jax.jit(
            lambda k, p, f: router.route_batch_dense(k, p, f, F))
        dt_d = _time(dense, key, pct, fn_ids, n=10 if B >= 1024 else 50)
        results[f"route_batch_dense_B{B}_us"] = dt_d * 1e6
        print(f"route_batch      B={B:5d}: {dt_s*1e6:8.1f} us   "
              f"dense: {dt_d*1e6:10.1f} us   ({dt_d/dt_s:6.1f}x)")

    # Fleet-scale vectorized control plane (ROADMAP item 3): one
    # ControlLoop tick over the whole fleet, both Eq-(1) front ends.
    # The exact path pays the O(F W log W) percentile sort; the
    # streaming-sketch tick (ingest + two-level quantile select +
    # Eqs (2)-(4), all one jitted call) is the 10k-function budget:
    # < 1 ms per tick at F=4096 / W=256 on one CPU core.
    rng = np.random.default_rng(0)
    for F in (1024, 4096):
        W = 256
        lat = rng.gamma(2.0, 0.05, (F, W)).astype(np.float32)
        valid = rng.random((F, W)) < 0.9
        arrivals = rng.integers(0, 30, F)
        exact = ControlLoop("auto", F, window=W)
        assert exact.vectorized
        dt = _wall(lambda: exact.step_tiers([lat], [valid],
                                            arrivals=[arrivals]),
                   n=4 if F >= 4096 else 10)
        results[f"exact_controller_F{F}_us"] = dt * 1e6

        # S fresh samples per 1 Hz tick (a quarter of the fleet reporting
        # each second); the tick cost is dominated by the F-shaped sketch
        # math, not S.
        S = F // 4
        ids = rng.integers(0, F, S).astype(np.int64)
        vals = rng.gamma(2.0, 0.05, S).astype(np.float32)
        sk = ControlLoop("auto", F, window=W, eq1="sketch")
        dt_s = _wall(lambda: sk.step_stream([(ids, vals)],
                                            arrivals=arrivals), n=30)
        results[f"vector_controller_F{F}_us"] = dt_s * 1e6
        print(f"fleet tick   F={F:4d} W={W}: exact {dt*1e6:9.1f} us   "
              f"sketch {dt_s*1e6:8.1f} us   ({dt/dt_s:5.1f}x)")
    results["vector_controller_us"] = results["vector_controller_F4096_us"]
    results["vector_tick_under_1ms"] = (
        results["vector_controller_F4096_us"] < 1000.0)

    # Bit-identity: the batched rows kernel must reproduce the legacy
    # per-boundary loop exactly (the golden contract check_regression
    # gates; tests/test_vector_control.py covers more shapes).
    results["vector_bit_identical"] = _vector_bit_identical()
    print(f"vector_bit_identical: {results['vector_bit_identical']}   "
          f"F=4096 sketch tick: "
          f"{results['vector_controller_F4096_us']:.0f} us")

    # sketch path
    hist = quantile.Histogram.init(16, num_buckets=64)
    lat16 = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (16, 128))) + 0.01
    upd = jax.jit(quantile.update)
    dt_u = _time(upd, hist, lat16)
    state16 = offload.OffloadState.init(16, cfg)
    fused = jax.jit(lambda s, h: offload.offload_update_from_sketch(s, h, cfg))
    dt_f = _time(fused, state16, hist)
    results["sketch_update_us"] = dt_u * 1e6
    results["sketch_controller_us"] = dt_f * 1e6
    print(f"histogram update: {dt_u*1e6:8.1f} us; "
          f"sketch controller: {dt_f*1e6:8.1f} us")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "controller_micro.json"), "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "results"))
