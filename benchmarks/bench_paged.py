"""Paged KV-cache bench: memory packing + prefix reuse on a Zipf trace.

Serverless LLM traffic is a few hot functions invoked over and over with
the same function prompt.  This bench plays one Zipf(1.1)-popularity
Poisson trace (``trace_prompts="per_fn"``: every invocation of a
function carries that function's prompt, as real function traffic does)
through two single-tier arms holding the *same KV pool bytes*:

  dense — 4 slots x 64-token contiguous rows (slot count == residency)
  paged — the same 16 pages (page_size 16) behind 8 slots: requests
          reserve only the pages their extent needs, invocations of the
          same function share its resident prompt pages copy-on-write,
          and exact-prompt hits skip prefill compute entirely.

Gated facts (CPU-stable; wall-clock is not gated):

  * both arms serve the whole trace (unbounded gateway -> deterministic
    served counts, and the packing comparison is at equal service);
  * the paged arm holds strictly more concurrently-resident requests
    per GB of KV pool than the dense arm;
  * >50% of offered prefill tokens hit the prefix registry;
  * a partially-filled paged row's migration payload (whole used pages)
    is strictly smaller than the dense full-row payload.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.platform import Continuum, Request, TierSpec, Topology, Trace

ARCH = "stablelm-1.6b"
MAX_LEN, PAGE = 64, 16
PROMPT_LEN, MAX_NEW = 24, 8
FNS = ("alpha", "beta", "gamma")
GB = 1 << 30


def _topology(paged: bool) -> Topology:
    # equal pool bytes: 4 dense rows of 64 == 16 pages of 16
    edge = TierSpec("edge", slots=(8 if paged else 4), max_len=MAX_LEN,
                    page_size=(PAGE if paged else None),
                    pool_pages=(16 if paged else None),
                    queue_depth_per_slot=None)
    return Topology((edge,), (), waterfall=False)


def _warm(cc: Continuum) -> None:
    """Compile the serving shapes off the clock."""
    tier = cc.tiers[0]
    for fn in FNS:
        g = 1
        while g <= tier.cfg.slots:
            tier.serve_batch(fn, [
                (Request(rid=-1 - i, tokens=np.zeros(6, np.int32),
                         max_new=2), time.perf_counter())
                for i in range(g)])
            g *= 2
        ep = tier.endpoints[fn]
        if ep.paged:
            ep.prefix.flush()
            ep.prefill_hit_tokens = 0
            ep.prefill_total_tokens = 0
        tier.metrics.clear()


def _run_arm(paged: bool, trace: Trace, seed: int = 0) -> dict:
    cfg = configs.get_smoke_config(ARCH)
    params = model_zoo.init(jax.random.PRNGKey(seed), cfg)
    cc = Continuum.from_topology(_topology(paged), policy=0.0, seed=seed,
                                 trace=trace, trace_prompts="per_fn",
                                 max_steps_per_tick=4)
    for fn in FNS:
        cc.deploy(FunctionSpec(name=fn, arch=ARCH), cfg, params)
    _warm(cc)
    t0 = time.perf_counter()
    for _ in range(int(np.ceil(trace.duration_s)) + 2):
        cc.tick()
    cc.drain()
    wall = time.perf_counter() - t0

    reqs = cc.trace_requests
    served = sum(1 for r in reqs if r.output is not None)
    eps = [cc.tiers[0].endpoints[fn] for fn in FNS]
    peak = sum(ep.peak_active for ep in eps)
    pool_gb = float(sum(ep.pool_nbytes for ep in eps)) / GB
    hit_tok = sum(getattr(ep, "prefill_hit_tokens", 0) for ep in eps)
    tot_tok = sum(getattr(ep, "prefill_total_tokens", 0) for ep in eps)
    out = {
        "layout": "paged" if paged else "dense",
        "submitted": len(reqs),
        "served": served,
        "failed": sum(1 for r in reqs if r.failed),
        "peak_resident": int(peak),
        "pool_gb": pool_gb,
        "resident_per_gb": peak / pool_gb,
        "prefill_hit_rate": (hit_tok / tot_tok if tot_tok else 0.0),
        "wall_s": wall,
        "conserved": bool(
            served + sum(1 for r in reqs if r.failed) == len(reqs)
            and cc.queued == 0 and cc.in_flight == 0),
    }
    if paged:
        out["pools_balanced"] = bool(all(ep.pool.check_balanced()
                                         for ep in eps))
    return out


def _migration_payload() -> dict:
    """Bytes a mid-stream migration ships for a row at a partial fill:
    the paged payload is its used pages, the dense payload the full row."""
    from repro.serving.engine import Endpoint
    cfg = configs.get_smoke_config(ARCH)
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    toks = np.arange(PROMPT_LEN, dtype=np.int32) % 64
    dense = Endpoint(cfg, params, slots=2, max_len=MAX_LEN)
    paged = Endpoint(cfg, params, slots=2, max_len=MAX_LEN, paged=True,
                     page_size=PAGE)
    sd = dense.try_claim(tokens=toks, max_new=MAX_NEW)
    sp = paged.try_claim(tokens=toks, max_new=MAX_NEW)
    dense.prefill_batch({sd: toks})
    paged.prefill_batch({sp: toks})
    d_state, = dense.extract_rows([sd])
    p_state, = paged.extract_rows([sp])
    d_bytes = float(sum(l.nbytes for l in d_state))
    return {
        "row_pos": PROMPT_LEN,
        "dense_bytes": d_bytes,
        "paged_bytes": p_state.nbytes,
        "paged_pages_shipped": p_state.n_pages,
        "paged_smaller": bool(p_state.nbytes < d_bytes),
    }


def main(out_dir: str | None = None) -> dict:
    trace = Trace.poisson(rps=8.0, duration_s=15.0, fn_names=FNS, seed=7,
                          popularity="zipf", zipf_s=1.1,
                          prompt_len=PROMPT_LEN, max_new=MAX_NEW,
                          payload_bytes=2.0e5)
    print(f"-- zipf trace: {len(trace)} requests over "
          f"{trace.duration_s:g}s across {len(FNS)} functions")
    dense = _run_arm(paged=False, trace=trace)
    paged = _run_arm(paged=True, trace=trace)
    ratio = paged["resident_per_gb"] / dense["resident_per_gb"]
    out = {
        "dense": dense,
        "paged": paged,
        "served_equal": bool(dense["served"] == paged["served"]
                             and dense["failed"] == 0
                             and paged["failed"] == 0),
        "resident_per_gb_ratio": float(ratio),
        "paged_packs_more": bool(ratio > 1.0),
        "hit_rate_over_half": bool(paged["prefill_hit_rate"] > 0.5),
        "migration_payload": _migration_payload(),
    }
    print(f"   dense: served {dense['served']}/{dense['submitted']}  "
          f"peak resident {dense['peak_resident']}  "
          f"({dense['resident_per_gb']:.0f}/GB)  {dense['wall_s']:.1f}s")
    print(f"   paged: served {paged['served']}/{paged['submitted']}  "
          f"peak resident {paged['peak_resident']}  "
          f"({paged['resident_per_gb']:.0f}/GB)  "
          f"hit-rate {paged['prefill_hit_rate']:.0%}  "
          f"{paged['wall_s']:.1f}s")
    mp = out["migration_payload"]
    print(f"   packing ratio {ratio:.2f}x; migration payload at pos "
          f"{mp['row_pos']}: {mp['paged_bytes']/1e3:.0f} kB paged vs "
          f"{mp['dense_bytes']/1e3:.0f} kB dense")
    if out_dir:
        path = os.path.join(out_dir, "bench_paged.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"paged results -> {path}")
    return out


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "results"))
