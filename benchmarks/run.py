"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One bench per paper artifact + the roofline report:

  table2       — Table 2 (successful responses per workload x policy)
  fig2         — Figure 2 time series (latency/CPU/memory/network CSVs)
  controller   — Eqs (1)-(4) microbenchmarks (jitted + sketch paths)
  serving      — live two-tier engine + policy + scheduler comparisons
  chaos        — trace + fault-injection scenarios (flash crowd, edge
                 brownout, cloud partition) on the live continuum
  paged        — paged KV-cache packing + prefix reuse on a Zipf trace
                 (dense vs paged pools at equal bytes)
  sharded      — cost-model-derived tier capacity for the sharded
                 device/edge/cloud continuum (slots, decode steps,
                 service-rate multipliers)
  roofline     — §Roofline table from the dry-run artifacts

Pass bench names to run a subset: ``python -m benchmarks.run table2 roofline``.

JSON-writing benches refresh the regression-gate goldens in
``benchmarks/results/`` in place — so after an intentional perf change,
``PYTHONPATH=src python -m benchmarks.run serving controller`` is the one
command that regenerates everything ``benchmarks/check_regression.py``
reads.  ``--json out.json`` additionally writes the same payload as one
combined ``{bench_name: {...}}`` file (also accepted by the gate's
``--baseline``/``--fresh``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results")
BENCHES = ("table2", "fig2", "controller", "serving", "chaos", "paged",
           "sharded", "roofline")
#: benches that write a results/<name>.json artifact (the gate's inputs)
JSON_ARTIFACTS = {"table2": "table2", "controller": "controller_micro",
                  "serving": "serving_bench", "chaos": "bench_chaos",
                  "paged": "bench_paged", "sharded": "bench_sharded_tier"}


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="run the paper-artifact benchmarks")
    ap.add_argument("benches", nargs="*", default=[],
                    help=f"subset to run from {BENCHES} (default: all)")
    ap.add_argument("--results-dir", default=RESULTS,
                    help="where per-bench JSON artifacts are written "
                         "(default: benchmarks/results — the goldens)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write one combined {bench: results} JSON — "
                         "the schema check_regression.py reads")
    args = ap.parse_args(argv)
    unknown = set(args.benches) - set(BENCHES)
    if unknown:
        ap.error(f"unknown benches {sorted(unknown)}; pick from {BENCHES}")
    wanted = set(args.benches) if args.benches else set(BENCHES)
    results_dir = args.results_dir
    os.makedirs(results_dir, exist_ok=True)
    t0 = time.time()

    if "table2" in wanted:
        print("\n" + "=" * 72 + "\nTable 2 — successful responses "
              "(simulator, 4 workloads x 6 policies)\n" + "=" * 72)
        from benchmarks import table2_responses
        table2_responses.main(results_dir)

    if "fig2" in wanted:
        print("\n" + "=" * 72 + "\nFigure 2 — metric time series\n" + "=" * 72)
        from benchmarks import fig2_timeseries
        fig2_timeseries.main()

    if "controller" in wanted:
        print("\n" + "=" * 72 + "\nController microbenchmarks\n" + "=" * 72)
        from benchmarks import controller_micro
        controller_micro.main(results_dir)

    if "serving" in wanted:
        print("\n" + "=" * 72 + "\nServing bench (live engine)\n" + "=" * 72)
        from benchmarks import serving_bench
        serving_bench.main(results_dir)

    if "chaos" in wanted:
        print("\n" + "=" * 72 + "\nChaos bench (traces + fault injection)\n"
              + "=" * 72)
        from benchmarks import bench_chaos
        bench_chaos.main(results_dir)

    if "paged" in wanted:
        print("\n" + "=" * 72 + "\nPaged KV-cache bench (packing + prefix "
              "reuse)\n" + "=" * 72)
        from benchmarks import bench_paged
        bench_paged.main(results_dir)

    if "sharded" in wanted:
        print("\n" + "=" * 72 + "\nSharded-tier cost model (derived "
              "capacity + service rates)\n" + "=" * 72)
        from benchmarks import bench_sharded_tier
        bench_sharded_tier.main(results_dir)

    if "roofline" in wanted:
        print("\n" + "=" * 72 + "\n§Roofline — dry-run derived terms\n" + "=" * 72)
        from benchmarks import roofline
        roofline.main()

    if args.json:
        combined = {}
        for bench, stem in JSON_ARTIFACTS.items():
            if bench not in wanted:
                continue
            path = os.path.join(results_dir, f"{stem}.json")
            if os.path.exists(path):
                with open(path) as f:
                    combined[stem] = json.load(f)
        with open(args.json, "w") as f:
            json.dump(combined, f, indent=1)
        print(f"combined results -> {args.json} ({sorted(combined)})")

    print(f"\nall benches done in {time.time()-t0:.1f}s; artifacts in "
          f"{results_dir}")


if __name__ == "__main__":
    main()
