"""Benchmark entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One bench per paper artifact + the roofline report:

  table2       — Table 2 (successful responses per workload x policy)
  fig2         — Figure 2 time series (latency/CPU/memory/network CSVs)
  controller   — Eqs (1)-(4) microbenchmarks (jitted + sketch paths)
  serving      — live two-tier engine + policy comparison
  roofline     — §Roofline table from the dry-run artifacts

Pass bench names to run a subset: ``python -m benchmarks.run table2 roofline``.
"""

from __future__ import annotations

import os
import sys
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def main(argv=None):
    argv = list(sys.argv[1:] if argv is None else argv)
    wanted = set(argv) if argv else {"table2", "fig2", "controller",
                                     "serving", "roofline"}
    os.makedirs(RESULTS, exist_ok=True)
    t0 = time.time()

    if "table2" in wanted:
        print("\n" + "=" * 72 + "\nTable 2 — successful responses "
              "(simulator, 4 workloads x 6 policies)\n" + "=" * 72)
        from benchmarks import table2_responses
        table2_responses.main(RESULTS)

    if "fig2" in wanted:
        print("\n" + "=" * 72 + "\nFigure 2 — metric time series\n" + "=" * 72)
        from benchmarks import fig2_timeseries
        fig2_timeseries.main()

    if "controller" in wanted:
        print("\n" + "=" * 72 + "\nController microbenchmarks\n" + "=" * 72)
        from benchmarks import controller_micro
        controller_micro.main(RESULTS)

    if "serving" in wanted:
        print("\n" + "=" * 72 + "\nServing bench (live engine)\n" + "=" * 72)
        from benchmarks import serving_bench
        serving_bench.main(RESULTS)

    if "roofline" in wanted:
        print("\n" + "=" * 72 + "\n§Roofline — dry-run derived terms\n" + "=" * 72)
        from benchmarks import roofline
        roofline.main()

    print(f"\nall benches done in {time.time()-t0:.1f}s; artifacts in "
          f"{RESULTS}")


if __name__ == "__main__":
    main()
