"""Paper Figure 2: latency / CPU / memory / network time series per policy.

Writes one CSV per (workload, policy) with the simulator's metric stream —
the same four panels as the paper's Figure 2 — plus a compact textual
summary (peaks and means) for quick inspection.
"""

from __future__ import annotations

import csv
import json
import os

import numpy as np

from repro.core.simulator import ContinuumSimulator, SimConfig

POLICIES = (0.0, 50.0, 100.0, "auto")
WORKLOADS = ("matmult", "image_proc", "io", "mixed")


def main(out_dir: str | None = None):
    out_dir = out_dir or os.path.join(os.path.dirname(__file__), "results",
                                      "fig2")
    os.makedirs(out_dir, exist_ok=True)
    cfg = SimConfig(duration_s=300.0)
    summary = {}
    for wl in WORKLOADS:
        for pol in POLICIES:
            res = ContinuumSimulator(wl, pol, cfg).run()
            name = f"{wl}_{pol}"
            path = os.path.join(out_dir, name + ".csv")
            with open(path, "w", newline="") as f:
                w = csv.writer(f)
                w.writerow(["t_s", "latency_s", "cpu_util", "mem_mb",
                            "net_MBps", "offload_pct"])
                for i in range(len(res.times)):
                    w.writerow([res.times[i], res.latency_avg[i],
                                res.cpu_util[i], res.mem_mb[i],
                                res.net_MBps[i], res.offload_pct[i]])
            summary[name] = {
                "latency_mean": float(np.nanmean(res.latency_avg)),
                "cpu_peak": float(np.nanmax(res.cpu_util)),
                "net_peak_MBps": float(np.nanmax(res.net_MBps)),
                "offload_peak_pct": float(np.nanmax(res.offload_pct)),
                "successes": res.successes,
            }
            print(f"{name:24s} lat={summary[name]['latency_mean']:.3f}s "
                  f"cpu={summary[name]['cpu_peak']:.2f} "
                  f"net={summary[name]['net_peak_MBps']:.1f}MB/s "
                  f"off={summary[name]['offload_peak_pct']:.0f}%")
    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    # §4.2 Network claim: full offload saturates the link for heavy
    # payloads while 'auto' stays below it.
    heavy = summary.get("image_proc_100.0", {}).get("net_peak_MBps", 0)
    auto = summary.get("image_proc_auto", {}).get("net_peak_MBps", 0)
    print(f"\nnetwork claim: 100%={heavy:.1f} MB/s >= auto={auto:.1f} MB/s:",
          heavy >= auto)
    return summary


if __name__ == "__main__":
    main()
