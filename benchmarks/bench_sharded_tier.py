"""Sharded-tier cost-model bench: the derived continuum, gated.

Prices the canonical cost-modeled chain —
``Topology.device_edge_cloud(cost_model=True)``: stablelm-1.6b on the
device, qwen2.5-14b on a 2-chip edge site, llama3-405b shard_map-sharded
over a (16, 16) cloud pod — and records the numbers the cost model
derives for both deployments.  Everything here is machine-independent:
the tier pricing is pure arithmetic over a synthetic HLO walk (no
wall-clock), and the simulator run is seeded.

Gated facts (see ``check_regression.py``):

  * the ingress tier's ``service_rate_mult`` is exactly 1.0 (the
    simulator's ``edge_service_s`` calibration point);
  * the honest speed inversion holds — each hop down the chain serves a
    far bigger model, so ``decode_step_ms`` strictly increases
    device -> edge -> cloud;
  * the sharded cloud step is interconnect-bound (its roofline's
    dominant term is the collective wire time) while the small
    unsharded device model is weight-streaming (memory) bound;
  * slot counts are the requested ceilings clamped to the per-device
    HBM KV fit — exact, deterministic integers — and an over-requested
    tier really clamps;
  * the resolved chain simulates with deterministic request accounting.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.launch import tier_cost
from repro.platform import Continuum, Topology

SEED_ARCH_ORDER = ("device", "edge", "cloud")


def _tier_row(spec) -> dict:
    return {
        "model": spec.model,
        "mesh_shape": list(spec.mesh_shape),
        "devices": spec.devices,
        "slots": spec.slots,
        "decode_step_ms": spec.decode_step_ms,
        "service_rate_mult": spec.service_rate_mult,
    }


def main(out_dir: str | None = None) -> dict:
    topo = Topology.device_edge_cloud(cost_model=True)
    tiers = {s.name: _tier_row(s) for s in topo.tiers}
    costs = {s.name: tier_cost.tier_cost(s.model, mesh_shape=s.mesh_shape,
                                         requested_slots=s.slots,
                                         max_len=s.max_len)
             for s in topo.tiers}
    for name, c in costs.items():
        tiers[name]["kv_fit_slots"] = c.kv_fit_slots
        tiers[name]["dominant"] = c.roofline["dominant"]
        tiers[name]["params_gb_per_device"] = (
            c.params_bytes_per_device / 1e9)

    steps = [tiers[n]["decode_step_ms"] for n in SEED_ARCH_ORDER]
    # an over-requested small model clamps to its HBM KV fit
    clamp = tier_cost.tier_cost("stablelm-1.6b", requested_slots=10_000)

    res = Continuum.simulate("matmult", "auto", topology=topo)
    sim = {
        "failures": int(res.failures),
        "latency_avg": float(np.nanmean(res.latency_avg)),
        "offload_onset": bool(np.any(np.asarray(res.offload_pct) > 0)),
    }

    out = {
        "tiers": tiers,
        "ingress_mult_is_one":
            tiers["device"]["service_rate_mult"] == 1.0,
        "speed_inversion": bool(steps[0] < steps[1] < steps[2]),
        "device_memory_bound": tiers["device"]["dominant"] == "memory",
        "cloud_collective_bound": tiers["cloud"]["dominant"] == "collective",
        "requested_slots_preserved": bool(
            tiers["device"]["slots"] == 2 and tiers["edge"]["slots"] == 4
            and tiers["cloud"]["slots"] == 64),
        "overrequest_clamps": {
            "requested": clamp.requested_slots,
            "slots": clamp.slots,
            "clamped": bool(clamp.slots == clamp.kv_fit_slots < 10_000),
        },
        "sim": sim,
    }
    for name in SEED_ARCH_ORDER:
        t = tiers[name]
        print(f"   {name:6s} {t['model']:14s} mesh {tuple(t['mesh_shape'])} "
              f"slots {t['slots']:3d} (fit {t['kv_fit_slots']})  "
              f"step {t['decode_step_ms']:7.3f} ms  "
              f"mult {t['service_rate_mult']:.4f}  {t['dominant']}")
    print(f"   sim: failures {sim['failures']}  "
          f"latency_avg {sim['latency_avg']:.3f}s  "
          f"onset {sim['offload_onset']}")
    if out_dir:
        path = os.path.join(out_dir, "bench_sharded_tier.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"sharded-tier results -> {path}")
    return out


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "results"))
