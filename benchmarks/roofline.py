"""§Roofline report: aggregate the dry-run artifacts into the per-cell
three-term table (compute / memory / collective seconds, dominant term,
MODEL_FLOPS ratio, one-line bottleneck note).

Reads benchmarks/results/dryrun/<mesh>/<arch>__<shape>[__tag].json written
by ``repro.launch.dryrun``; does not lower anything itself (so it runs in
milliseconds and inside ``benchmarks.run``).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

NOTE = {
    ("train", "collective"): "FSDP weight gathers + grad reductions dominate"
                             " — fuse reduce-scatter / cut accum re-gathers",
    ("train", "memory"): "remat boundary + optimizer traffic — deepen remat"
                         " grouping, bf16 moments, seq-shard boundaries",
    ("train", "compute"): "near MXU bound — tune accum/microbatch",
    ("prefill", "memory"): "flash chunk streaming in fp32 — bf16 dot inputs"
                           " with fp32 accumulation",
    ("prefill", "collective"): "TP all-reduces per layer — overlap with"
                               " compute via latency-hiding scheduler",
    ("prefill", "compute"): "attention FLOPs dominate — good (S^2 work)",
    ("decode", "memory"): "KV cache streaming — keep cache bf16, avoid"
                          " materialized f32 converts",
    ("decode", "collective"): "per-layer FSDP weight gathers at batch<<model"
                              " size — switch to serve_replicated weights",
    ("decode", "compute"): "unexpected for decode — check dispatch overhead",
}


def load(mesh: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS, mesh, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r: Dict) -> str:
    roof = r["roofline"]
    frac = r.get("roofline_fraction", 0.0)
    note = NOTE.get((r["kind"], roof["dominant"]), "")
    tag = ""
    mem = r.get("memory", {}).get("per_device_total", 0) / 2**30
    return (f"{r['arch']:>16s} {r['shape']:>12s} "
            f"{roof['compute_s']*1e3:>12.2f} {roof['memory_s']*1e3:>12.2f} "
            f"{roof['collective_s']*1e3:>12.2f} {roof['dominant']:>10s} "
            f"{r.get('useful_ratio', 0):>6.2f} {frac:>8.4f} {mem:>8.2f}")


def main(meshes=("single", "multi")) -> Dict:
    out = {}
    for mesh in meshes:
        rows = load(mesh)
        if not rows:
            print(f"[roofline] no dry-run artifacts for mesh={mesh} — run "
                  f"`python -m repro.launch.dryrun --all --mesh {mesh}` first")
            continue
        # keep only untagged baselines in the main table
        base = [r for r in rows if "__" not in os.path.basename(
            r.get("arch", "")) and r.get("meta", {}).get("variant") is None]
        print(f"\n=== mesh: {mesh} ({rows[0]['chips']} chips) — times are ms "
              f"per step ===")
        print(f"{'arch':>16s} {'shape':>12s} {'compute':>12s} {'memory':>12s} "
              f"{'collective':>12s} {'dominant':>10s} {'useful':>6s} "
              f"{'frac':>8s} {'GiB/dev':>8s}")
        for r in rows:
            print(fmt_row(r))
        doms = {}
        for r in rows:
            doms[r["roofline"]["dominant"]] = doms.get(
                r["roofline"]["dominant"], 0) + 1
        print(f"dominant-term histogram: {doms}")
        out[mesh] = {"cells": len(rows), "dominant_histogram": doms}
    return out


if __name__ == "__main__":
    main()
