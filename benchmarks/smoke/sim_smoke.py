"""Simulator smoke run (CI): a 2-tier and a 3-tier ``Continuum.simulate``
must produce successful responses, per-tier counts, and per-link net
series.

    PYTHONPATH=src python benchmarks/smoke/sim_smoke.py
"""

from repro.platform import Continuum, SimConfig, Topology


def main():
    cfg = SimConfig(duration_s=30.0)
    r = Continuum.simulate("io", "auto", cfg)
    print("2-tier:", r.summary())
    assert r.successes > 0
    r3 = Continuum.simulate("io", "auto", cfg,
                            topology=Topology.device_edge_cloud())
    print("3-tier:", r3.summary())
    assert r3.successes > 0 and len(r3.tier_counts) == 3
    assert r3.net_links_MBps.shape[0] == 2
    print("sim smoke OK")


if __name__ == "__main__":
    main()
