"""Paged KV-cache smoke run (CI): the interpret-mode paged Pallas
kernel must match the dense kernel bitwise on a gathered page-table
view, and a page-starved live tier must still conserve requests
(served + failed == submitted, pool balanced after drain).

    PYTHONPATH=src python benchmarks/smoke/paged_smoke.py
"""

import jax
import numpy as np

from repro import configs
from repro.cache import pages_for_tokens
from repro.core.replication import FunctionSpec
from repro.kernels import decode_attention as dec_mod
from repro.models import model_zoo
from repro.platform import Continuum, Request, TierSpec, Topology


def kernel_smoke():
    rng = np.random.default_rng(0)
    page, ppr, Hkv, G, D = 16, 4, 2, 2, 64
    B, P = 3, 9
    lengths = [5, 64, 37]
    k_pool = rng.standard_normal((P + 1, page, Hkv, D)).astype(np.float32)
    v_pool = rng.standard_normal((P + 1, page, Hkv, D)).astype(np.float32)
    kv_pos_pages = np.full((P + 1, page), -1, np.int32)
    tables = np.full((B, ppr), P, np.int32)
    nxt = iter(range(P))
    for b, L in enumerate(lengths):
        for i in range(pages_for_tokens(L, page)):
            pid = next(nxt)
            tables[b, i] = pid
            lo = i * page
            n = min(L - lo, page)
            kv_pos_pages[pid, :n] = np.arange(lo, lo + n)
    q = rng.standard_normal((B, G * Hkv, D)).astype(np.float32)
    q_pos = np.asarray(lengths, np.int32)
    out_paged = dec_mod.paged_decode_attention(
        q, k_pool, v_pool, tables, q_pos, kv_pos_pages, interpret=True)
    k_dense = k_pool[tables].reshape(B, ppr * page, Hkv, D)
    v_dense = v_pool[tables].reshape(B, ppr * page, Hkv, D)
    kv_pos = kv_pos_pages[tables].reshape(B, ppr * page)
    out_dense = dec_mod.decode_attention(
        q, k_dense, v_dense, q_pos, kv_pos, blk_k=page, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_paged),
                                  np.asarray(out_dense))
    print(f"paged kernel: bitwise == dense on {B} rows "
          f"(lengths {lengths}, page {page})")


def exhaustion_smoke():
    # a pool of 6 pages behind 3 slots: pages bind before slots do
    topo = Topology(
        tiers=(TierSpec("edge", slots=3, max_len=32, page_size=8,
                        pool_pages=6, queue_depth_per_slot=2),),
        links=(), waterfall=False)
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    cc = Continuum.from_topology(topo, policy=0.0, seed=0,
                                 max_steps_per_tick=4)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    rng = np.random.default_rng(1)
    reqs = []
    for burst in range(3):
        for _ in range(5):
            r = Request(rid=len(reqs),
                        tokens=rng.integers(0, 64, 14).astype(np.int32),
                        max_new=4)
            cc.submit("fn", r)
            reqs.append(r)
        cc.tick()
    cc.drain()
    served = sum(1 for r in reqs if r.output is not None)
    failed = sum(1 for r in reqs if r.failed)
    assert served + failed == len(reqs)
    assert all((r.output is not None) != r.failed for r in reqs)
    assert cc.queued == 0 and cc.in_flight == 0
    ep = cc.tiers[0].endpoints["fn"]
    assert ep.pool.check_balanced() and ep.active == 0
    print(f"page exhaustion: {served} served + {failed} failed "
          f"== {len(reqs)} submitted; pool balanced")


def main():
    kernel_smoke()
    exhaustion_smoke()
    print("PAGED SMOKE OK")


if __name__ == "__main__":
    main()
