"""Chaos smoke run (CI): a short trace-driven live run through a link
brownout and an edge crash — requests must be conserved (served + failed
== submitted), crashed-tier residents must be replayed rather than lost,
and the migration identity must balance after drain.

    PYTHONPATH=src python benchmarks/smoke/chaos_smoke.py
"""

import jax

from repro import configs
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.platform import (Continuum, FaultEvent, FaultSchedule, LinkSpec,
                            TierSpec, Topology, Trace, edge_brownout,
                            merge_schedules)


def main():
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64,
                        queue_depth_per_slot=8),
               TierSpec("cloud", slots=4, max_len=64)),
        links=(LinkSpec(rtt_s=0.02, bandwidth_Bps=50e6),))
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)

    trace = Trace.poisson(rps=4.0, duration_s=6.0, fn_names=("fn",),
                          seed=3, prompt_len=6, max_new=4)
    faults = merge_schedules(
        edge_brownout(1.0, 3.0, link=0, bw_mult=0.1, rtt_mult=4.0),
        FaultSchedule((FaultEvent(t=4.0, kind="crash_tier", target=0),
                       FaultEvent(t=5.0, kind="restore_tier", target=0))))
    cc = Continuum.from_topology(topo, policy="auto+migrate", seed=0,
                                 trace=trace, faults=faults,
                                 max_steps_per_tick=4)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    for rnd in range(8):
        rec = cc.tick()
        print(rnd, rec["tiers"], "backlog:", rec["backlog"])
    cc.drain()

    reqs = cc.trace_requests
    served = sum(1 for r in reqs if r.output is not None)
    failed = sum(1 for r in reqs if r.failed)
    c = cc.metrics.counter
    assert len(reqs) == len(trace)
    assert served + failed == len(reqs)
    assert all((r.output is not None) != r.failed for r in reqs)
    assert cc.queued == 0 and cc.in_flight == 0 and cc.migrations_open == 0
    assert c("faults_applied") == len(faults)
    assert c("migrations_fired") == (c("migrations_completed")
                                     + c("migrations_aborted"))
    print(f"chaos smoke OK: served {served}/{len(reqs)}, "
          f"replayed {int(c('replayed'))}, "
          f"faults {int(c('faults_applied'))}")


if __name__ == "__main__":
    main()
