"""Live 3-tier gateway smoke run (CI): real endpoints on every tier of a
device/edge/cloud chain, driven by the continuous-batching scheduler —
nothing may be dropped or double-served, and in-flight hedge accounting
must balance.

    PYTHONPATH=src python benchmarks/smoke/live_gateway_smoke.py
"""

import jax
import numpy as np

from repro import configs
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.platform import (Continuum, LinkSpec, Request, TierSpec, Topology)


def main():
    topo = Topology(
        tiers=(TierSpec("device", slots=1, max_len=64),
               TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(rtt_s=0.005), LinkSpec(rtt_s=0.04)))
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    cc = Continuum.from_topology(topo, policy="auto", seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    rid = 0
    for rnd in range(6):
        for _ in range(2 if rnd < 2 else 6):
            assert cc.submit("fn", Request(
                rid=rid, tokens=np.arange(6, dtype=np.int32), max_new=2))
            rid += 1
        rec = cc.tick()
        print(rnd, rec["tiers"], "steps:", rec["steps"],
              "backlog:", rec["backlog"])
    served = sum(sum(r["tiers"].values()) for r in cc.log)
    rejected = sum(r["rejected"] for r in cc.log)
    assert served + cc.queued + cc.in_flight == rid and rejected == 0
    assert cc.hedges_open == 0
    print(f"live smoke OK: served {served}/{rid}")


if __name__ == "__main__":
    main()
