"""Sharded-tier smoke run (CI): on two forced host devices, a
shard_map tensor-parallel endpoint must produce the bit-identical token
stream of its dense twin, and a cost-modeled (resolved) topology must
deploy live with a sharded pool and serve real requests.

    PYTHONPATH=src python benchmarks/smoke/sharded_smoke.py
"""

import os

# two placeholder devices; must be set before jax initializes
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro import configs                               # noqa: E402
from repro.core.replication import FunctionSpec         # noqa: E402
from repro.launch import mesh as mesh_mod               # noqa: E402
from repro.models import model_zoo                      # noqa: E402
from repro.platform import (Continuum, Request, TierSpec,  # noqa: E402
                            Topology)
from repro.serving.engine import Endpoint               # noqa: E402


def parity_smoke():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)

    def run(mesh):
        ep = Endpoint(cfg, params, slots=4, max_len=32, mesh=mesh)
        rng = np.random.RandomState(7)
        prompts = {s: rng.randint(0, cfg.vocab_size,
                                  size=(5 + s,)).astype(np.int32)
                   for s in range(3)}
        for _ in prompts:
            ep.try_claim()
        cur = ep.prefill_batch(prompts)
        streams = {s: [int(v)] for s, v in cur.items()}
        for _ in range(5):
            cur = ep.decode_all(cur)
            for s, v in cur.items():
                streams[s].append(int(v))
        return streams

    dense = run(None)
    sharded = run(mesh_mod.make_mesh((1, 2), ("data", "model")))
    assert dense == sharded, (dense, sharded)
    print(f"sharded parity: 3 streams x {len(dense[0])} tokens bitwise "
          f"== dense on {len(jax.devices())} host devices")


def costed_live_smoke():
    # resolve a cost-modeled sharded tier, then serve through it live
    topo = Topology.costed(
        (TierSpec("edge", slots=4, model="stablelm-1.6b",
                  mesh_shape=(1, 2), queue_depth_per_slot=None),),
        links=(), waterfall=False)
    spec = topo.tiers[0]
    assert spec.resolved and spec.service_rate_mult == 1.0
    assert spec.decode_step_ms > 0

    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    cc = Continuum.from_topology(topo, policy=0.0, seed=0,
                                 max_steps_per_tick=4)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    ep = cc.tiers[0].endpoints["fn"]
    assert ep._tp == 2, "tier did not deploy tensor-parallel"
    assert ep.slots == spec.slots

    rng = np.random.default_rng(1)
    reqs = []
    for _ in range(6):
        r = Request(rid=len(reqs),
                    tokens=rng.integers(0, 64, 10).astype(np.int32),
                    max_new=4)
        cc.submit("fn", r)
        reqs.append(r)
    cc.tick()
    cc.drain()
    served = sum(1 for r in reqs if r.output is not None)
    assert served == len(reqs), (served, len(reqs))
    print(f"costed live tier: {served}/{len(reqs)} served on a "
          f"tensor-parallel pool (slots {ep.slots}, "
          f"step {spec.decode_step_ms:.3f} ms, mult "
          f"{spec.service_rate_mult:g})")


def main():
    parity_smoke()
    costed_live_smoke()
    print("SHARDED SMOKE OK")


if __name__ == "__main__":
    main()
