"""Docs smoke: execute fenced Python snippets, check relative links.

CI's docs job runs this over ``README.md`` and ``docs/*.md`` so the
documentation cannot rot silently:

- every ```` ```python ```` fenced block is executed in its own
  namespace (a failing snippet fails the job).  A block preceded
  directly by ``<!-- docs: no-run -->`` is skipped — for fragments that
  are deliberately not self-contained (e.g. a lone ``except:`` clause
  shown to document a suppression format);
- every relative markdown link target must exist on disk (dead links to
  moved/renamed files fail the job; external http(s)/mailto links and
  pure anchors are not checked).

Run locally:  PYTHONPATH=src python -m benchmarks.smoke.docs_smoke
"""

from __future__ import annotations

import glob
import os
import re
import sys
import traceback
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

NO_RUN = "<!-- docs: no-run -->"
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def default_files() -> List[str]:
    return [os.path.join(REPO, "README.md")] + sorted(
        glob.glob(os.path.join(REPO, "docs", "*.md")))


def extract_snippets(path: str) -> List[Tuple[int, str]]:
    """(start_line, source) for each runnable ```python block."""
    out = []
    lines = open(path).read().splitlines()
    i, skip_next = 0, False
    while i < len(lines):
        stripped = lines[i].strip()
        if stripped == NO_RUN:
            skip_next = True
        elif stripped.startswith("```"):
            info = stripped[3:].strip()
            block, start = [], i + 1
            i += 1
            while i < len(lines) and not lines[i].strip().startswith("```"):
                block.append(lines[i])
                i += 1
            if info == "python" and not skip_next:
                out.append((start + 1, "\n".join(block)))
            skip_next = False
        elif stripped:
            skip_next = False
        i += 1
    return out


def check_links(path: str) -> List[str]:
    """Dead relative-link targets in one markdown file."""
    problems = []
    base = os.path.dirname(os.path.abspath(path))
    for ln, line in enumerate(open(path).read().splitlines(), 1):
        for target in _LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if rel and not os.path.exists(os.path.join(base, rel)):
                problems.append(
                    f"{os.path.relpath(path, REPO)}:{ln}: "
                    f"dead link target {target!r}")
    return problems


def run_snippet(path: str, lineno: int, src: str) -> str | None:
    """Execute one snippet; returns an error description or None."""
    label = f"{os.path.relpath(path, REPO)}:{lineno}"
    try:
        code = compile(src, label, "exec")
        exec(code, {"__name__": "__docs__"})  # noqa: S102 - the point
        return None
    except Exception:
        return f"{label}: snippet failed\n{traceback.format_exc()}"


def main(argv: List[str] | None = None) -> int:
    files = (argv if argv else None) or default_files()
    failures: List[str] = []
    n_snippets = 0
    for path in files:
        failures.extend(check_links(path))
        for lineno, src in extract_snippets(path):
            n_snippets += 1
            err = run_snippet(path, lineno, src)
            if err:
                failures.append(err)
            else:
                print(f"ok: {os.path.relpath(path, REPO)}:{lineno}")
    if failures:
        print(f"\nDOCS SMOKE FAILED ({len(failures)} problems):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"docs smoke passed: {n_snippets} snippets executed, "
          f"links clean across {len(files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
