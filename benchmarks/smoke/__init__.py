"""CI smoke runs, kept as real files so they are runnable (and testable)
locally: ``PYTHONPATH=src python benchmarks/smoke/<name>.py``."""
