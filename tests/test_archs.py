"""Per-architecture smoke tests: reduced configs, one train + decode step.

Each assigned arch instantiates its reduced-family config, runs one
forward/train step and one prefill->decode step on CPU, and asserts output
shapes + finiteness. The FULL configs are exercised only via the dry-run.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_zoo
from repro.training import data as data_lib
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import (TrainConfig, init_state,
                                       make_train_step)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            KEY, (B, cfg.num_patches, cfg.d_model), cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_loss(arch):
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(KEY, cfg)
    loss, metrics = jax.jit(lambda p, b: model_zoo.loss(cfg, p, b))(
        params, _batch(cfg))
    assert np.isfinite(float(loss)), (arch, loss)
    assert 1.0 < float(loss) < 20.0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke_config(arch)
    tcfg = TrainConfig(opt=OptimizerConfig(peak_lr=1e-3, warmup_steps=2,
                                           total_steps=10))
    state = init_state(KEY, cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg)
    l0 = None
    for i in range(3):
        state, metrics = step(state, batch)
        l = float(metrics["loss"])
        assert np.isfinite(l), (arch, i)
        l0 = l0 if l0 is not None else l
    assert l < l0, f"{arch}: loss should drop on a repeated batch"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(KEY, cfg)
    B, S = 2, 16
    extra = cfg.num_patches if cfg.frontend == "vision" else 0
    batch = {k: v for k, v in _batch(cfg, B, S).items() if k != "labels"}
    cache = model_zoo.init_cache(cfg, B, S + extra + 4)
    logits, cache = jax.jit(
        lambda p, b, c: model_zoo.prefill(cfg, p, b, c))(params, batch, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    t = jnp.full((B,), S + extra, jnp.int32)
    logits2, cache = jax.jit(
        lambda p, c, tk, tt: model_zoo.decode(cfg, p, c, tk, tt))(
        params, cache, tok, t)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b", "hymba-1.5b",
                                  "mixtral-8x7b"])
def test_prefill_decode_consistency(arch):
    """decode(t=S) after prefill(S) == prefill(S+1)'s last logits."""
    cfg = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = model_zoo.init(jax.random.fold_in(KEY, 1), cfg)
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    cache = model_zoo.init_cache(cfg, B, S + 8)
    _, cache = model_zoo.prefill(cfg, params, {"tokens": toks[:, :S]}, cache)
    lgA, _ = model_zoo.decode(cfg, params, cache, toks[:, S],
                              jnp.full((B,), S, jnp.int32))
    cacheB = model_zoo.init_cache(cfg, B, S + 8)
    lgB, _ = model_zoo.prefill(cfg, params, {"tokens": toks}, cacheB)
    a = np.asarray(lgA, np.float32)
    b = np.asarray(lgB, np.float32)
    rel = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
    assert rel < 2e-3, (arch, rel)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_matches_assignment(arch):
    """Pin the full configs to the assigned hyperparameters."""
    cfg = configs.get_config(arch)
    expected = {
        "qwen2.5-14b": dict(num_layers=48, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=13824, vocab_size=152064),
        "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128,
                            num_kv_heads=8, d_ff=53248, vocab_size=128256),
        "stablelm-1.6b": dict(num_layers=24, d_model=2048, num_heads=32,
                              num_kv_heads=32, d_ff=5632, vocab_size=100352),
        "nemotron-4-340b": dict(num_layers=96, d_model=18432, num_heads=96,
                                num_kv_heads=8, d_ff=73728, vocab_size=256000,
                                activation="relu2"),
        "hymba-1.5b": dict(num_layers=32, d_model=1600, num_heads=25,
                           num_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
        "musicgen-medium": dict(num_layers=48, d_model=1536, num_heads=24,
                                num_kv_heads=24, d_ff=6144, vocab_size=2048),
        "internvl2-1b": dict(num_layers=24, d_model=896, num_heads=14,
                             num_kv_heads=2, d_ff=4864, vocab_size=151655),
        "rwkv6-7b": dict(num_layers=32, d_model=4096, d_ff=14336,
                         vocab_size=65536),
        "qwen2-moe-a2.7b": dict(num_layers=24, d_model=2048, num_heads=16,
                                num_kv_heads=16, moe_d_ff=1408,
                                vocab_size=151936, num_experts=60, top_k=4),
        "mixtral-8x7b": dict(num_layers=32, d_model=4096, num_heads=32,
                             num_kv_heads=8, d_ff=14336, vocab_size=32000,
                             num_experts=8, top_k=2),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_qwen_bias_and_gqa():
    cfg = configs.get_config("qwen2.5-14b")
    assert cfg.qkv_bias is True
    table = model_zoo.param_table(cfg)
    assert "layers/attn/bq" in table


def test_moe_active_params_below_total():
    cfg = configs.get_config("qwen2-moe-a2.7b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()


def test_long_context_cells_only_for_subquadratic():
    assert not configs.cell_is_valid("qwen2.5-14b", "long_500k")
    assert not configs.cell_is_valid("llama3-405b", "long_500k")
    for a in ("rwkv6-7b", "hymba-1.5b", "mixtral-8x7b"):
        assert configs.cell_is_valid(a, "long_500k")
    assert len(configs.valid_cells()) == 33
