"""Unit + property tests for the paper's Eqs (1)-(4) controller."""

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:      # not installable here; deterministic shim
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import offload
from repro.core.offload import OffloadConfig, OffloadState


def _steady(cfg, lat, steps=50, F=1, W=32):
    state = OffloadState.init(F, cfg)
    windows = jnp.asarray(np.tile(lat, (F, 1)), jnp.float32)
    R = None
    for _ in range(steps):
        state, R = offload.offload_update(state, windows, cfg)
    return np.asarray(R)


# ---- Eq (1) -----------------------------------------------------------------

def test_latency_ratio_uniform_is_one():
    lat = jnp.full((3, 64), 0.25)
    r = offload.latency_ratio(lat)
    np.testing.assert_allclose(np.asarray(r), 1.0, rtol=1e-6)


def test_latency_ratio_matches_numpy_percentiles():
    rng = np.random.default_rng(1)
    lat = rng.lognormal(-2, 0.7, size=(4, 128)).astype(np.float32)
    r = np.asarray(offload.latency_ratio(jnp.asarray(lat)))
    want = np.percentile(lat, 95, axis=-1) / np.percentile(lat, 50, axis=-1)
    np.testing.assert_allclose(r, np.maximum(want, 1.0), rtol=1e-4)


def test_latency_ratio_masked():
    lat = np.full((1, 8), 1.0, np.float32)
    lat[0, :2] = 100.0                      # only the masked slots are heavy
    valid = np.ones((1, 8), bool)
    valid[0, :2] = False
    r = np.asarray(offload.latency_ratio(jnp.asarray(lat), jnp.asarray(valid)))
    np.testing.assert_allclose(r, 1.0, rtol=1e-5)


# ---- Eq (2) -----------------------------------------------------------------

def test_decay_weights_normalized_and_monotone():
    cfg = OffloadConfig(c_decay=0.7, c_t=12)
    w = np.asarray(cfg.decay_weights())
    assert w.shape == (13,)
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-6)
    assert np.all(np.diff(w) < 0)           # newest first

def test_eq2_matches_hand_rolled():
    cfg = OffloadConfig(c_decay=0.5, c_t=3, c_in=0.0, c_soft=0.0, c_hard=100.0)
    state = OffloadState.init(1, cfg)
    ratios = [2.0, 3.0, 5.0, 7.0, 11.0]
    for r in ratios:
        state = offload.push_ratio(state, jnp.asarray([r], jnp.float32))
    r_prime = np.asarray(offload._decayed_ratio(state, cfg))[0]
    w = np.array([0.5 ** k for k in range(4)])
    newest_first = np.array(ratios[::-1][:4])
    want = float((w * newest_first).sum() / w.sum())
    np.testing.assert_allclose(r_prime, want, rtol=1e-5)


# ---- Eq (3) -----------------------------------------------------------------

@pytest.mark.parametrize("rp,want", [
    (1.0, 0.0),          # below soft limit
    (1.25, 0.0),         # at soft limit
    (2.5, 100.0),        # at hard limit
    (3.0, 100.0),        # above hard
    (1.875, 50.0),       # midpoint
])
def test_eq3_piecewise(rp, want):
    cfg = OffloadConfig(c_soft=1.25, c_hard=2.5)
    got = float(offload.target_percentage(jnp.asarray([rp]), cfg)[0])
    np.testing.assert_allclose(got, want, atol=1e-4)


# ---- Eq (4) -----------------------------------------------------------------

def test_eq4_inertia_first_step():
    cfg = OffloadConfig(c_in=0.6, c_soft=1.0, c_hard=2.0, c_t=0)
    state = OffloadState.init(1, cfg)
    # one update with ratio 2.0 -> r_t = 100; R = 0*0.6 + 100*0.4 = 40
    lat = np.ones((1, 64), np.float32)
    lat[0, -5:] = 10.0                      # >5% heavy => p95/p50 >> hard
    state, R = offload.offload_update(state, jnp.asarray(lat), cfg)
    np.testing.assert_allclose(np.asarray(R), [40.0], atol=1.0)


def test_controller_engages_and_disengages():
    cfg = OffloadConfig()
    heavy = np.ones((1, 64), np.float32)
    heavy[0, -6:] = 50.0
    R_hot = _steady(cfg, heavy[0], steps=40)
    assert R_hot[0] > 95.0
    # now the edge drains: uniform latencies -> R decays toward 0
    state = OffloadState.init(1, cfg)
    for _ in range(40):
        state, _ = offload.offload_update(state, jnp.asarray(heavy), cfg)
    uniform = jnp.ones((1, 64), jnp.float32)
    for _ in range(60):
        state, R = offload.offload_update(state, uniform, cfg)
    assert float(R[0]) < 1.0


def test_vectorized_over_functions():
    cfg = OffloadConfig()
    lat = np.ones((3, 64), np.float32)
    lat[1, -6:] = 40.0                      # only fn 1 is tail-heavy
    state = OffloadState.init(3, cfg)
    for _ in range(30):
        state, R = offload.offload_update(state, jnp.asarray(lat), cfg)
    R = np.asarray(R)
    assert R[1] > 90 and R[0] < 1 and R[2] < 1


def test_scan_controller_matches_loop():
    cfg = OffloadConfig()
    rng = np.random.default_rng(3)
    trace = rng.lognormal(-2, 0.5, size=(20, 2, 32)).astype(np.float32)
    Rs = np.asarray(offload.scan_controller(cfg, jnp.asarray(trace)))
    state = OffloadState.init(2, cfg)
    for t in range(20):
        state, R = offload.offload_update(state, jnp.asarray(trace[t]), cfg)
        np.testing.assert_allclose(Rs[t], np.asarray(R), rtol=1e-5)


def test_controller_jit_and_grad_safe():
    cfg = OffloadConfig()
    state = OffloadState.init(2, cfg)
    lat = jnp.ones((2, 16))
    f = jax.jit(lambda s, l: offload.offload_update(s, l, cfg))
    state, R = f(state, lat)
    assert R.shape == (2,)


# ---- properties -------------------------------------------------------------

@hypothesis.given(
    st.lists(st.floats(0.001, 10.0), min_size=8, max_size=64),
    st.floats(0.1, 0.99), st.integers(1, 16))
@hypothesis.settings(max_examples=40, deadline=None)
def test_R_always_in_range(lats, c_decay, c_t):
    cfg = OffloadConfig(c_decay=c_decay, c_t=c_t)
    lat = np.asarray(lats, np.float32)[None]
    state = OffloadState.init(1, cfg)
    for _ in range(10):
        state, R = offload.offload_update(state, jnp.asarray(lat), cfg)
        assert 0.0 <= float(R[0]) <= 100.0
        assert np.isfinite(float(R[0]))


@hypothesis.given(st.floats(1.0, 5.0), st.floats(0.0, 0.95))
@hypothesis.settings(max_examples=30, deadline=None)
def test_R_monotone_in_ratio(scale, c_in):
    """A strictly heavier tail never lowers the steady-state percentage."""
    cfg = OffloadConfig(c_in=c_in)
    base = np.ones(64, np.float32)
    tail_a = base.copy(); tail_a[-6:] = 1.0 + scale
    tail_b = base.copy(); tail_b[-6:] = 1.0 + scale * 2
    Ra = _steady(cfg, tail_a, steps=30)[0]
    Rb = _steady(cfg, tail_b, steps=30)[0]
    assert Rb >= Ra - 1e-4


def test_net_aware_caps_by_link():
    # demand 100 rps x 1 MB = 100 MB/s; link 50 MB/s -> cap 50%
    cfg = OffloadConfig(net_aware=True, link_bytes_per_s=50e6, req_bytes=1e6,
                        demand_rps=100.0)
    heavy = np.ones(64, np.float32); heavy[-8:] = 100.0
    R = _steady(cfg, heavy, steps=50)[0]
    assert R <= 50.0 + 1e-3
    # paper-faithful config saturates to ~100 on the same trace
    R0 = _steady(OffloadConfig(), heavy, steps=50)[0]
    assert R0 > 95.0
