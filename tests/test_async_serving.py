"""The continuous-batching async serving loop + hedge-loser cancellation.

Covers the PR-4 tentpole: persistent in-flight slots with
admit -> decode step -> retire/cancel scheduling (short requests no
longer wait on long co-resident ones), hedge pairs that cancel the
losing twin the step its sibling completes (slot reusable the same
step, no latency sample for the loser, pair-level accounting
``hedges_fired == hedges_won + hedges_cancelled + open``), requeued
leftovers keeping their original submit/tick stamps (monotone backlog
age), and cross-tick slot residency under ``max_steps_per_tick``.
"""

import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.policy import StaticSplit
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.models import model_zoo
from repro.platform import Continuum, Request
from repro.serving.tiers import Tier, TierConfig, _Queued


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(rid, max_new=1, length=6):
    return Request(rid=rid, tokens=np.arange(length, dtype=np.int32),
                   max_new=max_new)


def _queued(rid, max_new=1, t_submit=0.0):
    return _Queued("fn", _req(rid, max_new), t_submit=t_submit)


class _AlwaysHedge(StaticSplit):
    """Keep all primaries at the ingress tier, hedge every queued item."""

    def __init__(self):
        super().__init__(0.0)

    def hedge(self, key, ages_s, fn_ids, latencies, valid):
        return np.ones(len(fn_ids), bool)


# ---- Tier-level continuous loop ---------------------------------------------

def test_tier_admit_step_retire(model):
    cfg, params = model
    tier = Tier("t", TierConfig(slots=4, max_len=64))
    tier.deploy("fn", cfg, params, AutoscalingPolicy())
    short, long = _queued(0, max_new=2), _queued(1, max_new=5)
    in_flight, finished = tier.admit("fn", [short, long])
    assert len(in_flight) == 2 and not finished
    assert tier.inflight_count("fn") == 2
    assert tier.endpoints["fn"].active == 2
    done = tier.step("fn")                      # both got their 2nd token
    assert [r.item.req.rid for r in done] == [0]
    assert tier.inflight_count("fn") == 1       # short retired mid-stream
    assert tier.endpoints["fn"].active == 1     # ... and freed its slot
    lat = tier.finish("fn", done[0])
    assert lat > 0.0 and short.req.output.shape == (2,)
    for _ in range(3):
        done = tier.step("fn")
    assert [r.item.req.rid for r in done] == [1]
    tier.finish("fn", done[0])
    assert long.req.output.shape == (5,) and tier.inflight_count("fn") == 0


def test_tier_cancel_frees_slot_same_step(model):
    """The hedge-cancellation primitive: an evicted in-flight request
    frees its slot immediately — a new admission claims the SAME slot
    within the same scheduler step, before any further decode."""
    cfg, params = model
    tier = Tier("t", TierConfig(slots=2, max_len=64))
    tier.deploy("fn", cfg, params, AutoscalingPolicy())
    a, b = _queued(0, max_new=8), _queued(1, max_new=8)
    tier.admit("fn", [a, b])
    assert tier.free_slots("fn") == 0
    loser_slot = next(iter(tier.inflight["fn"]))
    rec = tier.cancel("fn", loser_slot)
    assert rec.item.req.rid in (0, 1)
    assert tier.free_slots("fn") == 1           # freed immediately
    in_flight, _ = tier.admit("fn", [_queued(2, max_new=3)])
    assert in_flight[0].slot == loser_slot      # same slot, same step
    done = tier.step("fn")                      # survivors keep decoding
    assert not done and tier.inflight_count("fn") == 2


def test_cancelled_slot_does_not_corrupt_neighbors(model):
    """Eviction mid-stream (masked decode rows) must not perturb the
    surviving co-resident stream: tokens match a solo run."""
    cfg, params = model
    tier = Tier("t", TierConfig(slots=2, max_len=64))
    tier.deploy("fn", cfg, params, AutoscalingPolicy())

    def run(with_neighbor):
        keep = _queued(0, max_new=6)
        items = [keep] + ([_queued(1, max_new=6)] if with_neighbor else [])
        tier.admit("fn", items)
        if with_neighbor:
            other = next(s for s, r in tier.inflight["fn"].items()
                         if r.item.req.rid == 1)
        done = []
        for step in range(6):
            if with_neighbor and step == 2:
                tier.cancel("fn", other)        # evict mid-decode
            done += tier.step("fn")
        [rec] = done
        tier.finish("fn", rec)
        return list(keep.req.output)

    assert run(True) == run(False)


# ---- continuum-level: mixed lengths, hedge cancellation ---------------------

def _two_tier(model, policy, **kw):
    cfg, params = model
    cc = Continuum(edge=TierConfig(slots=2, max_len=64),
                   cloud=TierConfig(slots=4, max_len=64),
                   policy=policy, seed=0, **kw)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    return cc


def test_short_requests_overtake_long_in_flight(model):
    """The tentpole behaviour: with a backlog of mixed lengths, a short
    request admitted into a freed slot completes while a long co-resident
    one is still decoding — it no longer waits for the wave to end."""
    cc = _two_tier(model, policy=0.0)           # everything at the edge
    long = _req(0, max_new=16)
    shorts = [_req(1 + i, max_new=2) for i in range(4)]
    cc.submit("fn", long)
    for r in shorts:
        cc.submit("fn", r)
    rec = cc.tick()
    assert rec["edge"] == 5 and rec["inflight"] == 0
    # every short request finished before the long one, although the
    # 2-slot tier was full from step one
    assert all(r.t_done < long.t_done for r in shorts)
    # and the whole tick took ~max(need) shared decode steps, not a
    # wave-serial sum (16 + 2 + 2 + ...)
    assert rec["steps"] <= 16
    assert rec["waves"] >= 2                    # admissions happened mid-run


def test_hedge_loser_evicted_when_sibling_completes(model):
    """A hedged request whose primary finishes first has its slot-resident
    twin cancelled the same step: `hedges_cancelled` increments, the
    loser records no latency sample, and the tick ends without running
    the twin to completion."""
    cfg, params = model
    # cloud slot is busy with a long request until step 6, so the twin is
    # admitted late and is mid-decode when the primary (8 steps) retires.
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=1, max_len=64)),
        links=(LinkSpec(rtt_s=0.0),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=_AlwaysHedge(), seed=0)
    cc.deploy(FunctionSpec(name="blk", arch="stablelm-1.6b"), cfg, params)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    # occupy the cloud with a non-hedged long request (pushed straight to
    # the cloud gateway, past the 0%-split ingress routing)
    blocker = _Queued("blk", _req(9, max_new=6), t_submit=time.perf_counter())
    cc.gateways[1].push(blocker, force=True)
    hedged = _req(1, max_new=8)
    assert cc.submit("fn", hedged)
    rec = cc.tick()
    assert rec["hedged"] == 1
    assert cc.metrics.counters["hedges_fired"] == 1
    assert cc.metrics.counters["hedges_cancelled"] == 1
    assert cc.metrics.counters.get("hedges_won", 0) == 0
    assert cc.hedges_open == 0
    # primary finished after 7 decode steps; the twin (admitted when the
    # blocker retired at step 5) was NOT run to completion (that would
    # have taken until step 12)
    assert 7 <= rec["steps"] < 12
    assert rec["inflight"] == 0                 # loser's slot freed
    assert cc.tiers[1].endpoints["fn"].active == 0
    assert hedged.output is not None and hedged.output.shape == (8,)
    # winner-only accounting: edge has exactly one "fn" sample, the
    # cancelled twin recorded nothing on the cloud
    assert len(cc.tiers[0].metrics.latency_values("fn")) == 1
    assert len(cc.tiers[1].metrics.latency_values("fn")) == 0
    assert len(cc.tiers[1].metrics.latency_values("blk")) == 1


def test_hedge_accounting_identity(model):
    """hedges_fired == hedges_won + hedges_cancelled + hedges_open after
    every tick, and winner-only latency: one sample per request."""
    cc = _two_tier(model, policy=_AlwaysHedge())
    rid = 0
    for tick in range(4):
        for _ in range(3):
            cc.submit("fn", _req(rid, max_new=1 + rid % 3))
            rid += 1
        cc.tick()
        c = cc.metrics.counters
        assert c["hedges_fired"] == (c["hedges_won"]
                                     + c["hedges_cancelled"]
                                     + cc.hedges_open)
        assert cc.hedges_open == 0              # default: ticks run dry
    samples = sum(len(t.metrics.latency_values("fn")) for t in cc.tiers)
    assert samples == rid                       # exactly one arm recorded
    served = sum(sum(r["tiers"].values()) for r in cc.log)
    assert served == rid                        # ... and served once


def test_hedge_race_survives_tick_boundary(model):
    """With max_steps_per_tick the twin can stay slot-resident across the
    tick boundary while the primary requeues; the race settles next tick
    and the request is served exactly once."""
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64,
                        autoscaling=AutoscalingPolicy(min_scale=0,
                                                      max_scale=0)),
               TierSpec("cloud", slots=4, max_len=64)),
        links=(LinkSpec(rtt_s=0.0),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=_AlwaysHedge(), seed=0,
                                 max_steps_per_tick=2)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    req = _req(1, max_new=6)
    assert cc.submit("fn", req)
    rec = cc.tick()
    # the twin is mid-decode on the cloud; the primary waits at the
    # zero-capacity edge with its pair link intact
    assert rec["inflight"] == 1 and cc.hedges_open == 1
    ticks = 1 + cc.drain()
    assert cc.hedges_open == 0
    assert cc.metrics.counters["hedges_won"] == 1
    assert req.output is not None and req.output.shape == (6,)
    served = sum(sum(r["tiers"].values()) for r in cc.log)
    assert served == 1 and ticks >= 2


def test_max_steps_keeps_requests_in_flight_across_ticks(model):
    cc = _two_tier(model, policy=0.0, max_steps_per_tick=3)
    long = _req(0, max_new=12)
    cc.submit("fn", long)
    rec = cc.tick()
    assert rec["inflight"] == 1 and rec["steps"] == 3
    assert long.output is None
    # a short request submitted mid-flight is admitted into a free slot
    # next tick while the long one keeps decoding
    short = _req(1, max_new=2)
    cc.submit("fn", short)
    rec2 = cc.tick()
    assert short.output is not None and long.output is None
    assert rec2["inflight"] == 1
    cc.drain()
    assert long.output is not None and long.output.shape == (12,)
    served = sum(r["edge"] + r["cloud"] for r in cc.log)
    assert served == 2


def test_paced_tick_still_admits_alongside_inflight(model):
    """Regression: with max_steps_per_tick=1 every tick must still run
    its admission pass — a free slot may not sit idle (fresh arrivals
    starving behind a long slot-resident request) just because the step
    budget was spent decoding."""
    cc = _two_tier(model, policy=0.0, max_steps_per_tick=1)
    long = _req(0, max_new=12)
    cc.submit("fn", long)
    cc.tick()                                   # long is slot-resident
    short = _req(1, max_new=2)
    cc.submit("fn", short)
    rec = cc.tick()                             # 1 decode step + admission
    assert rec["waves"] == 1                    # the short was admitted...
    assert rec["inflight"] == 2                 # ...into the free slot
    rec2 = cc.tick()
    assert short.output is not None             # and finished next step
    assert long.output is None and rec2["inflight"] == 1
    cc.drain()
    assert long.output is not None


# ---- satellite: requeue keeps tick bookkeeping ------------------------------

def test_requeue_preserves_submit_and_tick_stamps(model):
    """Wave-budget leftovers go back to their gateway with their ORIGINAL
    t_submit and tick stamp, so the backlog age each scrape reads grows
    monotonically instead of resetting on every requeue."""
    cfg, params = model
    cc = Continuum(edge=TierConfig(slots=2, max_len=64),
                   cloud=TierConfig(slots=4, max_len=64),
                   policy=0.0, seed=0, max_waves_per_tick=1)
    cc.deploy(FunctionSpec(
        name="fn", arch="stablelm-1.6b",
        autoscaling=AutoscalingPolicy(min_scale=1, max_scale=1,
                                      target_concurrency=1.0)), cfg, params)
    for i in range(4):
        assert cc.submit("fn", _req(i))
    stamps = {it.req.rid: (it.t_submit, it.tick_no)
              for it in cc.gateways[0].items}
    cc.tick()                                   # serves 1, requeues 3
    leftovers = list(cc.gateways[0].items)
    assert len(leftovers) == 3
    for it in leftovers:
        assert (it.t_submit, it.tick_no) == stamps[it.req.rid]
    ages1 = cc.gateways[0].backlog_ages(
        time.perf_counter(), cc._tick_no, cc._fn_ids, 1)
    assert len(ages1[0]) == 3                   # all leftovers are backlog
    cc.tick()                                   # serves 1 more
    ages2 = cc.gateways[0].backlog_ages(
        time.perf_counter(), cc._tick_no, cc._fn_ids, 1)
    # the same requests, older now: monotone backlog age
    assert len(ages2[0]) == 2
    assert min(ages2[0]) > min(ages1[0]) > 0.0


def test_requeued_items_survive_to_completion(model):
    cc = _two_tier(model, policy=0.0, max_waves_per_tick=1)
    reqs = [_req(i, max_new=2) for i in range(5)]
    for r in reqs:
        assert cc.submit("fn", r)
    for _ in range(8):
        if cc.queued == 0 and cc.in_flight == 0:
            break
        cc.tick()
    assert all(r.output is not None for r in reqs)
    assert sum(r["edge"] + r["cloud"] for r in cc.log) == 5
