"""The N-tier Topology layer: validation, 2-tier backward equivalence
(bit-identical R_t trajectories vs the pre-topology simulator), 3-tier
waterfall spill, N-tier routing, scale-to-zero on an intermediate tier,
and the hedge winner-only latency accounting."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.policy import ControlLoop, StaticSplit
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.models import model_zoo
from repro.platform import Continuum, Request
from repro.serving.tiers import TierConfig


# ---- validation -------------------------------------------------------------

def test_empty_chain_rejected():
    with pytest.raises(ValueError):
        Topology(tiers=())


def test_duplicate_tier_names_rejected():
    with pytest.raises(ValueError):
        Topology(tiers=(TierSpec("edge"), TierSpec("edge")),
                 links=(LinkSpec(),))


def test_negative_rtt_rejected():
    with pytest.raises(ValueError):
        Topology(tiers=(TierSpec("a"), TierSpec("b")),
                 links=(LinkSpec(rtt_s=-0.1),))


def test_link_count_must_match_tiers():
    with pytest.raises(ValueError):
        Topology(tiers=(TierSpec("a"), TierSpec("b"), TierSpec("c")),
                 links=(LinkSpec(),))
    with pytest.raises(ValueError):
        Topology(tiers=(TierSpec("a"),), links=(LinkSpec(),))


def test_bad_tier_fields_rejected():
    with pytest.raises(ValueError):
        Topology(tiers=(TierSpec("a", slots=-1),), links=())
    with pytest.raises(ValueError):
        Topology(tiers=(TierSpec("a", service_rate_mult=0.0),), links=())
    with pytest.raises(ValueError):
        Topology(tiers=(TierSpec("a"), TierSpec("b")),
                 links=(LinkSpec(bandwidth_Bps=0.0),))


def test_pair_accepts_legacy_tierconfig():
    topo = Topology.pair(TierConfig(slots=2, max_len=64),
                         TierConfig(slots=8, max_len=64,
                                    extra_latency_s=0.02))
    assert topo.names == ("edge", "cloud")
    assert topo.num_tiers == 2 and len(topo.links) == 1
    assert not topo.waterfall                   # seed overflow semantics
    assert topo.tiers[1].extra_latency_s == 0.02


# ---- 2-tier equivalence (the hard backward-compat requirement) --------------

# Golden values captured from the pre-topology simulator (main @ PR 1) on
# this exact config: same seed => bit-identical R_t trajectory and counts.
_GOLD_CFG = SimConfig(duration_s=80.0, low_rps=2.0, high_rps=14.0,
                      ramp_start_s=10.0, ramp_end_s=40.0, seed=0)
_GOLD_OFFLOAD_PCT = [
    0.0, 0.0, 0.0, 7.05392599105835, 33.94593048095703, 37.99419403076172,
    28.355018615722656, 33.66240310668945, 41.873504638671875,
    77.65264129638672, 31.752613067626953, 17.118032455444336,
    51.82924270629883, 37.41172409057617, 38.023170471191406, 64.0546875]
_GOLD_LATENCY_AVG = [
    0.8540354344117875, 0.8295079222443701, 1.0512368935625547,
    0.9427969076519057, 1.8111349047069167, 1.7694696187362278,
    1.082751138693534, 2.035602932737588, 3.1460666843773413,
    2.3318833584817575, 2.9028673881956353, 4.815157782534941,
    3.6328268616537964, 3.220968636883958, 3.840618680877827,
    3.0710358057480382]


def test_two_tier_sim_bit_identical_to_main():
    r = ContinuumSimulator("matmult", "auto", _GOLD_CFG).run()
    assert r.successes == 628 and r.failures == 163
    np.testing.assert_array_equal(r.offload_pct,
                                  np.asarray(_GOLD_OFFLOAD_PCT))
    np.testing.assert_array_equal(r.latency_avg,
                                  np.asarray(_GOLD_LATENCY_AVG))


def test_two_tier_static_counts_match_main():
    r = ContinuumSimulator("matmult", 50.0, _GOLD_CFG).run()
    assert r.successes == 699 and r.failures == 123
    np.testing.assert_array_equal(r.offload_pct, np.full(16, 50.0))


def test_explicit_topology_matches_default_two_tier():
    """Passing the sugar-built Topology explicitly is the same run."""
    a = ContinuumSimulator("io", "auto", _GOLD_CFG).run()
    b = ContinuumSimulator("io", "auto", _GOLD_CFG,
                           topology=_GOLD_CFG.default_topology()).run()
    assert a.successes == b.successes and a.failures == b.failures
    np.testing.assert_array_equal(a.offload_pct, b.offload_pct)
    np.testing.assert_array_equal(a.latency_avg, b.latency_avg)
    assert a.tier_counts == b.tier_counts


# ---- tier distributions and N-tier routing ----------------------------------

def test_tier_distribution_two_tier_is_R_split():
    pol = StaticSplit(30.0)
    d = pol.tier_distribution(np.asarray([[30.0, 30.0]], np.float32), 2)
    np.testing.assert_allclose(d, [[70.0, 30.0], [70.0, 30.0]])


def test_tier_distribution_waterfall_composes():
    pol = StaticSplit(50.0)
    R_all = np.asarray([[50.0], [50.0]], np.float32)     # 2 boundaries, F=1
    d = pol.tier_distribution(R_all, 3)
    np.testing.assert_allclose(d, [[50.0, 25.0, 25.0]])
    np.testing.assert_allclose(d.sum(axis=1), 100.0)


def test_route_tiers_extremes():
    loop = ControlLoop(StaticSplit(0.0), 2, num_tiers=3)
    fn_ids = np.asarray([0, 1, 0, 1, 0], np.int32)
    key = jax.random.PRNGKey(0)
    # fn 0 -> everything to the deepest tier, fn 1 -> everything ingress
    loop.R_all = np.asarray([[100.0, 0.0], [100.0, 0.0]], np.float32)
    tiers = loop.route_tiers(key, fn_ids)
    assert tiers.shape == (5,)
    assert (tiers[fn_ids == 0] == 2).all()
    assert (tiers[fn_ids == 1] == 0).all()


def test_route_tiers_expectation_matched():
    loop = ControlLoop(StaticSplit(50.0), 1, num_tiers=3)
    fn_ids = np.zeros(400, np.int32)
    counts = np.zeros(3)
    for t in range(20):
        tiers = loop.route_tiers(jax.random.PRNGKey(t), fn_ids)
        counts += np.bincount(tiers, minlength=3)
    frac = counts / counts.sum()
    np.testing.assert_allclose(frac, [0.5, 0.25, 0.25], atol=0.02)


def test_control_loop_step_tiers_shapes():
    loop = ControlLoop("auto", 2, window=16, num_tiers=4)
    assert loop.num_boundaries == 3
    lat = [np.full((2, 16), 0.1, np.float32)] * 3
    valid = [np.ones((2, 16), bool)] * 3
    R_all = loop.step_tiers(lat, valid, arrivals=[1.0, 1.0])
    assert R_all.shape == (3, 2)
    assert loop.dist().shape == (2, 4)
    np.testing.assert_allclose(loop.dist().sum(axis=1), 100.0, rtol=1e-5)


def test_single_tier_topology_simulates():
    """A 1-tier chain is valid: nothing routes off-tier, nothing crashes
    (ControlLoop keeps a phantom boundary whose R_t routing must not see)."""
    topo = Topology(tiers=(TierSpec("solo", slots=4),), links=())
    cfg = SimConfig(duration_s=30.0, seed=0)
    r = ContinuumSimulator("io", 50.0, cfg, topology=topo).run()
    assert r.tier_counts == {"solo": r.successes}
    assert r.successes > 0
    np.testing.assert_array_equal(r.offload_pct, 0.0)


def test_length_padding_restricted_to_dense():
    """MoE expert capacity is sequence-global, so only the dense family
    may right-pad prompts to a pow2 length bucket."""
    from repro.serving.engine import Endpoint
    from repro.models import model_zoo as mz
    for arch, padded in (("stablelm-1.6b", True), ("mixtral-8x7b", False),
                         ("rwkv6-7b", False)):
        cfg = configs.get_smoke_config(arch)
        params = mz.init(jax.random.PRNGKey(0), cfg)
        ep = Endpoint(cfg, params, slots=2, max_len=32)
        assert ep._pad_len == padded, arch


# ---- 3-tier simulator: waterfall spill --------------------------------------

_SIM3 = SimConfig(duration_s=90.0, low_rps=2.0, high_rps=12.0,
                  ramp_start_s=10.0, ramp_end_s=40.0, seed=0)


def test_three_tier_sim_runs_and_counts_tiers():
    topo = Topology.device_edge_cloud(device_slots=2, edge_slots=4,
                                      cloud_slots=64)
    r = ContinuumSimulator("matmult", "auto", _SIM3, topology=topo).run()
    assert set(r.tier_counts) == {"device", "edge", "cloud"}
    assert r.successes > 0
    assert sum(r.tier_counts.values()) == r.successes
    # overload pushes load past the 2-slot device tier
    assert r.tier_counts["edge"] + r.tier_counts["cloud"] > 0


def test_three_tier_waterfall_spills_past_dead_tier():
    """An intermediate tier scaled to zero (slots=0) spills everything
    routed at it down the chain instead of rejecting."""
    topo = Topology(
        tiers=(TierSpec("device", slots=2, queue_depth_per_slot=2),
               TierSpec("edge", slots=0, queue_depth_per_slot=0),
               TierSpec("cloud", slots=64, queue_depth_per_slot=None)),
        links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=50e6),
               LinkSpec(rtt_s=0.04, bandwidth_Bps=100e6)),
        waterfall=True)
    r = ContinuumSimulator("io", 50.0, _SIM3, topology=topo).run()
    assert r.tier_counts["edge"] == 0
    assert r.spilled > 0
    assert r.tier_counts["cloud"] > 0
    assert r.successes > 0


def test_waterfall_off_rejects_instead_of_spilling():
    topo = Topology(
        tiers=(TierSpec("device", slots=1, queue_depth_per_slot=0),
               TierSpec("cloud", slots=64, queue_depth_per_slot=None)),
        links=(LinkSpec(),), waterfall=False)
    r = ContinuumSimulator("io", 0.0, _SIM3, topology=topo).run()
    assert r.spilled == 0
    assert r.failures > 0                      # overflow 503s


# ---- live runtime over 3 tiers ----------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def live3(model):
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("device", slots=1, max_len=64),
               TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(rtt_s=0.005), LinkSpec(rtt_s=0.04)))
    cc = Continuum.from_topology(topo, policy="auto", seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    return cc


def test_live_three_tier_serves_everything(live3):
    rng = np.random.default_rng(0)
    rid = 0
    for rnd in range(8):
        for _ in range(2 if rnd < 3 else 8):
            live3.submit("fn", Request(
                rid=rid, tokens=rng.integers(0, 128, 6).astype(np.int32),
                max_new=2))
            rid += 1
        rec = live3.tick()
        assert set(rec["tiers"]) == {"device", "edge", "cloud"}
    served = sum(sum(r["tiers"].values()) for r in live3.log)
    assert served == rid                       # nothing dropped
    # the 1-slot device tier cannot absorb the ramp alone
    deeper = sum(r["tiers"]["edge"] + r["tiers"]["cloud"]
                 for r in live3.log)
    assert deeper > 0


def test_live_backward_compat_aliases(live3):
    assert live3.edge is live3.tiers[0]
    assert live3.cloud is live3.tiers[-1]


def test_live_intermediate_scale_to_zero_spills(model):
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("device", slots=2, max_len=64),
               TierSpec("edge", slots=2, max_len=64,
                        autoscaling=AutoscalingPolicy(min_scale=0,
                                                      max_scale=0)),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(), LinkSpec()))
    cc = Continuum.from_topology(topo, policy=50.0, seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    for i in range(8):
        cc.submit("fn", Request(rid=i, tokens=np.arange(6, dtype=np.int32),
                                max_new=1))
    rec = cc.tick()
    assert rec["tiers"]["edge"] == 0           # pinned to zero
    assert rec["spilled"] > 0                  # pending spilled down-chain
    assert sum(rec["tiers"].values()) == 8     # nothing dropped


# ---- hedge accounting (winner-only latency) ---------------------------------

def test_hedge_records_winner_only(model):
    cfg, params = model
    from repro.serving.tiers import TierConfig as TC
    cc = Continuum(edge=TC(slots=2, max_len=64),
                   cloud=TC(slots=8, max_len=64),
                   policy="auto+hedge", seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    # prime the latency windows so the p99 estimate exists
    for i in range(4):
        cc.submit("fn", Request(rid=i, tokens=np.arange(6, dtype=np.int32),
                                max_new=1))
    cc.tick()

    def window_count():
        n = 0
        for tier in cc.tiers:
            _, valid = tier.metrics.latency_windows(256)
            n += int(valid.sum())
        return n

    before = window_count()
    # submit, then age the queue entries far past any p99 so hedges fire
    for i in range(3):
        cc.submit("fn", Request(rid=100 + i,
                                tokens=np.arange(6, dtype=np.int32),
                                max_new=1))
    for item in cc.queue:
        item.t_submit -= 60.0
    rec = cc.tick()
    assert rec["hedged"] == 3                  # every aged request hedged
    assert cc.metrics.counters["hedges_fired"] == 3
    assert 0 <= cc.metrics.counters.get("hedges_won", 0) <= 3
    # winner-only accounting: 3 primaries -> exactly 3 new window entries,
    # even though 6 arms were served (the losers' latencies are dropped)
    assert window_count() - before == 3
