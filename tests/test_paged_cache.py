"""Paged KV-cache subsystem: allocator + prefix-registry units, the
paged Pallas decode kernel vs the dense kernel on the gathered view,
endpoint admission-in-pages, page-granular migration payloads, and the
simulator's matching page ledger.

The correctness contract under test everywhere: paged mode changes how
memory is *held*, never what the model computes — token streams, kernel
outputs, and migration payload contents are bit-identical to dense.
"""

import functools

import jax
import numpy as np
import pytest

from repro import configs
from repro.cache import (PagePool, PrefixRegistry, pages_for_tokens,
                         pages_needed)
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.kernels import decode_attention as dec_mod
from repro.models import model_zoo
from repro.serving.engine import Endpoint


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


# --------------------------------------------------------------------------
# arithmetic: the one shared sizing formula
# --------------------------------------------------------------------------


def test_pages_needed_formula():
    # extent = prompt + max_new - 1; last generated token is never written
    assert pages_needed(1, 1, 16, 64) == 1
    assert pages_needed(16, 1, 16, 64) == 1          # extent 16 -> 1 page
    assert pages_needed(16, 2, 16, 64) == 2          # extent 17 -> 2 pages
    assert pages_needed(0, 1, 16, 64) == 1           # never zero pages
    assert pages_needed(33, 15, 16, 64) == 3         # extent 47
    # wrap: extent past max_len touches every page of the rolling row
    assert pages_needed(60, 8, 16, 64) == 4
    assert pages_needed(64, 1, 16, 64) == 4
    # max_new <= 0 is treated as 1 (a claim always decodes at least once)
    assert pages_needed(5, 0, 16, 64) == 1
    with pytest.raises(ValueError):
        pages_needed(5, 1, 0, 64)


def test_pages_for_tokens():
    assert pages_for_tokens(0, 16) == 0
    assert pages_for_tokens(1, 16) == 1
    assert pages_for_tokens(16, 16) == 1
    assert pages_for_tokens(17, 16) == 2
    assert pages_for_tokens(-3, 16) == 0


# --------------------------------------------------------------------------
# PagePool: refcounted free-list allocator
# --------------------------------------------------------------------------


def test_page_pool_alloc_release_refcounts():
    pool = PagePool(8, 16)
    ids = pool.alloc(3)
    assert sorted(set(ids)) == sorted(ids) and len(ids) == 3
    assert pool.free_pages == 5 and pool.used_pages == 3
    assert all(pool.refcount(p) == 1 for p in ids)
    assert not any(pool.is_shared(p) for p in ids)
    # all-or-nothing: an oversized request allocates nothing
    assert pool.alloc(6) is None
    assert pool.free_pages == 5
    # sharing: retain bumps, release drops; page frees on last reference
    pool.retain(ids[:1])
    assert pool.is_shared(ids[0])
    pool.release(ids[:1])
    assert pool.refcount(ids[0]) == 1 and pool.free_pages == 5
    pool.release(ids)
    assert pool.free_pages == 8 and pool.check_balanced()
    # LIFO reuse keeps the working set compact
    assert pool.alloc(1) == [ids[-1]]
    pool.release([ids[-1]])


def test_page_pool_guards():
    pool = PagePool(4, 16)
    with pytest.raises(ValueError):
        pool.retain([2])                 # never allocated
    ids = pool.alloc(2)
    pool.release(ids)
    with pytest.raises(ValueError):
        pool.release(ids[:1])            # double free
    with pytest.raises(ValueError):
        pool.alloc(-1)
    with pytest.raises(ValueError):
        PagePool(0, 16)
    assert pool.check_balanced()


# --------------------------------------------------------------------------
# PrefixRegistry: LRU-bounded pinned prefixes
# --------------------------------------------------------------------------


def test_prefix_registry_lru_and_refcounts():
    pool = PagePool(8, 16)
    reg = PrefixRegistry(pool, capacity=2)
    pa, pb, pc = pool.alloc(2), pool.alloc(2), pool.alloc(2)
    ta = np.arange(3, dtype=np.int32)
    tb = np.arange(4, dtype=np.int32)
    tc = np.arange(5, dtype=np.int32)

    reg.register(ta, pa, first_token=7)
    reg.register(tb, pb, first_token=8)
    assert all(pool.refcount(p) == 2 for p in pa + pb)   # registry pins
    # the owning rows release; registry alone keeps the pages resident
    pool.release(pa)
    pool.release(pb)
    pool.release(pc)
    # pc freed + the 2 never-allocated pages; pa/pb stay pinned
    assert pool.free_pages == 4

    hit = reg.lookup(ta)                                 # refreshes LRU
    assert hit is not None and hit.first_token == 7 and hit.length == 3
    assert reg.lookup(np.arange(9, dtype=np.int32)) is None
    assert reg.hits == 1 and reg.misses == 1

    pd = pool.alloc(2)
    reg.register(tc, pd, first_token=9)
    pool.release(pd)
    # capacity 2: B (now LRU, A was refreshed) evicted, its pages freed
    assert len(reg) == 2
    assert reg.lookup(tb) is None
    assert reg.lookup(ta) is not None and reg.lookup(tc) is not None
    # re-registering a known prompt is a no-op (no double pin)
    reg.register(ta, pa, first_token=7)
    assert all(pool.refcount(p) == 1 for p in pa)

    assert reg.evict_lru() and reg.evict_lru() and not reg.evict_lru()
    assert pool.free_pages == 8 and pool.check_balanced()


def test_prefix_registry_zero_capacity():
    pool = PagePool(4, 16)
    reg = PrefixRegistry(pool, capacity=0)
    ids = pool.alloc(1)
    assert reg.register(np.arange(2, dtype=np.int32), ids, 1) is None
    assert len(reg) == 0 and pool.refcount(ids[0]) == 1
    pool.release(ids)
    assert pool.check_balanced()


# --------------------------------------------------------------------------
# paged Pallas kernel == dense kernel on the gathered view (bitwise)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("window,softcap",
                         [(None, None), (13, None), (None, 20.0), (13, 5.0)])
def test_paged_decode_kernel_bitwise(window, softcap):
    rng = np.random.default_rng(0)
    page, ppr, Hkv, G, D = 16, 4, 2, 2, 64
    B, P = 3, 9                                  # 9 used pages + 1 null
    lengths = [5, 64, 37]

    k_pool = rng.standard_normal((P + 1, page, Hkv, D)).astype(np.float32)
    v_pool = rng.standard_normal((P + 1, page, Hkv, D)).astype(np.float32)
    kv_pos_pages = np.full((P + 1, page), -1, np.int32)
    tables = np.full((B, ppr), P, np.int32)      # short rows pad with null
    nxt = iter(range(P))
    for b, L in enumerate(lengths):
        for i in range(pages_for_tokens(L, page)):
            pid = next(nxt)
            tables[b, i] = pid
            lo = i * page
            n = min(L - lo, page)
            kv_pos_pages[pid, :n] = np.arange(lo, lo + n)

    q = rng.standard_normal((B, G * Hkv, D)).astype(np.float32)
    q_pos = np.asarray(lengths, np.int32)
    out_paged = dec_mod.paged_decode_attention(
        q, k_pool, v_pool, tables, q_pos, kv_pos_pages,
        window=window, softcap=softcap, interpret=True)
    # the contiguous view the page tables describe
    k_dense = k_pool[tables].reshape(B, ppr * page, Hkv, D)
    v_dense = v_pool[tables].reshape(B, ppr * page, Hkv, D)
    kv_pos = kv_pos_pages[tables].reshape(B, ppr * page)
    out_dense = dec_mod.decode_attention(
        q, k_dense, v_dense, q_pos, kv_pos,
        window=window, softcap=softcap, blk_k=page, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_paged),
                                  np.asarray(out_dense))


# --------------------------------------------------------------------------
# Endpoint: admission is bounded by pages, not slots alone
# --------------------------------------------------------------------------


def test_endpoint_admission_in_pages():
    cfg, params = _model()
    # pool of exactly one row: 4 pages of 8 tokens
    ep = Endpoint(cfg, params, slots=4, max_len=32, paged=True, page_size=8,
                  total_pages=4, prefix_cache=False)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 64, 20).astype(np.int32)
    assert ep.page_need(20, 8) == 4                    # extent 27 -> 4 pages
    s0 = ep.try_claim(tokens=toks, max_new=8)
    assert s0 is not None and ep.free_pages == 0
    # slots remain, pages don't: the claim fails without allocating
    assert ep.try_claim(tokens=toks, max_new=8) is None
    assert ep.active == 1 and ep.pool.check_balanced()
    # a request whose extent fits the free pages... still none free
    assert ep.try_claim(tokens=toks[:4], max_new=1) is None
    ep.release(s0)
    assert ep.free_pages == 4 and ep.admissible_pages == 4
    s1 = ep.try_claim(tokens=toks[:4], max_new=1)      # 1 page
    assert s1 is not None and ep.free_pages == 3
    s2 = ep.try_claim(tokens=toks[:4], max_new=1)
    assert s2 is not None and ep.free_pages == 2       # packs 2 where dense=1
    ep.release(s1)
    ep.release(s2)
    assert ep.pool.check_balanced() and ep.free_pages == 4


def test_endpoint_registry_backpressure():
    """Pages pinned only by the prefix registry are reclaimable: a claim
    that needs them evicts LRU entries instead of failing."""
    cfg, params = _model()
    ep = Endpoint(cfg, params, slots=2, max_len=32, paged=True, page_size=8,
                  total_pages=4)
    rng = np.random.default_rng(1)
    a = rng.integers(0, 64, 10).astype(np.int32)
    s = ep.try_claim(tokens=a, max_new=2)
    ep.prefill_batch({s: a})
    ep.release(s)
    assert len(ep.prefix) == 1
    pinned = ep.used_pages
    assert pinned > 0 and ep.admissible_pages == ep.total_pages
    # a different prompt wanting the whole pool evicts the registry
    b = rng.integers(64, 128, 20).astype(np.int32)
    s2 = ep.try_claim(tokens=b, max_new=8)             # needs all 4 pages
    assert s2 is not None and len(ep.prefix) == 0
    ep.release(s2)
    assert ep.pool.check_balanced()


def test_cache_nbytes_page_granularity():
    """Satellite: migration payload accounting rounds up to whole pages
    in paged mode, and a partially-filled paged row ships strictly fewer
    bytes than a dense full row."""
    cfg, params = _model()
    dense = Endpoint(cfg, params, slots=2, max_len=32)
    paged = Endpoint(cfg, params, slots=2, max_len=32, paged=True,
                     page_size=8)
    # page rounding: every length within one page costs the same
    assert paged.cache_nbytes_per_row(1) == paged.cache_nbytes_per_row(8)
    assert paged.cache_nbytes_per_row(9) > paged.cache_nbytes_per_row(8)
    # at page boundaries the two layouts agree (same filled positions)
    assert paged.cache_nbytes_per_row(16) == dense.cache_nbytes_per_row(16)
    # rounding only ever adds, never removes
    for L in (1, 5, 9, 17, 31, 32):
        assert (paged.cache_nbytes_per_row(L)
                >= dense.cache_nbytes_per_row(L))
    assert (paged.cache_nbytes_per_row(40)
            == paged.cache_nbytes_per_row(32))         # capped at max_len

    # live payloads: extract a 5-token row from each layout
    rng = np.random.default_rng(2)
    toks = rng.integers(0, 64, 5).astype(np.int32)
    sd = dense.try_claim(tokens=toks, max_new=2)
    sp = paged.try_claim(tokens=toks, max_new=2)
    dense.prefill_batch({sd: toks})
    paged.prefill_batch({sp: toks})
    d_state, = dense.extract_rows([sd])
    p_state, = paged.extract_rows([sp])
    d_bytes = float(sum(l.nbytes for l in d_state))
    assert p_state.n_pages == 1 and p_state.nbytes < d_bytes
    dense.release(sd)
    paged.release(sp)


def test_reset_slot_from_row_template():
    """Satellite: reset_slot restores a used row to init values from the
    single-row template (no full-pool init_cache per call) — the row is
    bit-identical to a never-used endpoint's."""
    cfg, params = _model()
    ep = Endpoint(cfg, params, slots=2, max_len=32)
    fresh = Endpoint(cfg, params, slots=2, max_len=32)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, 64, 6).astype(np.int32)
    s = ep.try_claim(tokens=toks, max_new=3)
    cur = {s: ep.prefill_batch({s: toks})[s]}
    ep.decode_all(cur)
    ep.reset_slot(s)
    for got, want, ax in zip(jax.tree_util.tree_leaves(ep.cache),
                             jax.tree_util.tree_leaves(fresh.cache),
                             ep._batch_axes):
        if ax is None:
            continue
        np.testing.assert_array_equal(
            np.take(np.asarray(got), s, axis=ax),
            np.take(np.asarray(want), s, axis=ax))
    ep.release(s)


# --------------------------------------------------------------------------
# simulator: the matching page ledger
# --------------------------------------------------------------------------

def _sim_topo(page_size=None, pool_pages=None):
    edge = TierSpec("edge", slots=4, max_len=32, queue_depth_per_slot=2,
                    page_size=page_size, pool_pages=pool_pages)
    cloud = TierSpec("cloud", slots=16, max_len=32,
                     queue_depth_per_slot=None)
    return Topology((edge, cloud), (LinkSpec(rtt_s=0.0),), waterfall=False)


def test_sim_default_pool_matches_dense():
    """With the default pool (slots full rows) and size-less requests the
    page gate is exactly the slot gate: the paged spec reproduces the
    dense run event-for-event."""
    cfg = SimConfig(duration_s=20.0, low_rps=12.0)
    a = ContinuumSimulator("io", 0.0, cfg, topology=_sim_topo()).run()
    b = ContinuumSimulator("io", 0.0, cfg,
                           topology=_sim_topo(page_size=8)).run()
    assert (a.successes, a.failures, a.spilled) == \
        (b.successes, b.failures, b.spilled)
    assert a.tier_counts == b.tier_counts
    np.testing.assert_array_equal(a.offload_pct, b.offload_pct)


def test_sim_tight_pool_gates_admission():
    """A pool smaller than slots full rows binds before the slot count —
    edge throughput drops, yet conservation still holds."""
    # saturating load: edge capacity is 4 slots / 0.4 s = 10 rps
    cfg = SimConfig(duration_s=20.0, low_rps=12.0)
    base = ContinuumSimulator("io", 0.0, cfg,
                              topology=_sim_topo(page_size=8)).run()
    tight = ContinuumSimulator(
        "io", 0.0, cfg,
        topology=_sim_topo(page_size=8, pool_pages=4)).run()
    assert tight.successes + tight.failures == tight.submitted
    assert (tight.tier_counts["edge"] < base.tier_counts["edge"])
    assert tight.failures > base.failures


def test_tierspec_page_validation():
    with pytest.raises(ValueError):
        TierSpec("t", max_len=32, page_size=5)         # must divide
    with pytest.raises(ValueError):
        TierSpec("t", max_len=32, page_size=8, pool_pages=3)   # < one row
    with pytest.raises(ValueError):
        TierSpec("t", max_len=32, pool_pages=8)        # needs page_size
    spec = TierSpec("t", slots=4, max_len=32, page_size=8)
    assert spec.pages_per_row == 4 and spec.total_pages == 16
    assert TierSpec("t", max_len=32).total_pages == 0
