"""End-to-end: the live two-tier continuum offloads under load and the
simulator reproduces the paper's Table-2 ordering."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import offload
from repro.core.replication import FunctionSpec
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.models import model_zoo
from repro.serving.engine import Request
from repro.serving.tiers import EdgeCloudContinuum, TierConfig


@pytest.fixture(scope="module")
def continuum():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    cc = EdgeCloudContinuum(edge=TierConfig(slots=2, max_len=64),
                            cloud=TierConfig(slots=8, max_len=64),
                            seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    return cc


def test_continuum_serves_and_offloads(continuum):
    rng = np.random.default_rng(0)
    rid = 0
    R_hist = []
    for rnd in range(10):
        n = 2 if rnd < 3 else 10           # ramp
        for _ in range(n):
            continuum.submit("fn", Request(
                rid=rid, tokens=rng.integers(0, 128, 6).astype(np.int32),
                max_new=2))
            rid += 1
        rec = continuum.tick()
        R_hist.append(rec["R"])
    served = sum(r["edge"] + r["cloud"] for r in continuum.log)
    assert served == rid                    # nothing dropped
    # all requests produced output tokens
    assert all(isinstance(r["R"], float) for r in continuum.log)


def test_replication_mirrors_to_edge(continuum):
    assert "fn" in continuum.edge.endpoints
    assert "fn" in continuum.cloud.endpoints
    assert continuum.replicator.get("fn") is not None


# ---- simulator reproduces the paper ----------------------------------------

SIM = SimConfig(duration_s=150.0, low_rps=2.0, high_rps=14.0,
                ramp_start_s=20.0, ramp_end_s=70.0)


@pytest.fixture(scope="module")
def sweep():
    out = {}
    for pol in (0.0, 50.0, 100.0, "auto"):
        out[str(pol)] = ContinuumSimulator("matmult", pol, SIM).run()
    return out


def test_offloading_increases_successes(sweep):
    """Paper Table 2: any offloading beats edge-only under overload."""
    assert sweep["50.0"].successes > sweep["0.0"].successes
    assert sweep["auto"].successes > sweep["0.0"].successes


def test_offloading_reduces_latency(sweep):
    l0 = np.nanmean(sweep["0.0"].latency_avg)
    l50 = np.nanmean(sweep["50.0"].latency_avg)
    assert l50 < l0


def test_offloading_reduces_edge_cpu(sweep):
    c0 = np.nanmean(sweep["0.0"].cpu_util)
    c100 = np.nanmean(sweep["100.0"].cpu_util)
    assert c100 < c0


def test_auto_uses_network_only_under_load(sweep):
    """auto starts at 0% offload (no traffic crosses early) and engages
    during the ramp — the adaptivity claim of §4.2."""
    auto = sweep["auto"]
    third = len(auto.offload_pct) // 3
    assert np.nanmean(auto.offload_pct[:third // 2]) < 20.0
    assert np.nanmax(auto.offload_pct) > 50.0


def test_static_100_saturates_network_more_than_auto(sweep):
    assert np.nanmax(sweep["100.0"].net_MBps) >= np.nanmax(sweep["auto"].net_MBps) - 1e-6


def test_sim_is_deterministic():
    a = ContinuumSimulator("io", "auto", SIM).run()
    b = ContinuumSimulator("io", "auto", SIM).run()
    assert a.successes == b.successes and a.failures == b.failures
    np.testing.assert_allclose(a.latency_avg, b.latency_avg, equal_nan=True)
