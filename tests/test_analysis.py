"""continuum-lint: rule fixtures, suppressions, baseline, repo self-check.

Fixture tests build tiny source trees under tmp_path laid out like the
real repo (``src/repro/...``) so the default path-scoping (library roots,
hot paths) applies; each rule gets positive AND negative cases.  The
self-check test then runs the real linter over the real repo and requires
it clean modulo the committed baseline — the same gate CI runs.
"""

from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np

from repro.analysis.engine import (AnalysisConfig, load_baseline,
                                   run_analysis, write_baseline)
from repro.analysis.registry import FORMULAS, Formula

REPO = Path(__file__).resolve().parents[1]


def lint_tree(tmp_path, files, config=None, baseline=None, paths=None):
    """Write {relpath: source} under tmp_path and lint it."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src), encoding="utf-8")
    config = config or AnalysisConfig(formulas=())
    return run_analysis(paths or list(files), root=tmp_path,
                        config=config, baseline=baseline)


def rules_of(report):
    return sorted({f.rule for f in report.findings})


# ---------------------------------------------------------------- jit-purity

def test_jit_purity_flags_impurities_in_jitted_fn(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import time, jax
        import numpy as np

        @jax.jit
        def step(x):
            t = time.time()
            r = np.random.normal()
            print(x)
            v = x.item()
            return x + t + r + v
    """})
    msgs = [f.message for f in rep.findings]
    assert all(f.rule == "jit-purity" for f in rep.findings)
    assert any("time.time" in m for m in msgs)
    assert any("np.random.normal" in m for m in msgs)
    assert any("print" in m for m in msgs)
    assert any(".item()" in m for m in msgs)


def test_jit_purity_propagates_through_helpers(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import time, jax

        def helper(x):
            return x + time.time()

        def outer(x):
            return helper(x)

        stepped = jax.jit(outer)
    """})
    assert rules_of(rep) == ["jit-purity"]
    assert "helper" in rep.findings[0].message


def test_jit_purity_ignores_host_code(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import time

        def host_loop(x):
            print(x)
            return time.time()
    """})
    assert rep.clean


def test_unseeded_rng_flagged_even_outside_jit(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import numpy as np

        def make(seed):
            good = np.random.default_rng(seed)
            bad = np.random.default_rng()
            worse = np.random.uniform(0.0, 1.0)
            return good, bad, worse
    """})
    assert rules_of(rep) == ["jit-purity"]
    assert len(rep.findings) == 2  # the seeded ctor is fine


# ---------------------------------------------------------- recompile-hazard

def test_recompile_flags_jit_in_loop(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import jax

        def sweep(xs):
            out = []
            for x in xs:
                f = jax.jit(lambda v: v * x)
                out.append(f(x))
            return out
    """})
    assert "recompile-hazard" in rules_of(rep)
    assert any("inside a loop" in f.message for f in rep.findings)


def test_recompile_flags_per_call_closure_jit(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import jax

        def update(state, cfg):
            f = jax.jit(lambda s: s * cfg.gain)
            return f(state)
    """})
    assert any("fresh identity" in f.message for f in rep.findings)


def test_recompile_allows_init_and_init_only_helpers(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import jax

        class Engine:
            def __init__(self):
                self._ops = None
                self._build_ops()

            def _build_ops(self):
                def _gather(c, i):
                    return c[i]
                self._ops = jax.jit(_gather)
    """})
    assert rep.clean


def test_recompile_closure_check_skips_tests_and_benchmarks(tmp_path):
    src = """
        import jax

        def test_something():
            f = jax.jit(lambda v: v + 1)
            assert f(1) == 2
    """
    assert lint_tree(tmp_path, {"tests/test_x.py": src}).clean
    assert not lint_tree(tmp_path, {"src/repro/x.py": src}).clean


def test_recompile_validates_static_argnums_and_names(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import jax

        @jax.jit
        def f(a, b):
            return a + b

        g = jax.jit(f, static_argnums=(5,))
        h = jax.jit(f, static_argnames=("nope",))
    """})
    msgs = [f.message for f in rep.findings]
    assert any("out of range" in m for m in msgs)
    assert any("not a parameter" in m for m in msgs)


def test_recompile_flags_fstring_and_loop_static_args(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        import jax

        def route(x, n):
            return x * n

        routed = jax.jit(route, static_argnums=(1,))

        def drive(xs):
            routed(xs[0], f"mode-{len(xs)}")
            for n in range(4):
                routed(xs[0], n)
    """})
    msgs = [f.message for f in rep.findings]
    assert any("f-string" in m for m in msgs)
    assert any("loop variable `n`" in m for m in msgs)


# -------------------------------------------------------------- parity-drift

PAGES_CLONE = """
    def my_pages(prompt_len, max_new, page_size, max_len):
        if page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {page_size}")
        ppr = -(-max_len // page_size)
        span = token_extent(prompt_len, max_new)
        if span > max_len:
            return ppr
        return min(ppr, max(1, -(-span // page_size)))

    def token_extent(prompt_len, max_new):
        return prompt_len + max(max_new, 1) - 1
"""


def test_parity_drift_fires_on_pages_needed_clone(tmp_path):
    """Acceptance criterion: a re-typed pages_needed (renamed function,
    renamed locals) is detected against the REAL registry."""
    fixture = tmp_path / "clone.py"
    fixture.write_text(textwrap.dedent(PAGES_CLONE), encoding="utf-8")
    cfg = AnalysisConfig(formulas=FORMULAS, hot_paths=(),
                         library_roots=("/",))
    rep = run_analysis([str(fixture)], root=REPO, config=cfg)
    hits = [f for f in rep.findings if f.rule == "parity-drift"]
    assert any("pages-needed" in f.message for f in hits)
    assert any("token-extent" in f.message for f in hits)


def test_parity_drift_fires_on_link_latency_expression(tmp_path):
    fixture = tmp_path / "clone.py"
    fixture.write_text(textwrap.dedent("""
        class Net:
            def cost(self, nbytes=0.0):
                return self.rtt_s + nbytes / self.bandwidth_Bps
    """), encoding="utf-8")
    cfg = AnalysisConfig(formulas=FORMULAS, hot_paths=(),
                         library_roots=("/",))
    rep = run_analysis([str(fixture)], root=REPO, config=cfg)
    assert any(f.rule == "parity-drift" and "link-latency" in f.message
               for f in rep.findings)


def test_parity_drift_skips_canonical_home_and_tests(tmp_path):
    # the canonical implementations themselves must not self-flag
    cfg = AnalysisConfig(formulas=FORMULAS)
    rep = run_analysis(["src/repro/cache/pages.py",
                        "src/repro/core/topology.py",
                        "src/repro/core/offload.py",
                        "src/repro/core/policy.py"], root=REPO, config=cfg)
    assert not [f for f in rep.findings if f.rule == "parity-drift"]
    # and a clone in TEST code is fine (tests recompute oracles)
    fixture = tmp_path / "tests" / "test_clone.py"
    fixture.parent.mkdir()
    fixture.write_text(textwrap.dedent(PAGES_CLONE), encoding="utf-8")
    rep = run_analysis([str(fixture)], root=REPO,
                       config=AnalysisConfig(formulas=FORMULAS))
    assert not [f for f in rep.findings if f.rule == "parity-drift"]


def test_formula_registry_opt_in_is_one_line(tmp_path):
    """A brand-new formula registered with one Formula(...) line is
    immediately enforced."""
    files = {
        "src/repro/core/canon.py": """
            def decay_mix(w, a, b):
                num = w * a + (1.0 - w) * b
                den = max(w * a, 1e-9)
                return num / den + min(a, b)
        """,
        "src/repro/serving/copycat.py": """
            def sneaky(weight, x, y):
                num = weight * x + (1.0 - weight) * y
                den = max(weight * x, 1e-9)
                return num / den + min(x, y)
        """,
    }
    cfg = AnalysisConfig(formulas=(
        Formula(name="decay-mix", home="src/repro/core/canon.py",
                qualname="decay_mix", why="test formula"),))
    rep = lint_tree(tmp_path, files, config=cfg)
    assert any(f.rule == "parity-drift" and "decay-mix" in f.message
               and f.path.endswith("copycat.py") for f in rep.findings)
    # the home itself is not flagged
    assert not any(f.path.endswith("canon.py") for f in rep.findings)


# ------------------------------------------------------- swallowed-exception

def test_swallowed_exception_hot_path_flags_even_reraise(tmp_path):
    src = """
        def tick(ep, claimed):
            try:
                ep.step()
            except Exception:
                for s in claimed:
                    ep.release(s)
                raise
    """
    hot = lint_tree(tmp_path, {"src/repro/serving/t.py": src})
    assert rules_of(hot) == ["swallowed-exception"]
    cold = lint_tree(tmp_path, {"src/repro/launch/t.py": src})
    assert cold.clean  # re-raising broad catch is fine off the hot path


def test_swallowed_exception_silent_flagged_everywhere(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/launch/t.py": """
        def probe(x):
            try:
                return x.info()
            except Exception:
                pass
            return None
    """})
    assert rules_of(rep) == ["swallowed-exception"]


def test_swallowed_exception_narrow_or_logged_ok(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/launch/t.py": """
        import warnings

        def probe(x):
            try:
                return x.info()
            except (KeyError, ValueError):
                pass
            try:
                return x.info()
            except Exception as e:
                warnings.warn(f"probe failed: {e!r}")
            return None
    """})
    assert rep.clean


# ------------------------------------------------------------ library-assert

def test_library_assert_scoped_to_library(tmp_path):
    src = """
        def f(x):
            assert x > 0
            return x
    """
    assert rules_of(lint_tree(tmp_path, {"src/repro/m.py": src})) \
        == ["library-assert"]
    assert lint_tree(tmp_path, {"tests/test_m.py": src}).clean


# --------------------------------------------------- suppressions & baseline

def test_inline_and_block_suppressions(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        def f(x):
            assert x > 0  # lint: ignore[library-assert] -- fixture wants it
            # lint: ignore[library-assert] -- reason may span a
            # comment block; the directive covers the next code line
            assert x < 9
            return x
    """})
    assert rep.clean
    assert len(rep.suppressed) == 2


def test_suppression_requires_rule_and_reason(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        def f(x):
            assert x > 0  # lint: ignore[library-assert]
            return x
    """})
    assert "bad-suppression" in rules_of(rep)
    # and the un-reasoned directive does NOT suppress the finding
    assert "library-assert" in rules_of(rep)


def test_ignore_file_suppression(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        # lint: ignore-file[library-assert] -- generated shim, asserts ok

        def f(x):
            assert x > 0
            return x
    """})
    assert rep.clean and len(rep.suppressed) == 1


def test_directive_inside_docstring_is_inert(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": '''
        """Docs quoting the syntax: # lint: ignore[library-assert] -- x."""

        def f(x):
            assert x > 0
            return x
    '''})
    assert rules_of(rep) == ["library-assert"]  # not suppressed, not bad


def test_baseline_grandfathers_then_expires_on_edit(tmp_path):
    files = {"src/repro/m.py": """
        def f(x):
            assert x > 0
            return x
    """}
    rep = lint_tree(tmp_path, files)
    assert not rep.clean
    bpath = tmp_path / "baseline.json"
    write_baseline(bpath, rep)

    rep2 = lint_tree(tmp_path, files, baseline=load_baseline(bpath))
    assert rep2.clean and len(rep2.baselined) == 1

    # same rule, same file, but the offending LINE changed -> new finding
    edited = {"src/repro/m.py": """
        def f(x):
            assert x > 1
            return x
    """}
    rep3 = lint_tree(tmp_path, edited, baseline=load_baseline(bpath))
    assert not rep3.clean


def test_finding_keys_disambiguate_identical_lines(tmp_path):
    rep = lint_tree(tmp_path, {"src/repro/m.py": """
        def f(x):
            assert x > 0
            return x

        def g(x):
            assert x > 0
            return x
    """})
    keys = {rep.keys[id(f)] for f in rep.findings}
    assert len(keys) == len(rep.findings) == 2


# ------------------------------------------------------------- CLI & CI gate

def test_cli_reports_and_exits_nonzero_on_findings(tmp_path):
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "m.py").write_text(
        "def f(x):\n    assert x\n    return x\n", encoding="utf-8")
    env_root = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "--root", env_root,
         "--json", str(tmp_path / "stats.json")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1
    stats = json.loads((tmp_path / "stats.json").read_text())
    assert stats["new"] == 1 and stats["per_rule"] == {"library-assert": 1}


def test_repo_is_clean_modulo_baseline():
    """The gate CI enforces: the real repo, the real rules, the committed
    baseline."""
    baseline = load_baseline(REPO / ".analysis-baseline.json")
    rep = run_analysis(["src", "tests", "benchmarks"], root=REPO,
                       baseline=baseline)
    assert rep.clean, "\n".join(f.render() for f in rep.findings)
    # every suppression in the tree carries a reason by construction;
    # make sure none of them quietly lost its target rule
    for f, reason in rep.suppressed:
        assert reason.strip()


# --------------------------------------------------------- RNG determinism

def test_seeded_workloads_are_bitwise_deterministic():
    """Satellite of the RNG audit: every generator descends from an
    explicit seed, so two identically-seeded runs must agree exactly."""
    from repro.workloads import trace as tr

    t1 = tr.request_rounds(rounds=5, seed=17)
    t2 = tr.request_rounds(rounds=5, seed=17)
    assert len(t1) == len(t2)
    for (r1, tok1, m1), (r2, tok2, m2) in zip(t1, t2):
        assert r1 == r2 and m1 == m2
        assert np.array_equal(tok1, tok2)

    t3 = tr.request_rounds(rounds=5, seed=18)
    assert any(not np.array_equal(a[1], b[1]) for a, b in zip(t1, t3))
