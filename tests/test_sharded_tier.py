"""Sharded-tier cost model: TierSpec resolution contract, tier_cost
pricing units, the roofline fallback path, and (in a 2-placeholder-device
subprocess) bit-identical shard_map decode plus collective costs on the
compiled sharded HLO."""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import configs
from repro.core.simulator import SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.launch import hlo_analysis, hlo_cost
from repro.launch import tier_cost as tc
from repro.platform import Continuum
from repro.serving.tiers import Tier

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---- TierSpec validation: cost-modeled fields are all-or-nothing ----------

def test_mesh_shape_requires_model():
    with pytest.raises(ValueError, match="mesh_shape requires model"):
        TierSpec("cloud", mesh_shape=(1, 2))


def test_mesh_shape_dims_validated():
    with pytest.raises(ValueError, match="two positive"):
        TierSpec("cloud", model="stablelm-1.6b", mesh_shape=(2,))
    with pytest.raises(ValueError, match="two positive"):
        TierSpec("cloud", model="stablelm-1.6b", mesh_shape=(0, 2))


def test_decode_step_ms_is_an_output_not_an_input():
    with pytest.raises(ValueError, match="requires model"):
        TierSpec("cloud", decode_step_ms=5.0)


def test_hand_set_mult_on_cost_modeled_tier_rejected():
    # the drift this PR removes: a model-named tier with a hand-set rate
    with pytest.raises(ValueError, match="set neither by hand"):
        TierSpec("cloud", model="stablelm-1.6b", service_rate_mult=2.0)
    # ...and the mirror image: a derived step without its derived rate
    with pytest.raises(ValueError, match="set neither by hand"):
        TierSpec("cloud", model="stablelm-1.6b", decode_step_ms=5.0)


def test_spec_properties():
    unres = TierSpec("cloud", model="stablelm-1.6b", mesh_shape=(2, 4))
    assert unres.cost_modeled and not unres.resolved
    assert unres.devices == 8
    res = dataclasses.replace(unres, decode_step_ms=3.0,
                              service_rate_mult=1.0)
    assert res.cost_modeled and res.resolved
    plain = TierSpec("edge", service_rate_mult=1.0)
    assert not plain.cost_modeled and plain.resolved and plain.devices == 1


# ---- both deployments refuse unresolved cost-modeled specs ----------------

def _unresolved_topology():
    return Topology(tiers=(TierSpec("edge", service_rate_mult=1.0),
                           TierSpec("cloud", model="stablelm-1.6b",
                                    queue_depth_per_slot=None)),
                    links=(LinkSpec(),), waterfall=False)


def test_simulator_rejects_unresolved_spec():
    with pytest.raises(ValueError, match="unresolved"):
        Continuum.simulate("matmult", "auto",
                           topology=_unresolved_topology())


def test_live_deploy_rejects_unresolved_spec():
    spec = _unresolved_topology().tiers[1]
    with pytest.raises(ValueError, match="unresolved"):
        Tier("cloud", spec).deploy("fn", None, None)


# ---- bugfix 1: the elastic-cloud None sentinel must pass through ----------

def test_resolve_costs_is_identity_for_hand_set_chains():
    topo = Topology.pair(TierSpec("edge", slots=2),
                         TierSpec("cloud", slots=16,
                                  queue_depth_per_slot=None))
    assert topo.resolve_costs() is topo
    out = tc.resolve_specs(topo.tiers)
    # pass-through means the SAME objects: the elastic cloud keeps its
    # service_rate_mult=None profile-default sentinel bit-identically
    assert out[0] is topo.tiers[0] and out[1] is topo.tiers[1]
    assert out[1].service_rate_mult is None


def test_two_tier_bit_identity():
    """Pin that the derived-rate plumbing left the paper apparatus alone:
    an explicit default topology simulates bit-identically to the
    built-in 2-tier path."""
    a = Continuum.simulate("matmult", "auto")
    b = Continuum.simulate("matmult", "auto",
                           topology=SimConfig().default_topology())
    assert a.failures == b.failures
    for f in ("latency_avg", "cpu_util", "offload_pct", "net_MBps"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)


# ---- tier_cost pricing units ----------------------------------------------

def test_derived_slot_capacity_formula():
    # 10 GB free / 1 GB per row = 10 rows; requested clamps both ways
    assert tc.derived_slot_capacity(4, 12e9, 1e9, 1e9, 1e9) == 4
    assert tc.derived_slot_capacity(500, 12e9, 1e9, 1e9, 1e9) == 10
    with pytest.raises(ValueError, match="kv_row_bytes"):
        tc.derived_slot_capacity(4, 12e9, 1e9, 1e9, 0.0)
    with pytest.raises(ValueError, match="does not fit"):
        tc.derived_slot_capacity(4, 2e9, 1.5e9, 1e9, 1e9)


def test_derived_service_rate_mult_formula():
    assert tc.derived_service_rate_mult(2.0, 4.0) == 0.5
    assert tc.derived_service_rate_mult(3.0, 3.0) == 1.0
    with pytest.raises(ValueError, match="must be > 0"):
        tc.derived_service_rate_mult(0.0, 1.0)


def test_tier_cost_unsharded_small_model():
    c = tc.tier_cost("stablelm-1.6b", requested_slots=500)
    assert c.devices == 1 and c.mesh_shape == (1, 1)
    # requested 500 clamps to the HBM KV fit
    assert c.slots == c.kv_fit_slots < 500
    assert c.decode_step_s > 0
    # small-batch unsharded decode is weight-streaming bound
    assert c.roofline["dominant"] == "memory"
    # no mesh => the synthetic HLO carries no collectives
    hlo = tc.decode_step_hlo(configs.get_config("stablelm-1.6b"),
                             tp=1, batch=c.slots, max_len=256)
    assert hlo_cost.analyze_hlo(hlo)["num_collectives"] == 0


def test_tier_cost_sharded_collective_count():
    cfg = configs.get_config("stablelm-1.6b")
    hlo = tc.decode_step_hlo(cfg, tp=2, batch=4, max_len=256)
    hc = hlo_cost.analyze_hlo(hlo)
    # psum scheme: 2 all-reduce instructions in the layer body (the
    # while's known_trip_count scales their traffic by num_layers) plus
    # the embed/logits all-gathers in the entry
    assert hc["num_collectives"] == 4
    counts = hlo_analysis.collective_ops_count(hlo)
    assert counts["all-reduce"] == 2 and counts["all-gather"] == 2
    # per-layer all-reduce wire = 2*R*(n-1)/n, charged once per layer
    cfg1 = dataclasses.replace(cfg, num_layers=1)
    hlo1 = tc.decode_step_hlo(cfg1, tp=2, batch=4, max_len=256)
    hc1 = hlo_cost.analyze_hlo(hlo1)
    per_layer_ar = 2.0 * (4 * cfg.d_model * 2) * (2 - 1) / 2  # bf16 (B,d)
    got = hc["collective_wire_bytes"] - hc1["collective_wire_bytes"]
    assert got == pytest.approx((cfg.num_layers - 1) * 2 * per_layer_ar)


def test_tier_cost_rejects_model_that_does_not_fit():
    with pytest.raises(ValueError, match="does not fit"):
        tc.tier_cost("qwen2.5-14b")        # 14B unsharded > 16 GB HBM


def test_tier_cost_rejects_non_dense_family():
    with pytest.raises(ValueError, match="dense family"):
        tc.tier_cost("qwen2-moe-a2.7b")


def test_sharding_shrinks_per_device_footprint():
    cfg = configs.get_config("qwen2.5-14b")
    p1 = tc.params_bytes_per_device(cfg, 1)
    p2 = tc.params_bytes_per_device(cfg, 2)
    assert p1 / 2 < p2 < p1          # sharded, minus replicated norms
    k1 = tc.kv_row_bytes_per_device(cfg, 1, 256)
    k2 = tc.kv_row_bytes_per_device(cfg, 2, 256)
    assert k2 < k1


def test_resolve_specs_reference_tier_mult_is_one():
    specs = (TierSpec("device", slots=2, model="stablelm-1.6b",
                      queue_depth_per_slot=4),
             TierSpec("edge", slots=4, service_rate_mult=1.0))
    out = tc.resolve_specs(specs)
    assert out[0].service_rate_mult == 1.0          # chain's first modeled
    assert out[0].decode_step_ms and out[0].resolved
    assert out[1] is specs[1]                       # hand-set passthrough


@pytest.mark.slow
def test_device_edge_cloud_cost_model():
    topo = Topology.device_edge_cloud(cost_model=True)
    dev, edge, cloud = topo.tiers
    assert all(t.resolved for t in topo.tiers)
    assert dev.service_rate_mult == 1.0             # ingress = calibration
    # honest speed inversion: each hop serves a far bigger model
    assert dev.decode_step_ms < edge.decode_step_ms < cloud.decode_step_ms
    assert edge.service_rate_mult < 1.0
    assert cloud.service_rate_mult < 1.0
    # requested slots survived as ceilings (they all fit)
    assert (dev.slots, edge.slots, cloud.slots) == (2, 4, 64)
    # the resolved chain actually simulates
    res = Continuum.simulate("matmult", "auto", topology=topo)
    assert float(np.nanmean(res.latency_avg)) > 0


# ---- bugfix 2: roofline_from_compiled survives cost_analysis failure ------

class _BrokenCompiled:
    def as_text(self):
        raise RuntimeError("backend cannot render HLO")

    def cost_analysis(self):
        raise RuntimeError("no cost analysis on this backend")


def test_roofline_fallback_on_text_failure():
    with pytest.warns(UserWarning, match="fallback"):
        roof, detail = hlo_analysis.roofline_from_compiled(
            _BrokenCompiled(), 2)
    # explicit zero-cost roofline, never a partial dict
    assert roof.step_s == 0.0 and roof.flops_per_device == 0.0
    assert detail["fallback"] is not None
    assert "cannot render" in detail["fallback"]
    assert detail["xla_cost_analysis_ok"] is False
    assert detail["collectives"]["total"] == 0.0
    assert detail["num_collectives"] == 0


def test_roofline_explicit_text_survives_cost_analysis_failure():
    hlo = tc.decode_step_hlo(configs.get_config("stablelm-1.6b"),
                             tp=2, batch=2, max_len=64)
    with pytest.warns(UserWarning, match="cost_analysis unavailable"):
        roof, detail = hlo_analysis.roofline_from_compiled(
            _BrokenCompiled(), 2, hlo_text=hlo)
    # the cost walk ran from the provided text: real roofline, no fallback
    assert roof.step_s > 0 and detail["fallback"] is None
    assert detail["xla_cost_analysis_ok"] is False
    assert detail["num_collectives"] > 0


# ---- sharded decode parity on forced host devices (subprocess) ------------

_SUBPROC_CODE = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro import configs
    from repro.models import model_zoo
    from repro.serving import sharded
    from repro.serving.engine import Endpoint
    from repro.launch import mesh as mesh_mod
    from repro.launch import hlo_analysis

    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    mesh = mesh_mod.make_mesh((1, 2), ("data", "model"))

    # -- Endpoint-level parity: dense vs tensor-parallel ------------------
    def run(mesh):
        ep = Endpoint(cfg, params, slots=4, max_len=32, mesh=mesh)
        rng = np.random.RandomState(7)
        prompts = {s: rng.randint(0, cfg.vocab_size,
                                  size=(5 + s,)).astype(np.int32)
                   for s in range(3)}
        for _ in prompts:
            ep.try_claim()
        first = ep.prefill_batch(prompts)
        streams = {s: [int(v)] for s, v in first.items()}
        cur = dict(first)
        for _ in range(6):
            cur = ep.decode_all(cur)
            for s, v in cur.items():
                streams[s].append(int(v))
        return streams, ep.cache_nbytes_per_row(16)

    s_ref, nb_ref = run(None)
    s_tp, nb_tp = run(mesh)

    # -- raw-function prefill-logits parity --------------------------------
    cache = model_zoo.init_cache(cfg, 2, 32)
    tp_prefill, tp_decode, pspecs, cspecs = sharded.make_tp_functions(
        cfg, mesh, cache)
    params_s = sharded.shard_params(params, mesh, pspecs)
    cache_s = sharded.shard_cache(cache, mesh, cspecs)
    toks = jnp.asarray(np.random.RandomState(3).randint(
        0, cfg.vocab_size, size=(2, 8)), jnp.int32)
    lengths = jnp.array([8, 5], jnp.int32)
    lg_tp, _ = tp_prefill(params_s, toks, lengths, cache_s)
    lg_ref, _ = model_zoo.prefill(cfg, params, {"tokens": toks}, cache,
                                  lengths=lengths)
    logits_equal = bool(jnp.array_equal(lg_tp, lg_ref))

    # -- collective costs on the REAL compiled sharded decode HLO ----------
    tok = jnp.zeros((2,), jnp.int32)
    t = jnp.full((2,), 5, jnp.int32)
    compiled = jax.jit(tp_decode).lower(params_s, cache_s, tok, t).compile()
    roof, detail = hlo_analysis.roofline_from_compiled(compiled, 2)

    print(json.dumps({
        "ndev": len(jax.devices()),
        "streams_equal": s_ref == s_tp,
        "logits_equal": logits_equal,
        "nbytes_ref": nb_ref, "nbytes_tp": nb_tp,
        "all_gathers": detail["counts"]["all-gather"],
        "wire_bytes": roof.collective_bytes_per_device,
        "fallback": detail["fallback"],
    }))
""")


@pytest.fixture(scope="module")
def sharded_subproc():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC_CODE], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_decode_bit_identical(sharded_subproc):
    r = sharded_subproc
    assert r["ndev"] == 2
    assert r["streams_equal"], "sharded token stream diverged from dense"
    assert r["logits_equal"], "sharded prefill logits diverged from dense"


@pytest.mark.slow
def test_cache_nbytes_per_row_mesh_invariant(sharded_subproc):
    # bugfix 3: per-shard KV leaves must not count once per replica —
    # the logical per-row bytes are identical at mesh size 1 and 2
    r = sharded_subproc
    assert r["nbytes_ref"] == r["nbytes_tp"] > 0


@pytest.mark.slow
def test_compiled_sharded_hlo_collective_costs(sharded_subproc):
    # the weight-gather scheme's all-gathers survive compilation and the
    # cost walk prices their wire bytes from real replica_groups={{0,1}}
    r = sharded_subproc
    assert r["all_gathers"] > 0
    assert r["wire_bytes"] > 0
    assert r["fallback"] is None
