"""Replication merge invariants (paper §3.3.1 anti-feedback-loop)."""

import dataclasses

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:      # not installable here; deterministic shim
    from _hypothesis_fallback import hypothesis, st

from repro.core.replication import (EDGE_ANNOTATION_PREFIX, AutoscalingPolicy,
                                    EdgeServiceState, FunctionSpec,
                                    ReplicationController, merge)

ann_key = st.text(alphabet="abcdefgh/.-", min_size=1, max_size=12)
ann_val = st.text(max_size=8)


def mk_spec(name="fn", rev=1, ann=None, ckpt=""):
    return FunctionSpec(name=name, arch="stablelm-1.6b", revision=rev,
                        checkpoint_ref=ckpt, annotations=ann or {})


def test_merge_idempotent():
    cloud = mk_spec(rev=3, ann={"a": "1"})
    edge = EdgeServiceState(spec=mk_spec(rev=1), traffic_pct_to_cloud=37.5)
    once, ch1 = merge(edge, cloud)
    twice, ch2 = merge(once, cloud)
    assert ch1 is True and ch2 is False
    assert twice == once


def test_merge_preserves_edge_owned_fields():
    cloud = mk_spec(rev=5)
    edge = EdgeServiceState(spec=mk_spec(rev=1), ready_instances=2,
                            traffic_pct_to_cloud=80.0, status="Ready")
    merged, _ = merge(edge, cloud)
    assert merged.ready_instances == 2
    assert merged.traffic_pct_to_cloud == 80.0
    assert merged.status == "Ready"
    assert merged.spec.revision == 5


def test_merge_preserves_edge_annotations():
    cloud = mk_spec(rev=2, ann={"cloud.key": "c"})
    e_ann = {EDGE_ANNOTATION_PREFIX + "state": "warm"}
    edge = EdgeServiceState(spec=mk_spec(rev=2, ann=e_ann))
    merged, changed = merge(edge, cloud)
    assert merged.spec.annotations[EDGE_ANNOTATION_PREFIX + "state"] == "warm"
    assert merged.spec.annotations["cloud.key"] == "c"


def test_no_writes_in_steady_state():
    """The paper's feedback loop = writes growing without cloud changes."""
    rc = ReplicationController()
    view = {"f1": mk_spec("f1", rev=1), "f2": mk_spec("f2", rev=4)}
    rc.reconcile(view)
    w0 = rc.writes
    for _ in range(25):
        rc.reconcile(view)
    assert rc.writes == w0


def test_edge_state_writes_do_not_trigger_replication():
    rc = ReplicationController()
    view = {"f1": mk_spec("f1")}
    rc.reconcile(view)
    w0 = rc.writes
    rc.set_edge_state("f1", traffic_pct_to_cloud=66.0, status="Ready")
    rc.reconcile(view)
    assert rc.writes == w0
    assert rc.get("f1").traffic_pct_to_cloud == 66.0


def test_revision_bump_redeploys_and_gc():
    rc = ReplicationController()
    rc.reconcile({"f1": mk_spec("f1", rev=1)})
    out = rc.reconcile({"f1": mk_spec("f1", rev=2)})
    assert out["f1"] is True
    out = rc.reconcile({})
    assert out["f1"] is True and rc.get("f1") is None


@hypothesis.given(
    st.dictionaries(ann_key, ann_val, max_size=4),
    st.dictionaries(ann_key.map(lambda k: EDGE_ANNOTATION_PREFIX + k),
                    ann_val, max_size=4),
    st.integers(1, 9))
@hypothesis.settings(max_examples=60, deadline=None)
def test_merge_properties(cloud_ann, edge_ann, rev):
    """idempotence + edge-ownership for arbitrary annotation sets."""
    cloud = mk_spec(rev=rev, ann=cloud_ann)
    edge = EdgeServiceState(spec=mk_spec(rev=1, ann=edge_ann),
                            traffic_pct_to_cloud=12.0)
    m1, _ = merge(edge, cloud)
    m2, changed2 = merge(m1, cloud)
    assert m2 == m1 and changed2 is False
    # every edge-prefixed annotation of the edge copy survives
    for k, v in edge_ann.items():
        assert m1.spec.annotations.get(k) == v
    # edge-owned scalar survives
    assert m1.traffic_pct_to_cloud == 12.0
