"""``repro.workloads``: trace generators, CSV replay, fault schedules,
and chaos through the simulator (the live-runtime chaos paths are
covered by ``test_fault_tolerance.py`` and ``test_parity_fuzz.py``)."""

import numpy as np
import pytest

from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.workloads.faults import (FaultEvent, FaultSchedule, LinkState,
                                    cloud_partition, edge_brownout,
                                    merge_schedules, tier_outage)
from repro.workloads.trace import (RampedPoisson, StationaryPoisson, Trace,
                                   request_rounds, trace_requests)


# ---- trace generators ------------------------------------------------------

def test_generators_deterministic():
    for gen in (lambda s: Trace.poisson(4.0, 60.0, seed=s),
                lambda s: Trace.bursty(2.0, 20.0, 60.0, seed=s),
                lambda s: Trace.diurnal(4.0, 60.0, period_s=60.0, seed=s)):
        a, b, c = gen(7), gen(7), gen(8)
        np.testing.assert_array_equal(a.t, b.t)
        np.testing.assert_array_equal(a.fn, b.fn)
        assert len(c) and not np.array_equal(
            a.t[:min(len(a), len(c))], c.t[:min(len(a), len(c))])


def test_poisson_rate_and_bounds():
    tr = Trace.poisson(rps=8.0, duration_s=200.0, seed=0)
    assert tr.duration_s == 200.0
    assert np.all(tr.t >= 0) and np.all(tr.t < 200.0)
    assert np.all(np.diff(tr.t) >= 0)
    assert abs(tr.mean_rps() - 8.0) / 8.0 < 0.15        # LLN at n~1600


def test_bursty_is_bimodal():
    """On-phase arrival density is much higher than off-phase: the
    busiest 1s bucket of an MMPP trace far exceeds the base rate."""
    tr = Trace.bursty(base_rps=2.0, burst_rps=40.0, duration_s=300.0,
                      mean_on_s=10.0, mean_off_s=30.0, seed=1)
    counts = tr.per_tick(1.0)[:, 0]
    assert counts.max() >= 20                           # deep in a burst
    assert np.median(counts) <= 6                       # mostly off-phase
    base, burst = 2.0, 40.0
    assert base < tr.mean_rps() < burst


def test_diurnal_modulates_rate():
    tr = Trace.diurnal(mean_rps=10.0, duration_s=600.0, period_s=600.0,
                       amplitude=0.8, peak_at_s=0.0, seed=2)
    # peak half-period (cos > 0) vs trough half-period
    peak = np.sum((tr.t < 150.0) | (tr.t >= 450.0))
    trough = np.sum((tr.t >= 150.0) & (tr.t < 450.0))
    assert peak > 1.5 * trough
    with pytest.raises(ValueError):
        Trace.diurnal(4.0, 60.0, amplitude=1.5)


def test_zipf_popularity_skew():
    names = tuple(f"f{i}" for i in range(8))
    tr = Trace.poisson(rps=20.0, duration_s=200.0, fn_names=names,
                       seed=3, popularity="zipf", zipf_s=1.2)
    counts = np.bincount(tr.fn, minlength=8)
    assert counts[0] > 2 * counts[4]                    # head >> tail
    uni = Trace.poisson(rps=20.0, duration_s=200.0, fn_names=names,
                        seed=3, popularity="uniform")
    ucounts = np.bincount(uni.fn, minlength=8)
    assert ucounts.max() < 2 * max(ucounts.min(), 1)
    with pytest.raises(ValueError):
        Trace.poisson(4.0, 10.0, popularity="powerlaw")


def test_trace_validation():
    with pytest.raises(ValueError):                     # decreasing times
        Trace(t=[2.0, 1.0], fn=[0, 0], prompt_len=[4, 4],
              max_new=[2, 2], payload_bytes=[1.0, 1.0])
    with pytest.raises(ValueError):                     # fn out of range
        Trace(t=[1.0], fn=[3], prompt_len=[4], max_new=[2],
              payload_bytes=[1.0], fn_names=("a",))
    with pytest.raises(ValueError):                     # ragged columns
        Trace(t=[1.0, 2.0], fn=[0], prompt_len=[4], max_new=[2],
              payload_bytes=[1.0])


def test_window_and_per_tick():
    tr = Trace(t=[0.5, 1.1, 1.9, 3.2], fn=[0, 1, 0, 1],
               prompt_len=[4] * 4, max_new=[2] * 4,
               payload_bytes=[1.0] * 4, fn_names=("a", "b"),
               duration_s=4.0)
    np.testing.assert_array_equal(tr.window(1.0, 2.0), [1, 2])
    counts = tr.per_tick(1.0)
    assert counts.shape == (4, 2)
    assert counts.sum() == 4
    np.testing.assert_array_equal(counts[1], [1, 1])


def test_csv_roundtrip_bit_faithful(tmp_path):
    tr = Trace.bursty(2.0, 24.0, 60.0, fn_names=("alpha", "beta"),
                      seed=5, popularity="zipf")
    rt = tr.round_trip()
    assert len(rt) == len(tr)
    np.testing.assert_allclose(rt.t, tr.t, atol=1e-6)   # 6-decimal format
    # per-row function *names* survive (index remap is allowed)
    assert ([tr.fn_names[i] for i in tr.fn]
            == [rt.fn_names[i] for i in rt.fn])
    np.testing.assert_array_equal(rt.prompt_len, tr.prompt_len)
    np.testing.assert_array_equal(rt.max_new, tr.max_new)
    np.testing.assert_allclose(rt.payload_bytes, tr.payload_bytes)
    # and through a real file
    p = str(tmp_path / "trace.csv")
    tr.to_csv(p)
    again = Trace.from_csv(p)
    np.testing.assert_allclose(again.t, rt.t)
    with pytest.raises(ValueError):                     # header pinned
        bad = tmp_path / "bad.csv"
        bad.write_text("time,function\n")
        Trace.from_csv(str(bad))


def test_request_rounds_matches_historical_workload():
    """The consolidated helper reproduces serving_bench's historical
    private generator draw-for-draw."""
    rng = np.random.default_rng(4)
    expect = []
    for rnd in range(6):
        for _ in range(2 if rnd < 3 else 8):
            expect.append((rnd, rng.integers(0, 128, 6).astype(np.int32), 6))
    got = request_rounds(6, seed=4)
    assert len(got) == len(expect)
    for (r1, t1, m1), (r2, t2, m2) in zip(got, expect):
        assert r1 == r2 and m1 == m2
        np.testing.assert_array_equal(t1, t2)


def test_trace_requests_tokens():
    tr = Trace.poisson(5.0, 20.0, seed=6, prompt_len=7)
    toks = trace_requests(tr, seed=0, vocab=64)
    assert len(toks) == len(tr)
    assert all(len(t) == 7 and t.dtype == np.int32 for t in toks)
    assert all(t.min() >= 0 and t.max() < 64 for t in toks)


# ---- fault schedules -------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(1.0, "melt_link", 0)
    with pytest.raises(ValueError):
        FaultEvent(-1.0, "crash_tier", 0)
    with pytest.raises(ValueError):
        FaultEvent(1.0, "degrade_link", 0, bw_mult=0.0)


def test_schedule_due_and_reset():
    s = FaultSchedule([FaultEvent(5.0, "crash_tier", 0),
                       FaultEvent(1.0, "partition_link", 0),
                       FaultEvent(3.0, "restore_link", 0)])
    assert [e.t for e in s] == [1.0, 3.0, 5.0]          # time-sorted
    assert [e.t for e in s.due(3.0)] == [1.0, 3.0]
    assert not s.exhausted
    assert [e.t for e in s.due(100.0)] == [5.0]
    assert s.exhausted and s.due(1e9) == []
    s.reset()
    assert len(s.due(10.0)) == 3


def test_schedule_validate_against_topology():
    ok = FaultSchedule([FaultEvent(1.0, "degrade_link", 0),
                        FaultEvent(2.0, "crash_tier", 1)])
    assert ok.validate(num_tiers=2) is ok
    with pytest.raises(ValueError):                     # no link 1 in 2 tiers
        FaultSchedule([FaultEvent(1.0, "partition_link", 1)]).validate(2)
    with pytest.raises(ValueError):                     # no tier 3
        FaultSchedule([FaultEvent(1.0, "crash_tier", 3)]).validate(3)


def test_link_state_overlay():
    ls = LinkState(LinkSpec(rtt_s=0.01, bandwidth_Bps=1e8))
    healthy = ls.latency_s(1e6)
    assert healthy == 0.01 + 1e6 / 1e8
    ls.apply(FaultEvent(0.0, "degrade_link", 0, bw_mult=0.1, rtt_mult=4.0))
    assert ls.latency_s(1e6) == pytest.approx(0.04 + 1e6 / 1e7)
    assert ls.effective_capacity() == pytest.approx(1e7)
    ls.apply(FaultEvent(0.0, "partition_link", 0))
    assert not ls.up and ls.effective_capacity() <= 1e-6
    ls.apply(FaultEvent(0.0, "restore_link", 0))
    assert ls.up and ls.latency_s(1e6) == healthy
    with pytest.raises(ValueError):
        ls.apply(FaultEvent(0.0, "crash_tier", 0))


def test_scenario_constructors_and_merge():
    s = merge_schedules(edge_brownout(10.0, 20.0),
                        cloud_partition(15.0, 25.0, link=1),
                        tier_outage(5.0, 30.0, tier=2), None)
    assert len(s) == 6
    assert [e.t for e in s] == sorted(e.t for e in s)
    kinds = {e.kind for e in s}
    assert kinds == {"degrade_link", "restore_link", "partition_link",
                     "crash_tier", "restore_tier"}


# ---- chaos through the simulator ------------------------------------------

_SIM = SimConfig(duration_s=90.0, low_rps=2.0, high_rps=10.0,
                 ramp_start_s=20.0, ramp_end_s=60.0, seed=0)


def test_sim_default_trace_is_ramped_poisson():
    """Passing the consolidated RampedPoisson explicitly is bit-identical
    to the simulator's built-in default arrivals (golden protection)."""
    base = ContinuumSimulator("io", "auto", _SIM).run()
    via = ContinuumSimulator(
        "io", "auto", _SIM,
        trace=RampedPoisson(_SIM.low_rps, _SIM.high_rps,
                            _SIM.ramp_start_s, _SIM.ramp_end_s)).run()
    assert base.summary() == via.summary()
    assert base.successes == via.successes and base.failures == via.failures


def test_sim_stationary_process():
    res = ContinuumSimulator("io", "auto", _SIM,
                             trace=StationaryPoisson(rps=4.0)).run()
    assert res.submitted > 0
    assert res.successes + res.failures == res.submitted


def test_sim_materialized_trace_conservation():
    tr = Trace.bursty(2.0, 24.0, 60.0, seed=9)
    res = ContinuumSimulator("io", "auto+migrate", _SIM, trace=tr).run()
    assert res.submitted == len(tr)
    assert res.successes + res.failures == res.submitted


def test_sim_brownout_conservation_and_counter():
    res = ContinuumSimulator(
        "io", "auto+net+migrate", _SIM,
        faults=edge_brownout(30.0, 60.0, bw_mult=0.02, rtt_mult=10.0)).run()
    assert res.faults_applied == 2
    assert res.successes + res.failures == res.submitted
    assert "faults_applied" in res.summary()


def test_sim_tier_crash_replays_or_fails():
    res = ContinuumSimulator("io", "auto", _SIM,
                             faults=tier_outage(25.0, 50.0, tier=1)).run()
    assert res.faults_applied == 2
    assert res.successes + res.failures == res.submitted


def test_sim_partition_migration_identity():
    """Partition the link with migrations in flight: fired ==
    completed + aborted (no transit left open after the run drains),
    and the partition actually forces aborts."""
    cfg = SimConfig(duration_s=90.0, low_rps=4.0, high_rps=16.0,
                    ramp_start_s=10.0, ramp_end_s=40.0, seed=0)
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64,
                        queue_depth_per_slot=8),
               TierSpec("cloud", slots=16, max_len=64)),
        links=(LinkSpec(rtt_s=0.05, bandwidth_Bps=1e6),))
    res = ContinuumSimulator(
        "io", "auto+migrate", cfg, topology=topo,
        faults=cloud_partition(35.0, 55.0, link=0)).run()
    assert res.successes + res.failures == res.submitted
    assert res.migrations_fired > 0                     # not vacuous
    assert res.migrations_aborted > 0                   # partition bit
    assert (res.migrations_fired
            == res.migrations_completed + res.migrations_aborted)


def test_sim_faults_validated_against_topology():
    with pytest.raises(ValueError):
        ContinuumSimulator("io", "auto", _SIM,
                           faults=FaultSchedule(
                               [FaultEvent(1.0, "crash_tier", 5)]))


def test_sim_rejects_bogus_trace():
    with pytest.raises(TypeError):
        ContinuumSimulator("io", "auto", _SIM, trace=[1.0, 2.0, 3.0])
