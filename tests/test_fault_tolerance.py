"""Checkpoint/restart, elastic resharding, preemption, data determinism."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.training import checkpoint as ckpt
from repro.training import data as data_lib
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import (LoopConfig, PreemptionError,
                                       TrainConfig, Trainer)

ARCH = "stablelm-1.6b"


def _mk_trainer(tmp, steps, fault_hook=None, seed=0):
    cfg = configs.get_smoke_config(ARCH)
    dcfg = data_lib.DataConfig(batch=4, seq_len=32, seed=seed)
    tcfg = TrainConfig(opt=OptimizerConfig(peak_lr=1e-3, warmup_steps=4,
                                           total_steps=steps))
    lcfg = LoopConfig(total_steps=steps, ckpt_dir=tmp, ckpt_every=5)
    return Trainer(cfg, tcfg, lcfg,
                   lambda s: data_lib.stream(cfg, dcfg, s),
                   seed=seed, fault_hook=fault_hook)


def test_checkpoint_atomic_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32)}}
    d = str(tmp_path)
    ckpt.save(d, 7, tree, extra={"note": "x"})
    assert ckpt.latest_step(d) == 7
    out, extra = ckpt.restore(d, 7, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert extra["note"] == "x"


def test_partial_checkpoint_ignored(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.zeros(3)}
    ckpt.save(d, 5, tree)
    # a crashed write: directory without manifest
    os.makedirs(os.path.join(d, "step_00000009"))
    assert ckpt.latest_step(d) == 5


def test_checkpoint_shape_mismatch_fails(tmp_path):
    d = str(tmp_path)
    ckpt.save(d, 1, {"a": jnp.zeros((3, 4))})
    with pytest.raises(ValueError):
        ckpt.restore(d, 1, {"a": jnp.zeros((4, 3))})
    with pytest.raises(ValueError):
        ckpt.restore(d, 1, {"b": jnp.zeros((3, 4))})


def test_gc_keeps_newest(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, {"a": jnp.zeros(2)})
    ckpt.gc_old(d, keep=2)
    assert ckpt.latest_step(d) == 5
    names = sorted(os.listdir(d))
    assert names == ["step_00000004", "step_00000005"]


def test_resume_is_bit_identical(tmp_path):
    """Uninterrupted run == crash-at-7 + resume (same data, same loss)."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    full = _mk_trainer(d1, 12).run()

    class Boom(Exception):
        pass

    def hook(step):
        if step == 7 and not getattr(hook, "fired", False):
            hook.fired = True
            raise PreemptionError("simulated node loss")

    t = _mk_trainer(d2, 12, fault_hook=hook)
    with pytest.raises(PreemptionError):
        t.run()
    # "restarted job": new Trainer instance, same ckpt dir
    t2 = _mk_trainer(d2, 12)
    assert t2.start_step == 5          # newest complete checkpoint
    out = t2.run()
    full_tail = [h for h in full["history"] if h["step"] > 5]
    resumed = out["history"]
    assert [h["step"] for h in resumed] == [h["step"] for h in full_tail]
    for a, b in zip(resumed, full_tail):
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-6), (a, b)


def test_elastic_restore_to_different_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore onto an explicit 1-device
    mesh sharding (the degenerate case of restoring onto a new mesh)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    ckpt.save(d, 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    out, _ = ckpt.restore(d, 3, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_data_stream_seekable():
    cfg = configs.get_smoke_config(ARCH)
    dcfg = data_lib.DataConfig(batch=2, seq_len=16, seed=3)
    a = [next(data_lib.stream(cfg, dcfg, i)) for i in (0, 5, 9)]
    s = data_lib.stream(cfg, dcfg, 0)
    all_batches = [next(s) for _ in range(10)]
    for got, idx in zip(a, (0, 5, 9)):
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      np.asarray(all_batches[idx]["tokens"]))


def test_straggler_ratio_reported(tmp_path):
    t = _mk_trainer(str(tmp_path), 6)
    out = t.run()
    assert out["straggler_ratio"] >= 1.0


# ---- serving-side fault tolerance: tier crash/restore through the
# ---- replication path (repro.workloads.faults x core.replication)

def _mk_continuum(**kwargs):
    from repro.core.replication import FunctionSpec
    from repro.models import model_zoo
    from repro.platform import Continuum, TierConfig

    cfg = configs.get_smoke_config(ARCH)
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    cc = Continuum(edge=TierConfig(slots=2, max_len=64),
                   cloud=TierConfig(slots=8, max_len=64),
                   seed=0, **kwargs)
    cc.deploy(FunctionSpec(name="fn", arch=ARCH), cfg, params)
    return cc


def test_serving_edge_crash_replays_residents():
    """Crashing the edge mid-decode loses its slots and backlog, but
    every resident request replays at the cloud: served-or-failed holds
    for all of them and nothing is silently lost."""
    from repro.platform import FaultEvent, Request
    from repro.serving.engine import Request as _Req  # noqa: F401

    cc = _mk_continuum(policy="auto", max_steps_per_tick=2)
    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(6):
        r = Request(rid=rid, tokens=rng.integers(0, 64, 5).astype(np.int32),
                    max_new=8)
        cc.submit("fn", r)
        reqs.append(r)
    cc.tick()                                   # residents on both tiers
    assert cc.in_flight > 0
    cc.apply_fault(FaultEvent(0.0, "crash_tier", 0))
    assert not cc.tier_up[0]
    assert cc.tiers[0].endpoints == {}          # pool wiped
    assert cc.metrics.counter("replayed") > 0 or cc.queued > 0
    cc.drain()
    for r in reqs:
        assert (r.output is not None) != r.failed, r.rid
    assert sum(1 for r in reqs if r.output is not None) == len(reqs)


def test_serving_restore_reregisters_through_replication():
    """Recovery is the replication path, not a special case: the fresh
    ReplicationController reconciles against the cloud specs, every
    function reports changed, and the redeploy (with a fresh autoscaler
    at min_scale) re-registers the edge's endpoints from the stored
    artifacts."""
    from repro.platform import FaultEvent, Request

    cc = _mk_continuum(policy="auto")
    old_rep = cc.replicators[0]
    assert old_rep.writes >= 1                  # initial deploy went through it
    cc.apply_fault(FaultEvent(0.0, "crash_tier", 0))
    fresh = cc.replicators[0]
    assert fresh is not old_rep                 # edge view was lost with the tier
    assert fresh.writes == 0
    cc.apply_fault(FaultEvent(0.0, "restore_tier", 0))
    assert cc.tier_up[0]
    assert fresh.writes == 1                    # re-registered via reconcile
    assert fresh.get("fn") is not None
    assert "fn" in cc.tiers[0].endpoints        # pool rebuilt from artifacts
    # and it actually serves again
    r = Request(rid=0, tokens=np.arange(5, dtype=np.int32), max_new=3)
    cc.submit("fn", r)
    cc.drain()
    assert r.output is not None and not r.failed


def test_serving_deep_tier_crash_survivors_stay_local():
    """The deepest tier going down leaves the shallow tier serving: its
    requests during the outage stay local (no 503s while the edge has
    capacity), and restore redeploys the cloud directly from the spec
    source."""
    from repro.platform import FaultEvent, Request

    cc = _mk_continuum(policy="auto")
    cc.apply_fault(FaultEvent(0.0, "crash_tier", 1))
    rng = np.random.default_rng(1)
    reqs = []
    for rid in range(4):
        r = Request(rid=rid, tokens=rng.integers(0, 64, 5).astype(np.int32),
                    max_new=2)
        cc.submit("fn", r)
        reqs.append(r)
    cc.drain()
    for r in reqs:
        assert r.output is not None and not r.failed
    cc.apply_fault(FaultEvent(0.0, "restore_tier", 1))
    assert "fn" in cc.tiers[1].endpoints        # direct redeploy (spec source)
