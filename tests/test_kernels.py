"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa_mod
from repro.kernels import decode_attention as dec_mod
from repro.kernels import rwkv6_scan as rwkv_mod
from repro.kernels import ssd_scan as ssd_mod

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-4)


def _assert_close(a, b, dtype):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **_tol(dtype))


# ---- flash attention --------------------------------------------------------

@pytest.mark.parametrize("B,S,T,Hq,Hkv,D", [
    (1, 16, 16, 2, 2, 8),        # MHA tiny
    (2, 64, 64, 4, 2, 16),       # GQA
    (1, 40, 72, 6, 3, 32),       # ragged (padding paths)
    (2, 128, 128, 8, 1, 64),     # MQA, aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, T, Hq, Hkv, D, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S * T + Hq), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    qp = jnp.broadcast_to(jnp.arange(T - S, T)[None], (B, S)).astype(jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    out = fa_mod.flash_attention(q, k, v, qp, kp, blk_q=32, blk_k=32,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, qp, kp)
    _assert_close(out, want, dtype)


@pytest.mark.parametrize("window", [4, 16])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_flash_attention_window_softcap(window, softcap):
    B, S, Hq, Hkv, D = 2, 48, 4, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    out = fa_mod.flash_attention(q, k, v, pos, pos, window=window,
                                 softcap=softcap, blk_q=16, blk_k=16,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, pos, pos, window=window,
                               softcap=softcap)
    _assert_close(out, want, jnp.float32)


def test_flash_attention_grad_matches_oracle():
    B, S, Hq, Hkv, D = 1, 32, 4, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    g1 = jax.grad(lambda q, k, v: ops.flash_attention(
        q, k, v, pos, pos).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: ref.flash_attention(
        q, k, v, pos, pos).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        _assert_close(a, b, jnp.float32)


# ---- decode attention -------------------------------------------------------

@pytest.mark.parametrize("B,T,Hq,Hkv,D,blk", [
    (2, 64, 4, 2, 16, 32),
    (1, 100, 8, 8, 32, 32),      # padded T
    (3, 256, 8, 2, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, T, Hq, Hkv, D, blk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, T + Hkv), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    # rolling-cache style: shuffled positions, some empty slots
    perm = jax.random.permutation(ks[3], jnp.arange(T))
    kp = jnp.where(perm > int(T * 0.9), -1, perm)[None].repeat(B, 0).astype(jnp.int32)
    qp = jnp.full((B,), int(T * 0.8), jnp.int32)
    out = dec_mod.decode_attention(q, k, v, qp, kp, blk_k=blk, interpret=True)
    want = ref.decode_attention(q, k, v, qp, kp)
    _assert_close(out, want, dtype)


def test_decode_attention_sliding_window():
    B, T, Hq, Hkv, D = 2, 96, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    kp = jnp.broadcast_to(jnp.arange(T)[None], (B, T)).astype(jnp.int32)
    qp = jnp.full((B,), T - 1, jnp.int32)
    out = dec_mod.decode_attention(q, k, v, qp, kp, window=24, blk_k=32,
                                   interpret=True)
    want = ref.decode_attention(q, k, v, qp, kp, window=24)
    _assert_close(out, want, jnp.float32)


# ---- rwkv6 ------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,D,chunk", [
    (1, 32, 2, 8, 16),
    (2, 128, 4, 16, 32),
    (2, 64, 1, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_sweep(B, S, H, D, chunk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S + D), 6)
    r = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    lw = -jnp.abs(jax.random.normal(ks[3], (B, S, H, D), jnp.float32)) * 0.4
    u = (jax.random.normal(ks[4], (H, D), jnp.float32) * 0.3)
    s0 = jax.random.normal(ks[5], (B, H, D, D), jnp.float32) * 0.1
    y, sf = rwkv_mod.rwkv6_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), lw, u, s0,
                                chunk=chunk, interpret=True)
    yr, sfr = ref.rwkv6_scan(r, k, v, lw, u, s0)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else \
        dict(atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr), **tol)


def test_rwkv6_state_carry_composes():
    """scan(S) == scan(S/2) ∘ scan(S/2) via the carried state."""
    B, S, H, D = 1, 64, 2, 8
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, D)) for i in range(3))
    lw = -jnp.abs(jax.random.normal(ks[3], (B, S, H, D))) * 0.3
    u = jax.random.normal(ks[4], (H, D)) * 0.2
    s0 = jnp.zeros((B, H, D, D))
    y_all, s_all = rwkv_mod.rwkv6_scan(r, k, v, lw, u, s0, chunk=16,
                                       interpret=True)
    h = S // 2
    y1, s1 = rwkv_mod.rwkv6_scan(r[:, :h], k[:, :h], v[:, :h], lw[:, :h],
                                 u, s0, chunk=16, interpret=True)
    y2, s2 = rwkv_mod.rwkv6_scan(r[:, h:], k[:, h:], v[:, h:], lw[:, h:],
                                 u, s1, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               atol=2e-4, rtol=1e-3)


# ---- ssd --------------------------------------------------------------------

@pytest.mark.parametrize("B,S,I,N,chunk,blk_i", [
    (1, 32, 16, 8, 16, 16),
    (2, 128, 40, 16, 32, 32),    # I padded to blk_i
    (1, 64, 256, 16, 64, 128),
])
def test_ssd_scan_sweep(B, S, I, N, chunk, blk_i):
    ks = jax.random.split(jax.random.fold_in(KEY, I + S), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, I, N)) * 2.0)
    b = jax.random.normal(ks[1], (B, S, I, N)) * 0.5
    h0 = jax.random.normal(ks[2], (B, I, N)) * 0.2
    hs, hf = ssd_mod.ssd_scan(a, b, h0, chunk=chunk, blk_i=blk_i,
                              interpret=True)
    hsr, hfr = ref.ssd_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hsr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr),
                               atol=1e-4, rtol=1e-4)


def test_ssd_strong_decay_stable():
    """Near-zero decay (the cumprod-underflow regime) stays exact."""
    B, S, I, N = 1, 128, 8, 4
    ks = jax.random.split(KEY, 2)
    a = jnp.full((B, S, I, N), 0.01)
    b = jax.random.normal(ks[0], (B, S, I, N))
    h0 = jax.random.normal(ks[1], (B, I, N))
    hs, hf = ssd_mod.ssd_scan(a, b, h0, chunk=64, blk_i=8, interpret=True)
    hsr, hfr = ref.ssd_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hsr),
                               atol=1e-5, rtol=1e-4)
