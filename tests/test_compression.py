"""Int8 error-feedback gradient compression: bias, convergence, ring."""

import functools

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:      # not installable here; deterministic shim
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:                      # older jax: experimental home,
    from jax.experimental import shard_map as _sm   # check_rep not check_vma

    def shard_map(f, **kw):
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        return _sm.shard_map(f, **kw)

from repro.training import compression
from repro.training.compression import CompressionConfig


def test_compress_decompress_error_feedback_identity():
    """q*s + err == grad + old_err (lossless bookkeeping)."""
    cfg = CompressionConfig(enabled=True)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)}
    e = {"w": jnp.asarray(rng.normal(size=(32, 16)) * 0.01, jnp.float32)}
    q, s, e2 = compression.compress(g, e, cfg)
    deq = compression.decompress(q, s)
    np.testing.assert_allclose(
        np.asarray(deq["w"] + e2["w"]), np.asarray(g["w"] + e["w"]),
        rtol=1e-5, atol=1e-6)
    assert q["w"].dtype == jnp.int8


def test_error_feedback_unbiased_over_time():
    """Accumulated dequantized sum tracks the true sum (EF property)."""
    cfg = CompressionConfig(enabled=True)
    rng = np.random.default_rng(1)
    e = {"w": jnp.zeros((64,), jnp.float32)}
    true_sum = np.zeros(64)
    deq_sum = np.zeros(64)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=64) * (1 + i % 3), jnp.float32)}
        q, s, e = compression.compress(g, e, cfg)
        deq_sum += np.asarray(compression.decompress(q, s)["w"])
        true_sum += np.asarray(g["w"])
    # residual error is bounded by one quantization step, not growing
    resid = np.abs(deq_sum - true_sum)
    scale = np.abs(true_sum).max()
    assert resid.max() < 0.05 * scale + 0.1


@hypothesis.given(st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=20, deadline=None)
def test_quantization_error_bounded(seed):
    cfg = CompressionConfig(enabled=True)
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=128), jnp.float32)}
    e = {"w": jnp.zeros(128, jnp.float32)}
    q, s, e2 = compression.compress(g, e, cfg)
    # |err| <= scale/2 per element
    assert float(jnp.max(jnp.abs(e2["w"]))) <= float(s["w"]) / 2 + 1e-7


def _mesh1d(n):
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip(f"needs {n} devices")
    return Mesh(np.array(devs[:n]), ("data",))


def test_allreduce_compressed_single_device_mean():
    """With axis size 1 the compressed all-reduce is just quantize+dequant."""
    mesh = _mesh1d(1)
    cfg = CompressionConfig(enabled=True)
    g = {"w": jnp.linspace(-1, 1, 64, dtype=jnp.float32)}
    e = {"w": jnp.zeros(64, jnp.float32)}

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()))
    def run(g, e):
        out, err = compression.allreduce_compressed(
            {"w": g}, {"w": e}, cfg, "data")
        return out["w"], err["w"]

    out, err = run(g["w"], e["w"])
    np.testing.assert_allclose(np.asarray(out + err), np.asarray(g["w"]),
                               atol=1e-6)


def test_ring_allreduce_int8_matches_psum():
    # runs on any jax: compression._pvary degrades to identity where
    # jax.lax.pvary is missing (check_rep/check_vma is off either way)
    mesh = _mesh1d(1)   # ring degenerates to identity at n=1
    x = jnp.arange(-8, 8, dtype=jnp.int8)

    # check_vma off: the compiler can't statically prove the post-all-gather
    # replication of a hand-rolled ring (every device does hold equal values)
    @functools.partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    def run(x):
        return compression.ring_allreduce_int8(x, "data")

    out = run(x)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(x, np.int32))


def test_training_converges_with_compression():
    """End-to-end: int8-EF training still reduces loss."""
    from repro import configs
    from repro.training import data as data_lib
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_loop import TrainConfig, init_state, make_train_step
    cfg = configs.get_smoke_config("stablelm-1.6b")
    tcfg = TrainConfig(
        opt=OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20),
        compression=CompressionConfig(enabled=True))
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    dcfg = data_lib.DataConfig(batch=4, seq_len=32)
    losses = []
    for i in range(12):
        state, m = step(state, data_lib.make_batch(cfg, dcfg, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
