"""Deterministic mini stand-in for ``hypothesis`` (not installable here).

The property tests in this repo only use ``given``/``settings`` and five
strategies (floats / integers / lists / text / dictionaries, plus
``.map``).  This shim draws ``max_examples`` pseudo-random examples from
a seed derived from the test name — no shrinking, no database — so the
property tests still execute (deterministically) instead of erroring the
whole module out at collection.

Usage in a test module::

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ImportError:
        from _hypothesis_fallback import hypothesis, st
"""

from __future__ import annotations

import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy is just a draw function rng -> value."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


def floats(min_value=0.0, max_value=1.0, **_):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def integers(min_value=0, max_value=100, **_):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def lists(elements, min_size=0, max_size=10, **_):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=10, **_):
    chars = list(alphabet)
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return "".join(chars[int(i)] for i in rng.integers(0, len(chars), n))
    return _Strategy(draw)


def dictionaries(keys, values, min_size=0, max_size=10, **_):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return {keys.example(rng): values.example(rng) for _ in range(n)}
    return _Strategy(draw)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_):
    def deco(f):
        f._shim_max_examples = max_examples
        return f
    return deco


def given(*strategies):
    def deco(f):
        # NB: deliberately no functools.wraps — pytest must see a zero-arg
        # signature, not the wrapped function's strategy parameters.
        def wrapper():
            n = (getattr(wrapper, "_shim_max_examples", None)
                 or getattr(f, "_shim_max_examples", None)
                 or _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                f(*(s.example(rng) for s in strategies))
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper
    return deco


def assume(condition):
    if not condition:
        raise AssertionError("assumption failed (shim has no rejection "
                             "sampling; loosen the strategy instead)")


st = types.ModuleType("hypothesis.strategies")
st.floats = floats
st.integers = integers
st.lists = lists
st.text = text
st.dictionaries = dictionaries

hypothesis = types.ModuleType("hypothesis")
hypothesis.given = given
hypothesis.settings = settings
hypothesis.assume = assume
hypothesis.strategies = st
