import os

# Tests run on the single real CPU device — the dry-run (and only the
# dry-run) forces placeholder devices. Keep any accidental inheritance out.
os.environ.pop("XLA_FLAGS", None)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
