"""Sim-live parity fuzzing: random topologies, policies, and traces.

Two properties the whole control-plane design rests on:

1. **R_t parity** — the simulator and the live runtime drive the *same*
   :class:`~repro.core.policy.ControlLoop`, so for any topology (1-4
   tiers), any policy shorthand, and any shared per-boundary trace
   (latency windows + backlog ages + crossing demand), their
   ``step_tiers`` outputs must be bit-identical at every boundary of
   every step.

2. **Conservation** — the live scheduler never loses or double-serves a
   request: after ``drain()``, every submitted request either completed
   (``output`` filled, counted served exactly once) or failed (gateway
   503), and ``submitted == served + failed`` with nothing left queued,
   slot-resident, or in a migration transfer.

Runs deterministically without hypothesis via the ``_hypothesis_fallback``
shim (each property is exercised on a seeded pseudo-random example set).
"""

import functools

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:      # not installable here; deterministic shim
    from _hypothesis_fallback import hypothesis, st

import jax
import numpy as np

from repro import configs
from repro.core.policy import AutoOffload
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.core.workloads import PROFILES
from repro.models import model_zoo
from repro.platform import Continuum, Request
from repro.workloads.faults import (KINDS, FaultEvent, FaultSchedule,
                                    LinkState)
from repro.workloads.trace import Trace

_POLICIES = (0.0, 37.5, 100.0, "auto", "auto+net", "auto+hedge",
             "auto+migrate", "auto+net+migrate")
_WORKLOADS = ("matmult", "image_proc", "io", "mixed")


@functools.lru_cache(maxsize=1)
def _model():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _topology(rng: np.random.Generator, num_tiers: int) -> Topology:
    tiers = tuple(
        TierSpec(f"t{i}", slots=int(rng.integers(1, 4)), max_len=32,
                 queue_depth_per_slot=(None if rng.uniform() < 0.3
                                       else int(rng.integers(1, 9))))
        for i in range(num_tiers))
    links = tuple(
        LinkSpec(rtt_s=float(rng.uniform(0.0, 0.05)),
                 bandwidth_Bps=float(rng.uniform(1e6, 200e6)))
        for _ in range(num_tiers - 1))
    return Topology(tiers, links, waterfall=bool(rng.uniform() < 0.5))


@hypothesis.settings(max_examples=10)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_step_tiers_parity_fuzz(seed):
    """Per-boundary R_t parity: the simulator's ControlLoop and the live
    continuum's ControlLoop produce bit-identical trajectories on any
    shared (windows, backlog-ages, crossing-demand) trace."""
    rng = np.random.default_rng(seed)
    num_tiers = int(rng.integers(1, 5))
    topo = _topology(rng, num_tiers)
    policy = _POLICIES[int(rng.integers(0, len(_POLICIES)))]
    workload = _WORKLOADS[int(rng.integers(0, len(_WORKLOADS)))]
    window = int(rng.integers(8, 65))

    sim = ContinuumSimulator(workload, policy,
                             SimConfig(duration_s=1.0, window=window),
                             topology=topo)
    cfg, params = _model()
    # the same payload hint the simulator derives from its profile, so
    # auto+net caps divide the links identically on both sides
    cc = Continuum.from_topology(topo, policy=policy, seed=seed,
                                 window=window,
                                 req_bytes=PROFILES[workload].payload_bytes)
    cc.deploy(FunctionSpec(name=workload, arch="stablelm-1.6b"),
              cfg, params)

    assert cc.control.num_boundaries == sim.control.num_boundaries
    B = sim.control.num_boundaries
    for step in range(8):
        lats = [rng.lognormal(-2.0, 1.0, (1, window)).astype(np.float32)
                for _ in range(B)]
        valids = [rng.uniform(size=(1, window)) < rng.uniform(0.2, 1.0)
                  for _ in range(B)]
        qages = [[list(rng.uniform(0.05, 6.0,
                                   size=int(rng.integers(0, 5))))]
                 for _ in range(B)]
        arrivals = [[float(rng.integers(0, 12))] for _ in range(B)]
        R_sim = np.array(sim.control.step_tiers(
            lats, valids, queue_ages=qages, arrivals=arrivals))
        R_live = np.array(cc.control.step_tiers(
            lats, valids, queue_ages=qages, arrivals=arrivals))
        np.testing.assert_array_equal(R_sim, R_live)


@hypothesis.settings(max_examples=6)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_conservation_after_drain_fuzz(seed):
    """submitted == served + rejected/failed + queued + in_flight, and
    after drain() the queued/in-flight/in-transit terms are all zero:
    every request either completed exactly once or failed loudly."""
    rng = np.random.default_rng(seed)
    cfg, params = _model()
    num_tiers = int(rng.integers(1, 4))
    tiers = tuple(
        TierSpec(f"t{i}", slots=int(rng.integers(1, 3)), max_len=32,
                 queue_depth_per_slot=(None if i == num_tiers - 1
                                       else int(rng.integers(1, 4))))
        for i in range(num_tiers))
    topo = Topology(tiers,
                    tuple(LinkSpec(rtt_s=0.0)
                          for _ in range(num_tiers - 1)),
                    waterfall=bool(rng.uniform() < 0.5))
    policy = _POLICIES[int(rng.integers(0, len(_POLICIES)))]
    cc = Continuum.from_topology(
        topo, policy=policy, seed=seed,
        max_waves_per_tick=(None if rng.uniform() < 0.5
                            else int(rng.integers(1, 3))),
        max_steps_per_tick=(None if rng.uniform() < 0.5
                            else int(rng.integers(1, 4))))
    cc.deploy(FunctionSpec(
        name="fn", arch="stablelm-1.6b",
        autoscaling=AutoscalingPolicy()), cfg, params)

    reqs, rid = [], 0
    for _ in range(int(rng.integers(1, 4))):          # a few bursts
        for _ in range(int(rng.integers(1, 5))):
            r = Request(rid=rid,
                        tokens=rng.integers(0, 64, 5).astype(np.int32),
                        max_new=int(rng.integers(1, 5)))
            cc.submit("fn", r)
            reqs.append(r)
            rid += 1
        cc.tick()
    cc.drain()

    assert cc.queued == 0 and cc.in_flight == 0
    assert cc.migrations_open == 0
    served = sum(sum(r["tiers"].values()) for r in cc.log)
    failed = sum(r.failed for r in reqs)
    # completed XOR failed, for every submitted request
    for r in reqs:
        assert (r.output is not None) != r.failed, r.rid
    assert served + failed == rid
    # hedge/migration accounting identities survive the whole run
    c = cc.metrics.counter
    assert c("hedges_fired") == (c("hedges_won") + c("hedges_cancelled")
                                 + cc.hedges_open)
    assert c("migrations_fired") == (c("migrations_completed")
                                     + c("migrations_aborted")
                                     + cc.migrations_open)


def _random_faults(rng: np.random.Generator, num_tiers: int,
                   horizon_s: float) -> FaultSchedule:
    """A random but always-valid fault script over ``num_tiers`` tiers.

    Every degrade/partition/crash is paired with a restore before the
    horizon, so the run always ends on a healthy (or at least reachable)
    continuum and drain() has somewhere to put the survivors."""
    events = []
    for _ in range(int(rng.integers(1, 4))):
        kind = KINDS[int(rng.integers(0, len(KINDS)))]
        if kind in ("degrade_link", "partition_link", "restore_link"):
            if num_tiers < 2:
                continue
            target = int(rng.integers(0, num_tiers - 1))
        else:
            target = int(rng.integers(0, num_tiers))
            kind = "crash_tier"
        t0 = float(rng.uniform(0.0, horizon_s * 0.5))
        t1 = float(rng.uniform(t0 + 0.5, horizon_s * 0.8))
        if kind == "degrade_link":
            events.append(FaultEvent(t0, kind, target,
                                     bw_mult=float(rng.uniform(0.01, 0.5)),
                                     rtt_mult=float(rng.uniform(1.0, 20.0))))
            events.append(FaultEvent(t1, "restore_link", target))
        elif kind == "partition_link":
            events.append(FaultEvent(t0, kind, target))
            events.append(FaultEvent(t1, "restore_link", target))
        elif kind == "crash_tier":
            events.append(FaultEvent(t0, kind, target))
            events.append(FaultEvent(t1, "restore_tier", target))
    return FaultSchedule(events)


@hypothesis.settings(max_examples=6)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_conservation_under_faults_fuzz(seed):
    """Chaos never breaks conservation: under a random fault schedule
    (link degradation, partitions, tier crashes mid-run) every submitted
    request still ends served-or-failed exactly once, with nothing left
    queued, slot-resident, or stuck in a migration transfer."""
    rng = np.random.default_rng(seed + 77_000)
    cfg, params = _model()
    num_tiers = int(rng.integers(1, 4))
    tiers = tuple(
        TierSpec(f"t{i}", slots=int(rng.integers(1, 3)), max_len=32,
                 queue_depth_per_slot=(None if i == num_tiers - 1
                                       else int(rng.integers(1, 4))))
        for i in range(num_tiers))
    topo = Topology(tiers,
                    tuple(LinkSpec(rtt_s=0.0)
                          for _ in range(num_tiers - 1)),
                    waterfall=bool(rng.uniform() < 0.5))
    policy = _POLICIES[int(rng.integers(0, len(_POLICIES)))]
    horizon = 8.0
    trace = Trace.poisson(rps=float(rng.uniform(1.0, 4.0)),
                          duration_s=horizon, fn_names=("fn",),
                          seed=seed, prompt_len=5,
                          max_new=int(rng.integers(1, 5)))
    faults = _random_faults(rng, num_tiers, horizon)
    cc = Continuum.from_topology(
        topo, policy=policy, seed=seed, trace=trace, faults=faults,
        max_steps_per_tick=(None if rng.uniform() < 0.5
                            else int(rng.integers(1, 4))))
    cc.deploy(FunctionSpec(
        name="fn", arch="stablelm-1.6b",
        autoscaling=AutoscalingPolicy()), cfg, params)

    for _ in range(int(horizon) + 4):
        cc.tick()
    cc.drain()

    assert cc.queued == 0 and cc.in_flight == 0
    assert cc.migrations_open == 0
    reqs = cc.trace_requests
    assert len(reqs) == len(trace)                 # all rows submitted
    for r in reqs:                                 # completed XOR failed
        assert (r.output is not None) != r.failed, r.rid
    served = sum(1 for r in reqs if r.output is not None)
    failed = sum(1 for r in reqs if r.failed)
    assert served + failed == len(reqs)
    c = cc.metrics.counter
    assert c("hedges_fired") == (c("hedges_won") + c("hedges_cancelled")
                                 + cc.hedges_open)
    assert c("migrations_fired") == (c("migrations_completed")
                                     + c("migrations_aborted")
                                     + cc.migrations_open)
    if len(faults):
        assert c("faults_applied") == len(faults)


@hypothesis.settings(max_examples=6)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_step_tiers_parity_with_degraded_link(seed):
    """R_t parity survives a degraded link: the live runtime's
    apply_fault() re-caps its net-aware policies exactly the way the
    simulator's _FAULT handler does, so both ControlLoops keep producing
    bit-identical trajectories after the brownout."""
    rng = np.random.default_rng(seed + 33_000)
    num_tiers = int(rng.integers(2, 5))
    topo = _topology(rng, num_tiers)
    policy = ("auto+net", "auto+net+migrate")[int(rng.integers(0, 2))]
    workload = _WORKLOADS[int(rng.integers(0, len(_WORKLOADS)))]
    window = int(rng.integers(8, 33))

    sim = ContinuumSimulator(workload, policy,
                             SimConfig(duration_s=1.0, window=window),
                             topology=topo)
    cfg, params = _model()
    cc = Continuum.from_topology(topo, policy=policy, seed=seed,
                                 window=window,
                                 req_bytes=PROFILES[workload].payload_bytes)
    cc.deploy(FunctionSpec(name=workload, arch="stablelm-1.6b"),
              cfg, params)

    B = sim.control.num_boundaries
    link = int(rng.integers(0, num_tiers - 1))
    ev = FaultEvent(0.0, "degrade_link", link,
                    bw_mult=float(rng.uniform(0.01, 0.2)),
                    rtt_mult=float(rng.uniform(2.0, 10.0)))
    # live side: the real fault path
    cc.apply_fault(ev)
    # sim side: what the simulator's _FAULT event handler does
    ls = LinkState(topo.links[link])
    ls.apply(ev)
    pol = sim.control.policies[link]
    assert isinstance(pol, AutoOffload)
    assert pol.set_link_capacity(ls.effective_capacity())

    for step in range(6):
        lats = [rng.lognormal(-2.0, 1.0, (1, window)).astype(np.float32)
                for _ in range(B)]
        valids = [rng.uniform(size=(1, window)) < rng.uniform(0.2, 1.0)
                  for _ in range(B)]
        qages = [[list(rng.uniform(0.05, 6.0,
                                   size=int(rng.integers(0, 5))))]
                 for _ in range(B)]
        arrivals = [[float(rng.integers(0, 12))] for _ in range(B)]
        R_sim = np.array(sim.control.step_tiers(
            lats, valids, queue_ages=qages, arrivals=arrivals))
        R_live = np.array(cc.control.step_tiers(
            lats, valids, queue_ages=qages, arrivals=arrivals))
        np.testing.assert_array_equal(R_sim, R_live)


# --------------------------------------------------------------------------
# paged KV pool: bit-identity with the dense layout
# --------------------------------------------------------------------------


def _prompt_pool(rng: np.random.Generator, n: int = 3):
    """A few fixed prompts reused across requests (drives prefix hits)."""
    return [rng.integers(0, 64, int(L)).astype(np.int32)
            for L in rng.integers(3, 14, n)]


@hypothesis.settings(max_examples=3)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_paged_vs_dense_engine_stream_fuzz(seed):
    """Token bit-identity under continuous-batching churn: a dense and a
    paged endpoint driven through the same random admit/decode/retire
    schedule (random prompt lengths, prompt reuse for prefix hits, slots
    retiring mid-stream) emit identical token streams at every step."""
    from repro.serving.engine import Endpoint
    rng = np.random.default_rng(seed)
    cfg, params = _model()
    slots, max_len, page = 3, 32, 8
    dense = Endpoint(cfg, params, slots=slots, max_len=max_len)
    paged = Endpoint(cfg, params, slots=slots, max_len=max_len,
                     paged=True, page_size=page)
    pool = _prompt_pool(rng)
    active = {}                       # slot -> [remaining, last_token]
    for _ in range(24):
        if len(active) < slots and rng.uniform() < 0.5:
            toks = (pool[int(rng.integers(0, len(pool)))]
                    if rng.uniform() < 0.5 else
                    rng.integers(0, 64,
                                 int(rng.integers(1, 16))).astype(np.int32))
            need = int(rng.integers(1, 7))
            sd = dense.try_claim(tokens=toks, max_new=need)
            sp = paged.try_claim(tokens=toks, max_new=need)
            # default pool (slots full rows): page admission never binds
            # tighter than slots, so the claims march in lockstep
            assert sd == sp and sd is not None
            fd = dense.prefill_batch({sd: toks})[sd]
            fp = paged.prefill_batch({sp: toks})[sp]
            assert fd == fp
            active[sd] = [need - 1, fd]
        retire = [s for s, (rem, _) in active.items() if rem <= 0]
        for s in retire:
            dense.release(s)
            paged.release(s)
            del active[s]
        if active and rng.uniform() < 0.9:
            cur = {s: tok for s, (_, tok) in active.items()}
            nd = dense.decode_all(dict(cur))
            np_ = paged.decode_all(dict(cur))
            assert nd == np_
            for s in active:
                active[s] = [active[s][0] - 1, nd[s]]
    for s in active:
        dense.release(s)
        paged.release(s)
    assert paged.pool.check_balanced()
    assert paged.prefill_total_tokens > 0


def test_paged_cow_keeps_shared_prefix_frozen():
    """Two requests share a prompt's prefix pages; one decodes past the
    fork point — the other's view of those pages stays bit-frozen (the
    write landed in a copy-on-write fork, not the shared page)."""
    import jax.numpy as jnp
    from repro.serving.engine import Endpoint
    cfg, params = _model()
    ep = Endpoint(cfg, params, slots=2, max_len=32, paged=True, page_size=8)
    rng = np.random.default_rng(11)
    toks = rng.integers(0, 64, 12).astype(np.int32)   # 1 full + 1 partial pg

    s0 = ep.try_claim(tokens=toks, max_new=10)
    f0 = ep.prefill_batch({s0: toks})[s0]
    s1 = ep.try_claim(tokens=toks, max_new=10)        # registry hit
    f1 = ep.prefill_batch({s1: toks})[s1]
    assert f1 == f0
    t0, t1 = ep._tables[s0], ep._tables[s1]
    # the full prefix page is physically shared; the partial fork page
    # was copy-on-write forked at claim, so each row owns its own
    assert t0[0] == t1[0] and ep.pool.is_shared(t0[0])
    assert t0[1] != t1[1]

    snap = [np.asarray(l) for l in
            ep._take_pages(ep.cache, jnp.asarray(t1, jnp.int32))]
    cur = {s0: f0}
    for _ in range(8):                 # s0 decodes well past the fork
        cur = ep.decode_all(cur)
    after = [np.asarray(l) for l in
             ep._take_pages(ep.cache, jnp.asarray(t1, jnp.int32))]
    for a, b in zip(snap, after):
        np.testing.assert_array_equal(a, b)
    # ...and s1 decodes on to the same stream a lone request would get
    cur1 = {s1: f1}
    for _ in range(3):
        cur1 = ep.decode_all(cur1)
    ep.release(s0)
    ep.release(s1)
    assert ep.pool.check_balanced()


def test_paged_row_migration_midstream():
    """A paged row extracted mid-stream and inserted into a peer paged
    endpoint resumes the exact token stream a dense endpoint produces,
    and the shipped payload is strictly smaller than a dense full row."""
    from repro.serving.engine import Endpoint
    cfg, params = _model()
    rng = np.random.default_rng(23)
    toks = rng.integers(0, 64, 9).astype(np.int32)
    total_new = 9

    dense = Endpoint(cfg, params, slots=2, max_len=32)
    sd = dense.try_claim(tokens=toks, max_new=total_new)
    want = [dense.prefill_batch({sd: toks})[sd]]
    for _ in range(total_new - 1):
        want.append(dense.decode_all({sd: want[-1]})[sd])

    src = Endpoint(cfg, params, slots=2, max_len=32, paged=True, page_size=8)
    dst = Endpoint(cfg, params, slots=2, max_len=32, paged=True, page_size=8)
    ss = src.try_claim(tokens=toks, max_new=total_new)
    got = [src.prefill_batch({ss: toks})[ss]]
    for _ in range(3):
        got.append(src.decode_all({ss: got[-1]})[ss])
    state, = src.extract_rows([ss])
    pos = int(src.slot_pos[ss])
    d_state, = dense.extract_rows([sd])
    assert state.nbytes < float(sum(l.nbytes for l in d_state))
    remaining = total_new - len(got)
    sr = dst.try_claim(reserve_tokens=pos + remaining)
    assert sr is not None
    dst.insert_rows([state], [sr], [pos])
    src.release(ss)
    for _ in range(remaining):
        got.append(dst.decode_all({sr: got[-1]})[sr])
    assert got == want
    dst.release(sr)
    dense.release(sd)
    assert src.pool.check_balanced() and dst.pool.check_balanced()


@hypothesis.settings(max_examples=3)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_paged_continuum_output_parity_fuzz(seed):
    """Continuum-level bit-identity: the same request set played through
    a dense-tier arm and a paged-tier arm (default pool, unbounded
    gateway so nothing 503s) completes with identical per-request token
    outputs, including duplicated prompts riding the prefix cache."""
    rng = np.random.default_rng(seed + 55_000)
    cfg, params = _model()

    def _arm(page_size):
        topo = Topology(
            (TierSpec("t0", slots=2, max_len=32, page_size=page_size,
                      queue_depth_per_slot=None),), (), waterfall=False)
        cc = Continuum.from_topology(topo, policy=0.0, seed=seed)
        cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b",
                               autoscaling=AutoscalingPolicy()), cfg, params)
        return cc

    prompts = _prompt_pool(rng)
    sizes = [(int(rng.integers(0, len(prompts))), int(rng.integers(1, 5)))
             for _ in range(int(rng.integers(4, 9)))]
    arms = []
    for page_size in (None, 8):
        cc = _arm(page_size)
        reqs = []
        for rid, (pi, mn) in enumerate(sizes):
            r = Request(rid=rid, tokens=prompts[pi].copy(), max_new=mn)
            assert cc.submit("fn", r)
            reqs.append(r)
        for _ in range(4):
            cc.tick()
        cc.drain()
        arms.append((cc, reqs))
    (cc_d, reqs_d), (cc_p, reqs_p) = arms
    for rd, rp in zip(reqs_d, reqs_p):
        assert not rd.failed and not rp.failed
        np.testing.assert_array_equal(rd.output, rp.output)
    ep = cc_p.tiers[0].endpoints["fn"]
    assert ep.pool.check_balanced()
    if len({pi for pi, _ in sizes}) < len(sizes):     # any duplicate prompt
        assert ep.prefill_hit_rate > 0.0


@hypothesis.settings(max_examples=4)
@hypothesis.given(st.integers(min_value=0, max_value=10_000))
def test_paged_conservation_under_page_exhaustion_fuzz(seed):
    """Conservation survives a page-starved tier: with a pool of a few
    pages behind a bounded gateway, every submitted request still ends
    served-or-failed exactly once and the pool drains balanced."""
    rng = np.random.default_rng(seed + 66_000)
    cfg, params = _model()
    num_tiers = int(rng.integers(1, 3))
    tiers = tuple(
        TierSpec(f"t{i}", slots=int(rng.integers(2, 4)), max_len=32,
                 page_size=8, pool_pages=int(rng.integers(4, 7)),
                 queue_depth_per_slot=(None if i == num_tiers - 1
                                       else int(rng.integers(1, 4))))
        for i in range(num_tiers))
    topo = Topology(tiers,
                    tuple(LinkSpec(rtt_s=0.0)
                          for _ in range(num_tiers - 1)),
                    waterfall=bool(rng.uniform() < 0.5))
    policy = _POLICIES[int(rng.integers(0, len(_POLICIES)))]
    cc = Continuum.from_topology(
        topo, policy=policy, seed=seed,
        max_steps_per_tick=(None if rng.uniform() < 0.5
                            else int(rng.integers(1, 4))))
    cc.deploy(FunctionSpec(
        name="fn", arch="stablelm-1.6b",
        autoscaling=AutoscalingPolicy()), cfg, params)

    reqs, rid = [], 0
    for _ in range(int(rng.integers(2, 4))):
        for _ in range(int(rng.integers(2, 6))):
            # prompts sized so a few-page pool holds 1-2 rows at once
            r = Request(rid=rid,
                        tokens=rng.integers(0, 64, int(
                            rng.integers(4, 20))).astype(np.int32),
                        max_new=int(rng.integers(1, 6)))
            cc.submit("fn", r)
            reqs.append(r)
            rid += 1
        cc.tick()
    cc.drain()

    assert cc.queued == 0 and cc.in_flight == 0
    assert cc.migrations_open == 0
    served = sum(sum(r["tiers"].values()) for r in cc.log)
    failed = sum(r.failed for r in reqs)
    for r in reqs:
        assert (r.output is not None) != r.failed, r.rid
    assert served + failed == rid
    for tier in cc.tiers:
        ep = tier.endpoints["fn"]
        assert ep.pool.check_balanced()
        assert ep.active == 0
