"""The unified Policy/ControlPlane API: parse round-trips, simulator/live
ControlLoop equivalence, hedging semantics, batched serving, and the live
autoscaler path."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core import offload, router
from repro.core.policy import (AutoOffload, ControlLoop, HedgedOffload,
                               NetAwareOffload, Policy, StaticSplit)
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.models import model_zoo
from repro.platform import Continuum
from repro.serving.engine import Endpoint, Request
from repro.serving.tiers import TierConfig


# ---- Policy.parse -----------------------------------------------------------

def test_parse_static_from_number_and_string():
    for spec in (0.0, 25, 50.0, "75", "100.0"):
        pol = Policy.parse(spec)
        assert isinstance(pol, StaticSplit)
        assert pol.pct == float(spec)


def test_parse_auto_variants():
    assert type(Policy.parse("auto")) is AutoOffload
    net = Policy.parse("auto+net")
    assert isinstance(net, NetAwareOffload) and net.cfg.net_aware
    assert isinstance(Policy.parse("auto+hedge"), HedgedOffload)


def test_parse_roundtrips_via_spec():
    for spec in ("auto", "auto+net", "auto+hedge", 37.5):
        pol = Policy.parse(spec)
        again = Policy.parse(pol.spec)
        assert type(again) is type(pol)
        if isinstance(pol, StaticSplit):
            assert again.pct == pol.pct


def test_parse_passthrough_and_errors():
    pol = AutoOffload()
    assert Policy.parse(pol) is pol
    with pytest.raises(ValueError):
        Policy.parse("definitely-not-a-policy")
    with pytest.raises(ValueError):
        Policy.parse(150.0)
    with pytest.raises(ValueError):
        Policy.parse("auto+warp")


def test_parse_net_aware_takes_link_parameters():
    pol = Policy.parse("auto+net", link_bytes_per_s=5e6, req_bytes=2e5)
    assert pol.cfg.link_bytes_per_s == 5e6 and pol.cfg.req_bytes == 2e5


def test_parse_net_plus_hedge_composes():
    pol = Policy.parse("auto+net+hedge", link_bytes_per_s=1e6)
    assert isinstance(pol, HedgedOffload)
    assert pol.cfg.net_aware and pol.cfg.link_bytes_per_s == 1e6
    assert type(Policy.parse(pol.spec)) is HedgedOffload  # round-trips


# ---- ControlLoop ------------------------------------------------------------

def test_static_control_loop_holds_percentage():
    loop = ControlLoop(StaticSplit(40.0), 2, window=16)
    np.testing.assert_allclose(loop.R, 40.0)
    lat = np.random.default_rng(0).lognormal(-2, 1, (2, 16)).astype(np.float32)
    R = loop.step(lat, np.ones_like(lat, bool))
    np.testing.assert_allclose(R, 40.0)


def test_queue_age_mixing_displaces_oldest():
    lat = np.full((1, 8), 0.5, np.float32)
    valid = np.zeros((1, 8), bool)
    ControlLoop.mix_queue_ages(lat, valid, 0, [3.0, 2.0, 1.0], window=8)
    # window//2 = 4 >= len(ages): all three ages land on the oldest slots
    np.testing.assert_allclose(lat[0, :3], [3.0, 2.0, 1.0])
    assert valid[0, :3].all() and not valid[0, 3:].any()


def test_route_matches_router_extremes():
    loop = ControlLoop(StaticSplit(0.0), 2)
    fn_ids = np.asarray([0, 1, 0, 1, 0], np.int32)
    key = jax.random.PRNGKey(0)
    R = np.asarray([100.0, 0.0], np.float32)
    mask = loop.policy.route(key, R, fn_ids, 2)
    assert mask.shape == (5,)
    assert mask[fn_ids == 0].all() and not mask[fn_ids == 1].any()


# ---- live harness (module-scoped: one deploy) -------------------------------

@pytest.fixture(scope="module")
def continuum():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    cc = Continuum(edge=TierConfig(slots=2, max_len=64),
                   cloud=TierConfig(slots=8, max_len=64),
                   policy="auto", seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    return cc


def test_sim_and_live_control_loops_identical(continuum):
    """The tentpole claim: simulator and live runtime run the SAME control
    loop — a shared latency trace yields identical R_t trajectories."""
    sim = ContinuumSimulator("matmult", "auto", SimConfig(duration_s=10.0))
    live_loop = continuum.control
    assert isinstance(sim.control, ControlLoop)
    assert isinstance(live_loop, ControlLoop)
    rng = np.random.default_rng(42)
    R_sim, R_live = [], []
    for t in range(25):
        lat = rng.lognormal(-2, 0.8, (1, 64)).astype(np.float32)
        valid = rng.uniform(size=(1, 64)) < 0.9
        ages = list(rng.uniform(0.1, 4.0, size=t % 5))
        arr = [float(t % 7)]
        R_sim.append(sim.control.step(lat, valid, [ages], arr).copy())
        R_live.append(live_loop.step(lat, valid, [ages], arr).copy())
    np.testing.assert_array_equal(np.asarray(R_sim), np.asarray(R_live))
    assert np.asarray(R_sim).max() > 0.0     # the trace actually engages


def test_batched_tick_shares_decode_stream(continuum):
    rid0 = 1000
    for i in range(4):
        continuum.submit("fn", Request(
            rid=rid0 + i, tokens=np.arange(1, 7, dtype=np.int32) + i,
            max_new=3))
    rec = continuum.tick()
    assert rec["edge"] + rec["cloud"] == 4      # nothing dropped or stolen
    assert rec["waves"] < 4                     # requests shared waves


def test_batched_matches_serial_streams(continuum):
    """Co-scheduled decode must emit the same tokens as serial serving."""
    ep: Endpoint = continuum.cloud.endpoints["fn"]
    prompts = {0: np.arange(5, 13, dtype=np.int32),
               1: np.arange(40, 48, dtype=np.int32)}
    s0, s1 = ep.try_claim(), ep.try_claim()
    firsts = ep.prefill_batch({s0: prompts[0], s1: prompts[1]})
    batched = {s0: [firsts[s0]], s1: [firsts[s1]]}
    toks = dict(firsts)
    for _ in range(3):
        toks = ep.decode_all(toks)
        for s in (s0, s1):
            batched[s].append(toks[s])
    ep.release(s0), ep.release(s1)
    for i, prompt in prompts.items():
        slot = ep.try_claim()
        serial = [ep.prefill_one(slot, prompt)]
        tk = {slot: serial[0]}
        for _ in range(3):
            tk = ep.decode_all(tk)
            serial.append(tk[slot])
        ep.release(slot)
        assert serial == batched[(s0, s1)[i]], f"prompt {i} diverged"


def test_no_slot_stealing(continuum):
    """Oversubscribing a tier raises instead of clobbering slot 0."""
    tier = continuum.edge                       # 2 slots
    reqs = [(Request(rid=2000 + i, tokens=np.arange(6, dtype=np.int32),
                     max_new=1), 0.0) for i in range(3)]
    with pytest.raises(RuntimeError):
        tier.serve_batch("fn", reqs)
    assert tier.endpoints["fn"].active == 0     # claims were rolled back


# ---- recurrent-state families ----------------------------------------------

@pytest.fixture(scope="module")
def rwkv_endpoint():
    cfg = configs.get_smoke_config("rwkv6-7b")
    params = model_zoo.init(jax.random.PRNGKey(2), cfg)
    return Endpoint(cfg, params, slots=2, max_len=32)


def _serve_alone(ep, prompt, steps=3):
    slot = ep.try_claim()
    out = [ep.prefill_one(slot, prompt)]
    tk = {slot: out[0]}
    for _ in range(steps):
        tk = ep.decode_all(tk)
        out.append(tk[slot])
    ep.release(slot)
    return out


def test_recurrent_slot_reuse_is_stateless(rwkv_endpoint):
    """Reusing a slot must not leak the previous request's RWKV state.

    Note the rwkv6 smoke config has num_layers == slots == 2, so this also
    pins the per-leaf batch-axis detection (a leading layer axis must not
    be mistaken for the slot axis)."""
    ep = rwkv_endpoint
    a = np.arange(3, 9, dtype=np.int32)
    b = np.arange(20, 26, dtype=np.int32)
    first = _serve_alone(ep, a)
    _serve_alone(ep, b)                      # pollute the slot
    again = _serve_alone(ep, a)
    assert first == again


def test_recurrent_mixed_length_wave_matches_serial(rwkv_endpoint):
    """A later length group's packed prefill must not advance the state of
    same-wave rows that were prefilled earlier (or are still waiting)."""
    ep = rwkv_endpoint
    short = np.arange(2, 6, dtype=np.int32)
    long = np.arange(7, 15, dtype=np.int32)
    s0, s1 = ep.try_claim(), ep.try_claim()
    firsts = ep.prefill_batch({s0: short, s1: long})
    streams = {s0: [firsts[s0]], s1: [firsts[s1]]}
    tk = dict(firsts)
    for _ in range(3):
        tk = ep.decode_all(tk)
        for s in (s0, s1):
            streams[s].append(tk[s])
    ep.release(s0), ep.release(s1)
    assert streams[s0] == _serve_alone(ep, short)
    assert streams[s1] == _serve_alone(ep, long)


# ---- hedging ----------------------------------------------------------------

def test_hedged_offload_targets_stragglers():
    pol = HedgedOffload()
    lat = np.full((1, 64), 0.1, np.float32)
    valid = np.ones((1, 64), bool)
    ages = np.asarray([0.01, 5.0, 0.02, 0.3], np.float32)
    fn_ids = np.zeros(4, np.int32)
    mask = pol.hedge(jax.random.PRNGKey(0), ages, fn_ids, lat, valid)
    np.testing.assert_array_equal(mask, [False, True, False, True])


def test_hedged_offload_never_hedges_blind():
    pol = HedgedOffload()
    lat = np.zeros((1, 64), np.float32)
    valid = np.zeros((1, 64), bool)             # nothing observed yet
    ages = np.asarray([100.0], np.float32)
    mask = pol.hedge(jax.random.PRNGKey(0), ages, np.zeros(1, np.int32),
                     lat, valid)
    assert not mask.any()


def test_hedged_mask_is_deterministic_rule():
    key = jax.random.PRNGKey(3)
    lat = np.asarray([0.1, 5.0, 0.1], np.float32)
    p99 = np.asarray([1.0], np.float32)
    fn_ids = np.zeros(3, np.int32)
    m1 = np.asarray(router.hedged_mask(key, lat, p99, fn_ids))
    m2 = np.asarray(router.hedged_mask(jax.random.PRNGKey(9), lat, p99,
                                       fn_ids))
    np.testing.assert_array_equal(m1, m2)       # key is API symmetry only
    np.testing.assert_array_equal(m1, [False, True, False])


# ---- live autoscaler --------------------------------------------------------

@pytest.fixture(scope="module")
def scaled_continuum():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(1), cfg)
    tier = dict(slots=4, max_len=64, stable_window_s=3.0, panic_window_s=1.0)
    cc = Continuum(edge=TierConfig(**tier), cloud=TierConfig(**tier),
                   policy=0.0, seed=0)
    cc.deploy(FunctionSpec(
        name="fn", arch="stablelm-1.6b",
        autoscaling=AutoscalingPolicy(min_scale=0, max_scale=4,
                                      target_concurrency=1.0,
                                      scale_to_zero_grace_s=2.0)),
        cfg, params)
    return cc


def test_autoscaler_scales_up_under_load(scaled_continuum):
    cc = scaled_continuum
    assert cc.edge.replicas("fn") == 0          # starts scaled to zero
    for i in range(4):
        cc.submit("fn", Request(rid=i, tokens=np.arange(6, dtype=np.int32),
                                max_new=1))
    rec = cc.tick()
    assert rec["edge"] == 4                     # scale-from-zero same tick
    assert cc.edge.replicas("fn") >= 2
    assert rec["replicas"]["edge"]["fn"] == cc.edge.replicas("fn")


def test_autoscaler_scales_to_zero_when_idle(scaled_continuum):
    cc = scaled_continuum
    for _ in range(8):                          # > stable window + grace
        cc.tick()
    assert cc.edge.replicas("fn") == 0
    assert cc.cloud.replicas("fn") == 0
    # and wakes back up for a late request
    cc.submit("fn", Request(rid=99, tokens=np.arange(6, dtype=np.int32),
                            max_new=1))
    rec = cc.tick()
    assert rec["edge"] + rec["cloud"] == 1
    assert cc.edge.replicas("fn") >= 1


def test_wave_budget_leaves_backlog(scaled_continuum):
    """Capping waves per tick leaves a backlog whose queue ages the next
    scrape mixes into Eq (1) — the live onset signal."""
    cc = scaled_continuum
    cc.max_waves_per_tick = 1
    try:
        for i in range(6):
            cc.submit("fn", Request(rid=200 + i,
                                    tokens=np.arange(6, dtype=np.int32),
                                    max_new=1))
        served = cc.tick()
        served_total = served["edge"] + served["cloud"]
        assert served["waves"] == 1
        assert len(cc.queue) == 6 - served_total > 0
        for _ in range(10):
            if not cc.queue:
                break
            rec = cc.tick()
            served_total += rec["edge"] + rec["cloud"]
        assert served_total == 6 and not cc.queue
    finally:
        cc.max_waves_per_tick = None
