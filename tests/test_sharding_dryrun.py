"""Distribution-layer tests: axis rules, spec builders, and a reduced
dry-run (4 placeholder devices via subprocess so the main test process
keeps its single real device)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis, hlo_cost
from repro.launch import sharding as rules_lib
from repro.models import model_zoo
from repro.sharding import AxisRules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh22():
    # a fake 2x2 mesh built on one device is enough for spec construction
    dev = np.array(jax.devices()[:1] * 4).reshape(2, 2)
    return Mesh(dev, ("data", "model"))


def test_axis_rules_divisibility_fallback():
    mesh = _mesh22()
    rules = AxisRules(mesh, {"heads": "model", "embed": "data"})
    # 8 heads on a 2-way axis shard; 7 heads fall back to replication
    assert rules.spec(("embed", "heads"), (8, 8)) == P("data", "model")
    assert rules.spec(("embed", "heads"), (8, 7)) == P("data")
    # tuple mapping drops trailing axes until it divides; a surviving
    # single mesh axis collapses to the scalar form (P("data"), not
    # P(("data",)) — older jax PartitionSpec treats those as unequal)
    rules2 = AxisRules(mesh, {"batch": ("data", "model")})
    assert rules2.spec(("batch",), (4,)) == P(("data", "model"))
    assert rules2.spec(("batch",), (2,)) == P("data")
    assert rules2.spec(("batch",), (1,)) == P()


def test_axis_rules_no_axis_reuse():
    mesh = _mesh22()
    rules = AxisRules(mesh, {"a": "model", "b": "model"})
    # the same mesh axis can't shard two dims; the later one loses
    assert rules.spec(("a", "b"), (4, 4)) == P("model")


def test_param_shardings_cover_every_param():
    mesh = _mesh22()
    for arch in ("qwen2.5-14b", "rwkv6-7b", "qwen2-moe-a2.7b", "hymba-1.5b"):
        cfg = configs.get_config(arch)
        sh = rules_lib.param_shardings(cfg, mesh, "train")
        table = model_zoo.param_table(cfg)
        assert set(sh) == set(table)
        for path, spec in table.items():
            nd = len(spec.shape)
            assert len(sh[path].spec) <= nd, path


def test_cache_shardings_match_cache_tree():
    mesh = _mesh22()
    for arch in ("qwen2.5-14b", "rwkv6-7b", "hymba-1.5b"):
        cfg = configs.get_config(arch)
        cache = model_zoo.init_cache(cfg, 4, 128, abstract=True)
        sh = rules_lib.cache_shardings(cfg, cache, mesh, "serve")
        assert jax.tree.structure(sh) == jax.tree.structure(
            cache, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_batch_shardings_long500k_replicated():
    mesh = _mesh22()
    spec = configs.SHAPES["long_500k"]
    cfg = configs.get_config("rwkv6-7b")
    batch = configs.input_specs(cfg, spec)
    sh = rules_lib.batch_shardings(batch, mesh)
    assert sh["tokens"].spec == P()           # B=1 cannot shard


# ---- HLO cost model unit tests ----------------------------------------------

def test_hlo_cost_counts_loop_trips():
    hlo = textwrap.dedent("""\
    HloModule m

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%i2, %d)
    }

    %cond (p: (s32[], f32[8,8])) -> pred[] {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
      %a = f32[8,8] parameter(0)
      %z = s32[] constant(0)
      %t0 = (s32[], f32[8,8]) tuple(%z, %a)
      ROOT %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
    }
    """)
    res = hlo_cost.analyze_hlo(hlo)
    # dot: 2*8*8*8 = 1024 flops x 10 trips
    assert res["mxu_flops"] == 1024 * 10


def test_hlo_cost_collective_accounting():
    hlo = textwrap.dedent("""\
    HloModule m

    ENTRY %main (a: f32[4,8]) -> f32[64,8] {
      %a = f32[4,8] parameter(0)
      ROOT %ag = f32[64,8] all-gather(%a), replica_groups=[16,16]<=[256], dimensions={0}
    }
    """)
    res = hlo_cost.analyze_hlo(hlo)
    R = 64 * 8 * 4
    assert res["collective_operand_bytes"]["all-gather"] == R / 16
    np.testing.assert_allclose(res["collective_wire_bytes"], R * 15 / 16)


def test_roofline_terms_and_dominance():
    r = hlo_analysis.Roofline(flops_per_device=197e12, bytes_per_device=0.0,
                              collective_bytes_per_device=0.0, chips=256,
                              mxu_flops_per_device=197e12)
    np.testing.assert_allclose(r.compute_s, 1.0)
    assert r.dominant == "compute"
    r2 = hlo_analysis.Roofline(0.0, 819e9, 0.0, 256)
    np.testing.assert_allclose(r2.memory_s, 1.0)
    assert r2.dominant == "memory"


def test_model_flops_shapes():
    cfg = configs.get_config("qwen2.5-14b")
    tr = hlo_analysis.model_flops(cfg, "train", 4096 * 256, seq_len=4096,
                                  batch=256)
    assert tr > 6 * cfg.param_count() * 4096 * 256 * 0.9
    de = hlo_analysis.model_flops(cfg, "decode", 128, seq_len=32768, batch=128)
    assert de > 2 * cfg.active_param_count() * 128


# ---- reduced dry-run in a subprocess (4 placeholder devices) ---------------

@pytest.mark.slow
def test_dryrun_small_mesh_subprocess():
    code = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import json, jax, jax.numpy as jnp
        from repro import configs, sharding as shlib
        from repro.launch import sharding as rules_lib
        from repro.launch import hlo_analysis
        from repro.models import model_zoo
        from repro.training import train_loop

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = configs.get_smoke_config("qwen2.5-14b")
        import dataclasses
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=64, num_heads=4,
                                  num_kv_heads=2, head_dim=16, d_ff=128,
                                  vocab_size=256)
        tcfg = train_loop.TrainConfig()
        state = train_loop.abstract_state(cfg, tcfg)
        state_sh = rules_lib.train_state_shardings(cfg, mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
        batch_sh = rules_lib.batch_shardings(batch, mesh)
        arules = rules_lib.act_rules(mesh, "train")
        step = train_loop.make_train_step(cfg, tcfg,
                                          grad_shardings=state_sh.params)
        def wrapped(s, b):
            with shlib.use_rules(arules):
                return step(s, b)
        with mesh:
            lowered = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)).lower(state, batch)
            compiled = lowered.compile()
        roof, detail = hlo_analysis.roofline_from_compiled(compiled, 4)
        assert roof.flops_per_device > 0
        assert detail["collectives"]["total"] >= 0
        print(json.dumps({"ok": True,
                          "ndev": len(jax.devices()),
                          "flops": roof.flops_per_device}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["ndev"] == 4


@pytest.mark.slow
def test_ring_allreduce_int8_4dev_subprocess():
    """The int8 ring matches psum on a real 4-device (host) mesh."""
    code = textwrap.dedent("""\
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import functools, inspect, json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax import shard_map
        except ImportError:          # jax < 0.5 keeps it in experimental
            from jax.experimental.shard_map import shard_map
        from repro.training import compression

        mesh = jax.make_mesh((4,), ("data",))
        x = jnp.arange(4 * 16, dtype=jnp.int8).reshape(4, 16) % 11 - 5

        # the replication-check kwarg was renamed check_rep -> check_vma
        ck = ("check_vma" if "check_vma"
              in inspect.signature(shard_map).parameters else "check_rep")

        @functools.partial(shard_map, mesh=mesh, in_specs=P("data"),
                           out_specs=P("data"), **{ck: False})
        def ring(x):
            return compression.ring_allreduce_int8(x[0], "data")[None]

        got = np.asarray(ring(x))
        want = np.sum(np.asarray(x, np.int32), axis=0)
        for d in range(4):
            np.testing.assert_array_equal(got[d], want)
        print(json.dumps({"ok": True}))
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
