"""The CI benchmark regression gate (benchmarks/check_regression.py):
metric resolution, the >25%-drop rule, combined-JSON loading, and the
committed goldens passing their own gate."""

import json
import os

from benchmarks import check_regression as cr


GOLDEN = {
    "serving_bench": {
        "scheduler": {"batched_speedup": 3.0,
                      "batched": {"served": 78}},
        "continuous_vs_wave": {"p95_speedup": 5.0, "p50_speedup": 4.0,
                               "continuous": {"served": 35},
                               "wave": {"served": 35}},
        "prefill_bucketing": {"bucketed_speedup": 2.0},
        "policies": {"edge_only": {"served": 78}, "auto": {"served": 78}},
        "closed_loop": {"onset_detected": True},
    },
    "controller_micro": {
        "route_batch_B4096_us": 100.0,
        "route_batch_dense_B4096_us": 4000.0,   # 40x speedup
    },
}


def _fresh(**overrides):
    fresh = json.loads(json.dumps(GOLDEN))     # deep copy
    for path, v in overrides.items():
        cur = fresh
        parts = path.split(".")
        for p in parts[:-1]:
            cur = cur[p]
        cur[parts[-1]] = v
    return fresh


def test_identical_results_pass():
    assert cr.compare(_fresh(), GOLDEN) == []


def test_small_drop_within_threshold_passes():
    fresh = _fresh(**{"serving_bench.scheduler.batched_speedup": 2.4})
    assert cr.compare(fresh, GOLDEN) == []     # -20% < 25%


def test_large_ratio_drop_fails():
    fresh = _fresh(**{"serving_bench.continuous_vs_wave.p95_speedup": 3.0})
    problems = cr.compare(fresh, GOLDEN)       # -40%
    assert len(problems) == 1
    assert "continuous_vs_wave.p95_speedup" in problems[0]


def test_derived_route_speedup_gate():
    fresh = _fresh(**{"controller_micro.route_batch_B4096_us": 200.0})
    problems = cr.compare(fresh, GOLDEN)       # 20x vs golden 40x
    assert any("route_speedup_B4096" in p for p in problems)


def test_count_mismatch_fails():
    fresh = _fresh(**{"serving_bench.policies.auto.served": 70})
    problems = cr.compare(fresh, GOLDEN)
    assert any("policies.auto.served" in p for p in problems)


def test_flag_regression_fails():
    fresh = _fresh(**{"serving_bench.closed_loop.onset_detected": False})
    problems = cr.compare(fresh, GOLDEN)
    assert any("onset_detected" in p for p in problems)


def test_missing_metric_in_fresh_fails():
    fresh = _fresh()
    del fresh["serving_bench"]["continuous_vs_wave"]
    problems = cr.compare(fresh, GOLDEN)
    assert any("missing" in p for p in problems)


def test_golden_without_metric_is_skipped():
    golden = json.loads(json.dumps(GOLDEN))
    del golden["serving_bench"]["continuous_vs_wave"]
    assert cr.compare(_fresh(), golden) == []  # golden predates the metric


def test_load_results_dir_and_combined_file(tmp_path):
    d = tmp_path / "results"
    d.mkdir()
    for bench, payload in GOLDEN.items():
        with open(d / f"{bench}.json", "w") as f:
            json.dump(payload, f)
    combined = tmp_path / "combined.json"
    with open(combined, "w") as f:
        json.dump(GOLDEN, f)                   # run.py --json schema
    from_dir = cr.load_results(str(d))
    from_file = cr.load_results(str(combined))
    assert from_dir == from_file == GOLDEN


def test_committed_goldens_pass_their_own_gate():
    """The gate must pass when a fresh run exactly reproduces the
    committed benchmarks/results/*.json — and every serving-bench stable
    metric must actually exist in the goldens."""
    golden = cr.load_results(cr.BASELINE)
    assert cr.compare(golden, golden) == []
    derived = cr.derive(golden)
    for bench, path, _ in cr.STABLE_METRICS:
        assert cr.dig(derived.get(bench, {}), path) is not None, \
            f"golden missing {bench}:{path} — refresh benchmarks/results"


def test_main_skip_run_pass_and_fail(tmp_path, capsys):
    d = tmp_path / "fresh"
    d.mkdir()
    for bench, payload in GOLDEN.items():
        with open(d / f"{bench}.json", "w") as f:
            json.dump(payload, f)
    g = tmp_path / "golden.json"
    with open(g, "w") as f:
        json.dump(GOLDEN, f)
    ok = cr.main(["--fresh", str(d), "--baseline", str(g), "--skip-run"])
    assert ok == 0
    bad = json.loads(json.dumps(GOLDEN))
    bad["serving_bench"]["scheduler"]["batched_speedup"] = 0.5
    with open(d / "serving_bench.json", "w") as f:
        json.dump(bad["serving_bench"], f)
    assert cr.main(["--fresh", str(d), "--baseline", str(g),
                    "--skip-run"]) == 1
