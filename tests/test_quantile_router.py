"""Histogram quantile sketch error bounds + router distribution tests."""

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:      # not installable here; deterministic shim
    from _hypothesis_fallback import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offload, quantile, router


# ---- quantile sketch --------------------------------------------------------

def test_sketch_error_bound_lognormal():
    """Relative error of sketch quantiles <= one geometric bucket width."""
    rng = np.random.default_rng(0)
    hist = quantile.Histogram.init(1, num_buckets=64, lo=1e-4, hi=1e3)
    data = rng.lognormal(-2.0, 1.0, size=4096).astype(np.float32)
    hist = quantile.update(hist, jnp.asarray(data[None]))
    # bucket width in log space
    width = (np.log(1e3) - np.log(1e-4)) / 64
    for q in (0.5, 0.9, 0.95, 0.99):
        got = float(quantile.quantile(hist, q)[0])
        want = float(np.quantile(data, q))
        assert abs(np.log(got) - np.log(want)) <= width + 1e-6, (q, got, want)


def test_sketch_ratio_close_to_exact():
    rng = np.random.default_rng(1)
    data = rng.lognormal(-2.0, 0.6, size=(3, 2048)).astype(np.float32)
    hist = quantile.Histogram.init(3, num_buckets=128)
    hist = quantile.update(hist, jnp.asarray(data))
    r_sketch = np.asarray(offload.latency_ratio_from_sketch(hist))
    r_exact = np.asarray(offload.latency_ratio(jnp.asarray(data)))
    np.testing.assert_allclose(r_sketch, r_exact, rtol=0.25)


def test_sketch_decay_forgets():
    hist = quantile.Histogram.init(1, num_buckets=64)
    slow = jnp.full((1, 256), 10.0)
    fast = jnp.full((1, 256), 0.01)
    hist = quantile.update(hist, slow)
    for _ in range(40):
        hist = quantile.update(hist, fast, decay=0.7)
    p95 = float(quantile.quantile(hist, 0.95)[0])
    assert p95 < 0.1        # the old slow regime is forgotten


@hypothesis.given(st.floats(0.05, 0.99))
@hypothesis.settings(max_examples=25, deadline=None)
def test_sketch_quantile_monotone(q):
    rng = np.random.default_rng(7)
    data = rng.lognormal(-1, 0.8, size=2048).astype(np.float32)
    hist = quantile.Histogram.init(1, num_buckets=64)
    hist = quantile.update(hist, jnp.asarray(data[None]))
    lo = float(quantile.quantile(hist, q * 0.5)[0])
    hi = float(quantile.quantile(hist, q)[0])
    assert hi >= lo - 1e-9


# ---- router -----------------------------------------------------------------

def test_route_batch_expectation():
    key = jax.random.PRNGKey(0)
    pct = jnp.asarray([30.0, 80.0])
    fn_ids = jnp.asarray([0] * 100 + [1] * 50, jnp.int32)
    counts = np.zeros(2)
    trials = 200
    for t in range(trials):
        mask = np.asarray(router.route_batch(jax.random.fold_in(key, t), pct,
                                             fn_ids, 2))
        counts[0] += mask[:100].sum()
        counts[1] += mask[100:].sum()
    np.testing.assert_allclose(counts[0] / trials, 30.0, atol=1.0)
    np.testing.assert_allclose(counts[1] / trials, 40.0, atol=1.0)


def test_route_batch_low_variance_vs_bernoulli():
    key = jax.random.PRNGKey(1)
    pct = jnp.asarray([50.0])
    fn_ids = jnp.zeros(64, jnp.int32)
    nb, nB = [], []
    for t in range(120):
        k = jax.random.fold_in(key, t)
        nb.append(int(np.asarray(router.route_batch(k, pct, fn_ids, 1)).sum()))
        nB.append(int(np.asarray(router.route_bernoulli(k, pct, fn_ids)).sum()))
    assert np.var(nb) < np.var(nB)
    assert abs(np.mean(nb) - 32) < 1.5


def test_route_batch_extremes():
    key = jax.random.PRNGKey(2)
    fn_ids = jnp.zeros(32, jnp.int32)
    all_edge = np.asarray(router.route_batch(key, jnp.asarray([0.0]), fn_ids, 1))
    all_cloud = np.asarray(router.route_batch(key, jnp.asarray([100.0]), fn_ids, 1))
    assert all_edge.sum() == 0 and all_cloud.sum() == 32


def test_hedged_mask_targets_stragglers():
    key = jax.random.PRNGKey(3)
    lat = jnp.asarray([0.1, 0.1, 5.0, 0.1, 7.0, 0.1])
    p99 = jnp.asarray([1.0])
    fn_ids = jnp.zeros(6, jnp.int32)
    mask = np.asarray(router.hedged_mask(key, lat, p99, fn_ids))
    assert mask[2] and mask[4] and mask.sum() == 2
