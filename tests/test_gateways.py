"""Per-tier gateways in the live runtime + the scheduler accounting
fixes that ride along: hedge-twin adoption (no double service), fractional
target concurrency, per-link net series, per-boundary demand/backlog
signals, bounded gateway rejection, and live/sim control-loop parity at
every boundary."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.metrics import LatencyWindow, MetricsRegistry
from repro.core.policy import ControlLoop, StaticSplit
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.models import model_zoo
from repro.platform import Continuum, Request
from repro.serving.tiers import Gateway, Tier, TierConfig, _Queued


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(rid, max_new=1):
    return Request(rid=rid, tokens=np.arange(6, dtype=np.int32),
                   max_new=max_new)


# ---- Gateway unit behaviour -------------------------------------------------

def test_gateway_bounds_and_backlog_ages():
    gw = Gateway(capacity=2)
    a = _Queued("f", _req(0), t_submit=10.0, tick_no=0)
    b = _Queued("f", _req(1), t_submit=11.0, tick_no=1)
    c = _Queued("f", _req(2), t_submit=12.0, tick_no=1)
    assert gw.push(a) and gw.push(b)
    assert not gw.push(c)                      # bounded backlog: rejected
    assert gw.rejected == 1 and len(gw) == 2
    assert gw.push(c, force=True)              # in-tick placement bypasses
    assert len(gw) == 3
    # only entries that survived a previous scheduler round are backlog
    ages = gw.backlog_ages(now=15.0, tick_no=1,
                           fn_ids={"f": 0}, num_functions=1)
    assert ages == [[5.0]]
    assert gw.pop_all() == [a, b, c] and len(gw) == 0


def test_legacy_pair_keeps_elastic_cloud_unbounded():
    """Topology.pair mirrors the paper apparatus: bounded edge queue,
    unbounded cloud — a legacy 2-tier continuum must not silently drop
    cloud-bound leftovers at a gateway cap the seed never had."""
    topo = Topology.pair(TierConfig(slots=2), TierConfig(slots=8))
    assert topo.tiers[0].queue_depth_per_slot == 8
    assert topo.tiers[1].queue_depth_per_slot is None
    cc = Continuum(edge=TierConfig(slots=2, max_len=64),
                   cloud=TierConfig(slots=8, max_len=64), policy=0.0)
    assert cc.gateways[0].capacity == 16 and cc.gateways[1].capacity is None


def test_submit_rejects_when_ingress_gateway_full(model):
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=1, max_len=64, queue_depth_per_slot=1),
               TierSpec("cloud", slots=4, max_len=64)),
        links=(LinkSpec(rtt_s=0.0),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=0.0, seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    reqs = [_req(i) for i in range(3)]
    oks = [cc.submit("fn", r) for r in reqs]
    assert oks == [True, False, False]         # capacity = 1 slot x depth 1
    assert [r.failed for r in reqs] == [False, True, True]
    assert cc.gateways[0].rejected == 2
    assert cc.metrics.counters["rejected"] == 2
    # every arrival counts as ingress demand, admitted or not (the
    # simulator counts 503'd arrivals the same way)
    assert cc._crossings[0][cc._fn_ids["fn"]] == 3
    # fast rejections are part of the ingress Eq (1) distribution
    lat, valid = cc.tiers[0].metrics.latency_windows(8)
    assert int(valid.sum()) == 2
    np.testing.assert_allclose(lat[0][valid[0]], cc.reject_latency_s)
    rec = cc.tick()
    assert sum(rec["tiers"].values()) == 1     # the admitted request
    assert rec["rejected"] == 2                # per-tick (pre-tick submits)
    assert cc.tick()["rejected"] == 0          # a delta, not a running sum


def test_requeue_overflow_drops_and_marks_failed(model):
    """A wave-budget leftover that does not fit its tier's bounded
    gateway is dropped for good — and the request says so instead of
    silently never completing."""
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=1, max_len=64,
                        queue_depth_per_slot=1)),
        links=(LinkSpec(rtt_s=0.0),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=100.0, seed=0,
                                 max_waves_per_tick=1)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    reqs = [_req(i) for i in range(4)]
    for r in reqs:
        assert cc.submit("fn", r)              # ingress gateway holds all 4
    rec = cc.tick()                            # all routed to the cloud
    assert rec["tiers"]["cloud"] == 1          # single admitted wave
    assert cc.queued == 1                      # one leftover fit the gateway
    assert rec["rejected"] == 2                # two did not: dropped
    assert sum(r.failed for r in reqs) == 2
    rec2 = cc.tick()
    assert rec2["tiers"]["cloud"] == 1 and rec2["rejected"] == 0
    served = sum(int(r.output is not None) for r in reqs)
    assert served == 2 and served + sum(r.failed for r in reqs) == 4


# ---- satellite: fractional target concurrency -------------------------------

def test_fractional_target_concurrency_capacity(model):
    cfg, params = model
    tier = Tier("t", TierConfig(slots=4, max_len=64))
    tier.deploy("fn", cfg, params,
                AutoscalingPolicy(min_scale=2, max_scale=4,
                                  target_concurrency=0.5))
    asc = tier.autoscalers["fn"]
    assert asc.replicas == 2
    # ceil(2 x 0.5) = 1, not int(2 x max(0.5, 1.0)) = 2 (the old
    # over-admission: a sub-one target silently rounded up to 1/replica)
    assert tier.capacity("fn") == 1
    asc.state.replicas = 4
    assert tier.capacity("fn") == 2            # ceil(4 x 0.5)
    asc.state.replicas = 0
    assert tier.capacity("fn") == 0            # scaled to zero


def test_capacity_still_bounded_by_slots(model):
    cfg, params = model
    tier = Tier("t", TierConfig(slots=4, max_len=64))
    tier.deploy("fn", cfg, params,
                AutoscalingPolicy(min_scale=4, max_scale=8,
                                  target_concurrency=4.0))
    assert tier.capacity("fn") == 4            # 16 wanted, 4-slot pool


# ---- satellite: hedge-twin adoption (no double service) ---------------------

class _AlwaysHedge(StaticSplit):
    """Keep all primaries at the ingress tier, hedge every queued item."""

    def __init__(self):
        super().__init__(0.0)

    def hedge(self, key, ages_s, fn_ids, latencies, valid):
        return np.ones(len(fn_ids), bool)


def test_hedge_twin_adoption_no_double_service(model):
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64,
                        autoscaling=AutoscalingPolicy(min_scale=0,
                                                      max_scale=0)),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(rtt_s=0.0),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=_AlwaysHedge(), seed=0,
                                 max_waves_per_tick=1)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    req = _req(1, max_new=2)
    assert cc.submit("fn", req)
    # The single wave serves the hedge twin on the cloud; the primary is
    # stranded at the zero-capacity edge.  The old scheduler requeued the
    # primary and served the same rid AGAIN next tick; now it adopts the
    # twin's completed result.
    rec = cc.tick()
    assert rec["hedged"] == 1 and rec["waves"] == 1
    assert rec["tiers"] == {"edge": 0, "cloud": 1}
    assert cc.queued == 0                      # adopted, not requeued
    assert req.output is not None              # twin's tokens copied over
    assert req.t_done > 0.0
    assert cc.metrics.counters["hedges_won"] == 1
    # exactly one latency entry, on the serving tier
    _, v_edge = cc.tiers[0].metrics.latency_windows(16)
    _, v_cloud = cc.tiers[1].metrics.latency_windows(16)
    assert int(v_edge.sum()) == 0 and int(v_cloud.sum()) == 1
    rec2 = cc.tick()                           # nothing left to serve
    assert sum(rec2["tiers"].values()) == 0 and rec2["waves"] == 0


def test_hedge_twin_pays_link_latency(model):
    """A twin dispatched down-chain crosses the same links a routed
    request would, so the twin-vs-primary comparison (and an adopted
    twin's recorded latency) includes the hop cost."""
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64,
                        autoscaling=AutoscalingPolicy(min_scale=0,
                                                      max_scale=0)),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(rtt_s=0.5),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=_AlwaysHedge(), seed=0,
                                 max_waves_per_tick=1)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    assert cc.submit("fn", _req(1, max_new=2))
    cc.tick()                                  # twin adopted on the cloud
    lat, valid = cc.tiers[1].metrics.latency_windows(16)
    assert int(valid.sum()) == 1
    assert float(lat[0][valid[0]][0]) >= 0.5   # link RTT charged


# ---- satellite: per-link net series in the simulator ------------------------

_SIM3 = SimConfig(duration_s=90.0, low_rps=2.0, high_rps=12.0,
                  ramp_start_s=10.0, ramp_end_s=40.0, seed=0)


def test_sim_two_tier_net_links_headline_identical():
    r = ContinuumSimulator("io", 50.0, SimConfig(duration_s=30.0)).run()
    assert r.net_links_MBps.shape == (1, len(r.times))
    np.testing.assert_array_equal(r.net_links_MBps[0], r.net_MBps)


def test_sim_three_tier_records_deep_link_egress():
    topo = Topology.device_edge_cloud(device_slots=2, edge_slots=4,
                                      cloud_slots=64)
    r = ContinuumSimulator("matmult", "auto", _SIM3, topology=topo).run()
    assert r.net_links_MBps.shape == (2, len(r.times))
    np.testing.assert_array_equal(r.net_links_MBps[0], r.net_MBps)
    assert r.net_links_MBps[1].max() > 0.0     # cloud-ward traffic visible
    assert "net_peak_MBps_link1" in r.summary()


# ---- tentpole: per-boundary demand, backlog, and parity ---------------------

def test_live_net_aware_parses_per_boundary_link_caps(model):
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("device", slots=1, max_len=64),
               TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=4, max_len=64)),
        links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=5e6),
               LinkSpec(rtt_s=0.04, bandwidth_Bps=80e6)))
    cc = Continuum.from_topology(topo, policy="auto+net", seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    assert cc.control.policies[0].cfg.link_bytes_per_s == 5e6
    assert cc.control.policies[1].cfg.link_bytes_per_s == 80e6


def test_live_and_sim_step_tiers_identical_per_boundary(model):
    """Shared per-boundary trace (windows + backlog ages + crossing
    demand) through the simulator's and the live runtime's ControlLoops:
    R_t trajectories must match at EVERY boundary."""
    cfg, params = model
    sim = ContinuumSimulator("matmult", "auto", SimConfig(duration_s=10.0),
                             topology=Topology.device_edge_cloud())
    topo = Topology(
        tiers=(TierSpec("device", slots=1, max_len=64),
               TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(rtt_s=0.005), LinkSpec(rtt_s=0.04)))
    cc = Continuum.from_topology(topo, policy="auto", seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    rng = np.random.default_rng(7)
    R_sim, R_live = [], []
    for t in range(25):
        lats = [rng.lognormal(-2, 0.8, (1, 64)).astype(np.float32)
                for _ in range(2)]
        valids = [rng.uniform(size=(1, 64)) < 0.9 for _ in range(2)]
        qages = [[list(rng.uniform(0.1, 4.0, size=t % 4))],
                 [list(rng.uniform(0.5, 8.0, size=(t + 1) % 3))]]
        arrivals = [[float(t % 7)], [float(t % 5)]]
        R_sim.append(np.array(sim.control.step_tiers(
            lats, valids, queue_ages=qages, arrivals=arrivals)))
        R_live.append(np.array(cc.control.step_tiers(
            lats, valids, queue_ages=qages, arrivals=arrivals)))
    np.testing.assert_array_equal(np.asarray(R_sim), np.asarray(R_live))
    assert np.asarray(R_sim)[:, 1].max() > 0.0   # deep boundary engages


def _backlogged_three_tier(model):
    """3-tier live chain under a wave budget: the device tier is pinned to
    zero (waterfall spills its load over link 0), the edge tier admits one
    request per tick, so the edge's OWN gateway accumulates backlog."""
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("device", slots=2, max_len=64,
                        autoscaling=AutoscalingPolicy(min_scale=0,
                                                      max_scale=0)),
               TierSpec("edge", slots=2, max_len=64,
                        autoscaling=AutoscalingPolicy(
                            min_scale=1, max_scale=1,
                            target_concurrency=1.0)),
               TierSpec("cloud", slots=8, max_len=64)),
        links=(LinkSpec(rtt_s=0.0), LinkSpec(rtt_s=0.0)),
        waterfall=True)
    cc = Continuum.from_topology(topo, policy="auto", seed=0,
                                 max_waves_per_tick=1)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    return cc


def test_gateway_spill_leaves_backlog_at_the_spilled_tier(model):
    cc = _backlogged_three_tier(model)
    for i in range(4):
        assert cc.submit("fn", _req(i))
    rec = cc.tick()
    # all four spilled device -> edge over the link; one served, the rest
    # wait in the EDGE gateway (not back at the ingress deque)
    assert rec["spilled"] == 4
    assert rec["tiers"] == {"device": 0, "edge": 1, "cloud": 0}
    assert rec["backlog"] == {"device": 0, "edge": 3, "cloud": 0}
    assert len(cc.gateways[1]) == 3
    assert all(it.tick_no < cc._tick_no for it in cc.gateways[1].items)
    # spill counted as demand that crossed boundary 1 (for the next scrape)
    assert cc._crossings[1][cc._fn_ids["fn"]] == 4
    # the backlog drains from the edge gateway on later ticks, nothing lost
    for _ in range(6):
        if cc.queued == 0:
            break
        cc.tick()
    assert cc.queued == 0
    assert sum(sum(r["tiers"].values()) for r in cc.log) == 4


def test_intermediate_boundary_fires_on_own_gateway_backlog(model):
    """The acceptance scenario: boundary 1's R_t rises because tier 1's
    own gateway backlog ages — while its completion windows are uniform
    (ratio 1), which under the old completions-only signal kept R_t at 0
    until the slow requests eventually drained."""
    cc = _backlogged_three_tier(model)
    for i in range(4):
        assert cc.submit("fn", _req(i))
    cc.tick()
    assert len(cc.gateways[1]) == 3
    assert float(cc.control.R_all[1][0]) == 0.0
    # uniform fast completion history at the edge (no tail of its own)
    cc.tiers[1].metrics.clear()
    for _ in range(20):
        cc.tiers[1].metrics.record_latency("fn", 0.05)
    # completions-only control (the old live signal): stays at zero
    lat1, val1 = cc.tiers[1].metrics.latency_windows(cc.window)
    zeros = np.zeros_like(lat1)
    ref = ControlLoop("auto", 1, window=cc.window, num_tiers=3)
    ref.step_tiers([zeros, lat1], [zeros.astype(bool), val1])
    assert float(ref.R_all[1][0]) == 0.0
    # the same windows + the gateway's own backlog ages: boundary 1 fires
    for it in cc.gateways[1].items:
        it.t_submit -= 30.0
    cc.controller_update()
    assert float(cc.control.R_all[1][0]) > 0.0
    assert float(cc.control.R_all[0][0]) == 0.0   # device boundary quiet


# ---- satellite: public LatencyWindow.clear ----------------------------------

def test_latency_window_public_clear():
    w = LatencyWindow(capacity=4)
    w.record(0.1)
    w.record(0.2)
    assert len(w) == 2
    w.clear()
    assert len(w) == 0
    reg = MetricsRegistry(["a"])
    reg.record_latency("a", 1.0)
    reg.inc("x")
    reg.clear()
    assert len(reg.latency["a"]) == 0 and not reg.counters
