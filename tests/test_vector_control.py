"""Vectorized control plane: bit-identity goldens, sketch-path property
tests, and the stacked metrics store.

The contract under test (docs/architecture.md "Vectorized control
plane"): with ``vectorized="auto"`` the batched all-boundaries kernel is
bit-identical to the legacy per-boundary loop (the parity oracle,
``vectorized=False``) for every auto-family policy shorthand, at any
fleet size — including F=1 (whose trajectory the seed goldens pin) and
non-power-of-two F (padding edge).
"""

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:      # not installable here; deterministic shim
    from _hypothesis_fallback import hypothesis, st
import jax
import numpy as np
import pytest

from repro import configs
from repro.core import offload, quantile
from repro.core.metrics import LatencyWindow, MetricsRegistry, VectorWindows
from repro.core.policy import ControlLoop, Policy
from repro.core.replication import FunctionSpec
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.models import model_zoo
from repro.platform import Continuum


# ---- golden: vectorized vs legacy R_t bit-identity --------------------------

SHORTHANDS = ["auto", "auto+net", "auto+hedge", "auto+migrate",
              "auto+net+hedge+migrate"]


def _parse(spec):
    """Each policy object is single-use (it owns jit/controller state)."""
    return Policy.parse(spec, link_bytes_per_s=2e6, req_bytes=1500.0)


def _drive(loop, F, B, W, steps=6, seed=0):
    """Deterministic multi-step drive with regime shifts, queue ages,
    per-boundary arrivals, and one all-invalid (frozen) interval."""
    rng = np.random.default_rng(seed)
    out = []
    for step in range(steps):
        scale = 30.0 if step % 5 == 0 else 1.0
        lats = [(rng.gamma(2.0, 0.05, (F, W)) * scale).astype(np.float32)
                for _ in range(B)]
        valid = [rng.random((F, W)) < 0.9 for _ in range(B)]
        if step == 3:
            valid[0][:] = False          # boundary skip must freeze state
        qa = [[list(rng.random(rng.integers(0, 5))) for _ in range(F)]
              for _ in range(B)]
        arr = [rng.integers(0, 50, F).tolist() for _ in range(B)]
        out.append(np.array(loop.step_tiers(lats, valid, queue_ages=qa,
                                            arrivals=arr)))
    return np.stack(out)


@pytest.mark.parametrize("spec", SHORTHANDS)
@pytest.mark.parametrize("F", [1, 3, 257])
def test_vectorized_bit_identical_to_legacy(spec, F):
    """The acceptance golden: batched R_t == per-boundary R_t, bitwise."""
    for B in ([1] if F == 1 else [1, 2]):
        vec = ControlLoop(_parse(spec), F, window=8, num_tiers=B + 1)
        leg = ControlLoop(_parse(spec), F, window=8, num_tiers=B + 1,
                          vectorized=False)
        assert vec.vectorized and not leg.vectorized
        Rv = _drive(vec, F, B, W=8)
        Rl = _drive(leg, F, B, W=8)
        np.testing.assert_array_equal(Rv, Rl)


def test_step_matches_legacy_and_leaves_deep_boundaries():
    """step() (ingress only) on a 3-tier vectorized loop: boundary 0
    bit-matches the legacy loop, boundaries 1+ stay frozen."""
    rng = np.random.default_rng(3)
    vec = ControlLoop("auto", 5, window=8, num_tiers=3)
    leg = ControlLoop("auto", 5, window=8, num_tiers=3, vectorized=False)
    for _ in range(5):
        lat = rng.gamma(2.0, 0.05, (5, 8)).astype(np.float32)
        valid = rng.random((5, 8)) < 0.9
        arr = rng.integers(0, 20, 5).tolist()
        Rv = vec.step(lat, valid, arrivals=arr)
        Rl = leg.step(lat, valid, arrivals=arr)
        np.testing.assert_array_equal(np.asarray(Rv), np.asarray(Rl))
        np.testing.assert_array_equal(vec.R_all, leg.R_all)
    assert not vec.R_all[1].any()        # never stepped


def test_f1_multiboundary_falls_back_to_legacy():
    """F=1 multi-tier seed trajectories come from (1, W) compilations the
    batched stack can't bit-reproduce (Eq-(4) FMA contraction), so auto
    mode keeps the per-boundary loop there."""
    assert not ControlLoop("auto", 1, window=8, num_tiers=3).vectorized
    assert ControlLoop("auto", 1, window=8, num_tiers=2).vectorized
    assert ControlLoop("auto", 2, window=8, num_tiers=3).vectorized


def test_static_split_uses_legacy_loop():
    loop = ControlLoop(25.0, 4, window=8)
    assert not loop.vectorized
    R = loop.step(np.ones((4, 8), np.float32), np.ones((4, 8), bool))
    np.testing.assert_array_equal(R, np.full(4, 25.0, np.float32))


def test_vectorized_true_rejects_mixed_policies():
    with pytest.raises(ValueError, match="auto-family"):
        ControlLoop("auto", 2, window=8, num_tiers=3,
                    boundary_policies=["auto", 25.0], vectorized=True)


def test_set_link_capacity_recaps_vectorized_loop():
    """Mid-run link faults re-cap the batched path without a rebuild
    (net params are per-row data, not compiled constants)."""
    pol_v, pol_l = _parse("auto+net"), _parse("auto+net")
    vec = ControlLoop(pol_v, 3, window=8)
    leg = ControlLoop(pol_l, 3, window=8, vectorized=False)
    _drive(vec, 3, 1, W=8, steps=2)
    _drive(leg, 3, 1, W=8, steps=2)
    pol_v.set_link_capacity(1e4)
    pol_l.set_link_capacity(1e4)
    Rv = _drive(vec, 3, 1, W=8, steps=3, seed=9)
    Rl = _drive(leg, 3, 1, W=8, steps=3, seed=9)
    np.testing.assert_array_equal(Rv, Rl)
    assert (Rv[-1] <= 100.0).all()


# ---- streaming sketch path --------------------------------------------------

def test_step_stream_reacts_to_regime_shift():
    rng = np.random.default_rng(0)
    loop = ControlLoop("auto", 4, window=64, eq1="sketch")
    for step in range(30):
        scale = 0.02 if step < 15 else 2.0    # calm -> heavy tail
        ids = rng.integers(0, 4, 64)
        vals = rng.gamma(2.0, scale, 64).astype(np.float32)
        if step >= 15:                        # bimodal: slow stragglers
            vals[::4] *= 50.0
        R = loop.step_stream([(ids, vals)],
                             arrivals=[rng.integers(1, 30, 4).tolist()])
    assert R.shape == (1, 4)
    assert (R > 0).all()                      # tail ratio fired everywhere


def test_step_stream_idle_boundary_stays_frozen():
    loop = ControlLoop("auto", 2, window=16, num_tiers=3, eq1="sketch")
    R = loop.step_stream([None, None])
    np.testing.assert_array_equal(R, np.zeros((2, 2), np.float32))
    ids = np.zeros(8, np.int64)
    vals = np.full(8, 0.05, np.float32)
    R = loop.step_stream([(ids, vals), None])
    assert not R[1].any()                     # boundary 1 never saw data


def test_eq1_dispatch_is_enforced():
    win = ControlLoop("auto", 2, window=8)
    sk = ControlLoop("auto", 2, window=8, eq1="sketch")
    with pytest.raises(ValueError, match="step_stream"):
        win.step_stream([None])
    with pytest.raises(ValueError, match="sketch"):
        sk.step(np.ones((2, 8), np.float32), np.ones((2, 8), bool))
    with pytest.raises(ValueError, match="sketch"):
        sk.step_tiers([np.ones((2, 8), np.float32)], [np.ones((2, 8), bool)])
    with pytest.raises(ValueError, match="eq1"):
        ControlLoop("auto", 2, window=8, eq1="exact")


def test_sim_sketch_loop_runs_and_offloads():
    """eq1="sketch" end-to-end through the simulator driver: same
    submitted totals as the exact loop, and offload engages under ramp."""
    cfg = SimConfig(duration_s=40.0, low_rps=2.0, high_rps=14.0)
    exact = ContinuumSimulator("matmult", "auto", cfg).run()
    sketch = ContinuumSimulator("matmult", "auto", cfg, eq1="sketch").run()
    assert (sketch.successes + sketch.failures
            == exact.successes + exact.failures)
    assert max(sketch.offload_pct) > 0.0


# ---- quantile sketch vs sorted buffer (property) ----------------------------

def _sketch_vs_sorted(data, num_buckets=64, lo=1e-4, hi=1e3):
    """Ingest ``data`` (flat, one function) and compare sketch quantiles
    against exact sorted-sample quantiles within the documented bound:
    one geometric bucket of log-space error (see quantile.quantile).
    The reference is the inverted empirical CDF — the sketch inverts a
    (bucketed) CDF, so interpolating between order statistics (numpy's
    default) is not the comparable estimator at discontinuities."""
    data = np.asarray(data, np.float32)
    hist = quantile.Histogram.init(1, num_buckets=num_buckets, lo=lo, hi=hi)
    rows = np.zeros(len(data), np.int32)
    hist = quantile.ingest(hist, rows, data, decay=1.0)
    width = (np.log(hi) - np.log(lo)) / num_buckets
    for q in (0.5, 0.95):
        got = float(quantile.quantile(hist, q)[0])
        want = float(np.quantile(data, q, method="inverted_cdf"))
        if lo <= want <= hi:                 # bound only holds in range
            assert abs(np.log(got) - np.log(max(want, 1e-30))) \
                <= width + 1e-6, (q, got, want)


@hypothesis.given(st.lists(st.floats(min_value=1e-3, max_value=500.0),
                           min_size=8, max_size=256))
@hypothesis.settings(max_examples=30, deadline=None)
def test_sketch_tracks_sorted_quantiles(xs):
    _sketch_vs_sorted(xs)


def test_sketch_tracks_sorted_quantiles_adversarial():
    """Distributions chosen to stress the log-bucket sketch: bimodal
    straggler mixes (Eq (1)'s regime), constants on bucket edges,
    heavy-tailed, and range-clamped outliers."""
    rng = np.random.default_rng(0)
    bim = np.concatenate([np.full(95, 0.01), np.full(5, 9.0)])
    _sketch_vs_sorted(bim)
    _sketch_vs_sorted(np.full(64, float(np.exp(-4 * 0.25 * 7))))  # on-edge
    _sketch_vs_sorted(rng.pareto(1.5, 512) + 1e-3)
    _sketch_vs_sorted(rng.lognormal(-2.0, 2.0, 1024))
    # out-of-range values clamp into the edge buckets, never crash
    hist = quantile.Histogram.init(1)
    hist = quantile.ingest(hist, np.zeros(4, np.int32),
                           np.asarray([1e-9, 0.0, 1e9, 5.0], np.float32))
    assert np.isfinite(float(quantile.quantile(hist, 0.95)[0]))


def test_quantile_fast_matches_reference():
    """The tick-path quantile (shared blocked-scan prefix sums) tracks
    the reference implementation to float tolerance on random and
    adversarial histograms."""
    rng = np.random.default_rng(1)
    for counts in [rng.random((7, 64)).astype(np.float32) * 10,
                   np.zeros((3, 64), np.float32),              # empty
                   np.eye(64, dtype=np.float32)[:5] * 100.0]:  # single spike
        hist = quantile.Histogram(counts, np.float32(np.log(1e-4)),
                                  np.float32(np.log(1e3)))
        fast = np.asarray(quantile.quantile_fast(hist, (0.95, 0.5)))
        ref = np.stack([np.asarray(quantile.quantile(hist, 0.95)),
                        np.asarray(quantile.quantile(hist, 0.5))])
        np.testing.assert_allclose(fast, ref, rtol=2e-4, atol=1e-7)


def test_ingest_matches_update_fold():
    """Scatter-add ingest == one-hot-einsum update on the same samples
    (same decay, same buckets), to float tolerance."""
    rng = np.random.default_rng(2)
    data = rng.lognormal(-2.0, 1.0, (3, 32)).astype(np.float32)
    a = quantile.update(quantile.Histogram.init(3), data, decay=0.7)
    rows = np.repeat(np.arange(3, dtype=np.int32), 32)
    b = quantile.ingest(quantile.Histogram.init(3), rows, data.reshape(-1),
                        decay=0.7)
    np.testing.assert_allclose(np.asarray(a.counts), np.asarray(b.counts),
                               rtol=1e-6, atol=1e-6)


# ---- stacked metrics store --------------------------------------------------

@hypothesis.given(st.lists(st.floats(min_value=1e-4, max_value=100.0),
                           min_size=0, max_size=40))
@hypothesis.settings(max_examples=25, deadline=None)
def test_vector_windows_row_matches_deque(xs):
    """A VectorWindows row is bit-identical to the deque-backed
    LatencyWindow at every size, including ring wraparound."""
    ref = LatencyWindow(capacity=8)
    vw = VectorWindows(capacity=8)
    row = vw.add_row()
    for x in xs:
        ref.record(x)
        vw.record(row, x)
    assert len(ref) == vw.count(row)
    np.testing.assert_array_equal(ref.values(), vw.values(row))
    for size in (1, 4, 8, 16):
        lat_r, val_r = ref.window(size)
        lat_v, val_v = vw.window(row, size)
        np.testing.assert_array_equal(lat_r, lat_v)
        np.testing.assert_array_equal(val_r, val_v)


def test_registry_windows_stacked_gather():
    reg = MetricsRegistry(["a", "b", "c"], capacity=4)
    for i in range(6):
        reg.record_latency("a", 0.1 * (i + 1))
    reg.record_latency("c", 9.0)
    lat, valid = reg.latency_windows(4)
    assert lat.shape == (3, 4)
    np.testing.assert_array_equal(valid.sum(axis=1), [4, 0, 1])
    np.testing.assert_allclose(lat[0], [0.3, 0.4, 0.5, 0.6], rtol=1e-6)
    # per-function view over the shared store keeps the historical API
    assert len(reg.latency["a"]) == 4
    reg.latency["a"].clear()
    assert len(reg.latency["a"]) == 0
    assert len(reg.latency["c"]) == 1


def test_registry_drain_fresh():
    reg = MetricsRegistry(["a", "b"], capacity=4)
    reg.record_latency("b", 0.5)
    reg.record_latency("a", 0.25)
    rows, vals = reg.drain_fresh()
    np.testing.assert_array_equal(rows, [1, 0])
    np.testing.assert_allclose(vals, [0.5, 0.25])
    rows, vals = reg.drain_fresh()            # drained: empty until new data
    assert rows.size == 0 and vals.size == 0


# ---- live driver ------------------------------------------------------------

@pytest.mark.slow
def test_live_sketch_controller_update():
    """eq1="sketch" through the live runtime's scrape: drain_fresh feeds
    step_stream and R_t responds to recorded latencies."""
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=4, max_len=64)),
        links=(LinkSpec(rtt_s=0.0),))
    cc = Continuum.from_topology(topo, policy="auto", seed=0, eq1="sketch")
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    assert cc.control.eq1 == "sketch"
    for lat in (0.01, 0.012, 0.011, 0.9, 1.1):   # bimodal burst
        cc.tiers[0].metrics.record_latency("fn", lat)
    R = cc.controller_update()
    assert R.shape == (1,)
    assert np.isfinite(R).all()
