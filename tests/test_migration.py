"""Live mid-stream request migration with KV-cache transfer.

The tentpole behaviour: once a request is admitted into a tier's
continuous-batching slots it used to be pinned there — R_t only
redirected *new arrivals*.  With a ``migrate_threshold`` policy the live
scheduler cancels slot-resident victims, ships their cache rows over the
boundary's :class:`LinkSpec` (real cache bytes + token tail), and the
destination resumes decode at the same position with **no re-prefill**.

The core correctness pin is token-stream bit-identity: a request
migrated mid-decode must produce the identical token sequence as the
same request served unmigrated on a single tier.  Edge cases covered per
the issue: abort on full destination (row resumes at source, never
lost), cross-tick landing over a slow link, and the migrate-vs-hedge
interaction (a migrated primary keeps its ``_HedgePair`` link and the
pair accounting identity holds every tick).
"""

import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.policy import MigratingOffload, Policy, StaticSplit
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.core.simulator import ContinuumSimulator, SimConfig
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.models import model_zoo
from repro.platform import Continuum, Request, TierConfig
from repro.serving.engine import Endpoint
from repro.serving.tiers import _Queued


@pytest.fixture(scope="module")
def model():
    cfg = configs.get_smoke_config("stablelm-1.6b")
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _req(rid, max_new=8, length=6):
    return Request(rid=rid, tokens=np.arange(length, dtype=np.int32),
                   max_new=max_new)


class _Migrate(StaticSplit):
    """Static split + a migration threshold (deterministic in tests)."""

    def __init__(self, pct, thr=50.0):
        super().__init__(pct)
        self.migrate_threshold = thr


# ---- engine primitives ------------------------------------------------------

@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b"])
def test_extract_insert_roundtrip_bit_identity(arch):
    """Decode k steps on one endpoint, transplant the row into a
    *different* pool (with a busy neighbor), keep decoding: the token
    stream matches an unmigrated solo run bit for bit — for attention
    caches AND recurrent state (rwkv6's rows have no length axis)."""
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(6, dtype=np.int32)

    solo_ep = Endpoint(cfg, params, slots=2, max_len=64)
    s = solo_ep.try_claim()
    tok = solo_ep.prefill_one(s, prompt)
    solo = [tok]
    for _ in range(9):
        tok = solo_ep.decode_all({s: tok})[s]
        solo.append(tok)

    src = Endpoint(cfg, params, slots=2, max_len=64)
    dst = Endpoint(cfg, params, slots=4, max_len=64)
    s = src.try_claim()
    tok = src.prefill_one(s, prompt)
    got = [tok]
    for _ in range(4):
        tok = src.decode_all({s: tok})[s]
        got.append(tok)
    [state] = src.extract_rows([s])
    pos = int(src.slot_pos[s])
    src.release(s)
    # a busy neighbor on the destination must not perturb the insert
    other = dst.try_claim()
    dst.prefill_one(other, np.arange(3, dtype=np.int32))
    d = dst.try_claim()
    dst.insert_rows([state], [d], [pos])
    for _ in range(5):
        tok = dst.decode_all({d: tok})[d]
        got.append(tok)
    assert got == solo


def test_cache_nbytes_per_row_scales_with_position(model):
    cfg, params = model
    ep = Endpoint(cfg, params, slots=2, max_len=64)
    n10, n64 = ep.cache_nbytes_per_row(10), ep.cache_nbytes_per_row(64)
    assert 0 < n10 < n64
    # beyond the context budget the row cannot grow
    assert ep.cache_nbytes_per_row(1000) == n64
    # KV leaves dominate: bytes scale ~linearly with filled positions
    assert n64 / n10 > 3.0


def test_endpoint_compatibility_gate(model):
    cfg, params = model
    a = Endpoint(cfg, params, slots=2, max_len=64)
    b = Endpoint(cfg, params, slots=8, max_len=64)
    c = Endpoint(cfg, params, slots=2, max_len=128)
    assert a.compatible_with(b)          # pool size may differ
    assert not a.compatible_with(c)      # context budget may not


# ---- continuum-level migration ----------------------------------------------

def _two_tier(model, policy, **kw):
    cfg, params = model
    cc = Continuum(edge=TierConfig(slots=2, max_len=64),
                   cloud=TierConfig(slots=4, max_len=64),
                   policy=policy, seed=0, **kw)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    return cc


def _resident(cc, req, tier=0):
    """Admit a request straight into a tier's slots (bypassing routing),
    the deterministic way to pre-saturate a tier in tests."""
    item = _Queued("fn", req, t_submit=time.perf_counter())
    cc.tiers[tier].admit("fn", [item])
    return item


def test_migration_mid_decode_bit_identity(model):
    """The acceptance pin: a request migrated mid-decode produces the
    identical token sequence as the same request served unmigrated."""
    solo_cc = _two_tier(model, policy=0.0)
    solo = _req(0, max_new=12)
    solo_cc.submit("fn", solo)
    solo_cc.tick()
    assert solo.output is not None

    pol = _Migrate(100.0, thr=None)      # threshold off: no migration yet
    cc = _two_tier(model, pol, max_steps_per_tick=3)
    req = _req(0, max_new=12)
    _resident(cc, req)
    cc.tick()                            # 3 decode steps at the edge
    assert req.output is None and cc.in_flight == 1
    pol.migrate_threshold = 50.0         # R_t (100) now crosses: migrate
    rec = cc.tick()
    assert rec["migrations_fired"] == 1
    cc.drain()
    assert list(req.output) == list(solo.output)
    assert cc.metrics.counter("migrations_completed") == 1
    assert cc.metrics.counter("migrations_aborted") == 0
    # served exactly once, at the destination
    served = {t.name: sum(r["tiers"][t.name] for r in cc.log)
              for t in cc.tiers}
    assert served == {"edge": 0, "cloud": 1}
    # the transfer shipped real cache bytes + token tail over link 0
    assert cc.link_bytes[0] > cc.tiers[0].endpoints[
        "fn"].cache_nbytes_per_row(6)


def test_migration_latency_includes_link_cost(model):
    """The transfer occupies the request's clock: with a chunky RTT the
    migrated request's end-to-end latency includes the hop."""
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=4, max_len=64)),
        links=(LinkSpec(rtt_s=0.4),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=_Migrate(100.0), seed=0)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    req = _req(0, max_new=6)
    _resident(cc, req)
    t0 = time.perf_counter()
    cc.tick()
    cc.drain()
    assert req.output is not None
    assert req.t_done - t0 >= 0.4        # waited out the link
    assert cc.metrics.counter("migrations_completed") == 1


def test_migration_aborted_on_full_destination(model):
    """Destination full at landing: the migration ABORTS and the row
    resumes at its source — finishes correctly, never lost."""
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=1, max_len=64)),
        links=(LinkSpec(rtt_s=0.0),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=_Migrate(100.0), seed=0,
                                 max_steps_per_tick=4)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    # the cloud's only "fn" slot is held by a long blocker (endpoint
    # pools are per-function: the destination must be full for "fn")
    blocker = Request(rid=9, tokens=np.arange(6, dtype=np.int32),
                      max_new=40)
    item = _Queued("fn", blocker, t_submit=time.perf_counter())
    cc.tiers[1].admit("fn", [item])
    req = _req(0, max_new=10)
    _resident(cc, req)
    rec = cc.tick()                      # migration fires, cannot land
    assert rec["migrations_fired"] == 1
    cc.drain()
    assert cc.metrics.counter("migrations_aborted") >= 1
    assert cc.metrics.counter("migrations_completed") == 0
    assert req.output is not None and req.output.shape == (10,)
    # compare against an unmigrated solo run: still bit-identical
    solo_cc = _two_tier(model, policy=0.0)
    solo = _req(0, max_new=10)
    solo_cc.submit("fn", solo)
    solo_cc.tick()
    assert list(req.output) == list(solo.output)
    # resumed (and served) at the source tier
    assert sum(r["tiers"]["edge"] for r in cc.log) == 1


def test_cross_tick_landing_over_slow_link(model):
    """State in flight over a slow link when the tick ends: the transit
    survives the tick boundary and lands during a later tick."""
    cfg, params = model
    topo = Topology(
        tiers=(TierSpec("edge", slots=2, max_len=64),
               TierSpec("cloud", slots=4, max_len=64)),
        links=(LinkSpec(rtt_s=0.6),), waterfall=False)
    cc = Continuum.from_topology(topo, policy=_Migrate(100.0), seed=0,
                                 max_steps_per_tick=1)
    cc.deploy(FunctionSpec(name="fn", arch="stablelm-1.6b"), cfg, params)
    req = _req(0, max_new=8)
    _resident(cc, req)
    # a second resident row keeps the step-capped tick from waiting out
    # the link inside the tick (max_new=2 -> ineligible to migrate)
    keeper = _req(1, max_new=2)
    _resident(cc, keeper)
    rec = cc.tick()
    assert rec["migrations_fired"] == 1
    assert cc.migrations_open == 1       # still in flight over the link
    assert rec["inflight"] >= 1          # ... and counted as in flight
    ticks = 1 + cc.drain()
    assert ticks >= 2                    # landed on a later tick
    assert cc.migrations_open == 0
    assert cc.metrics.counter("migrations_completed") == 1
    assert req.output is not None and req.output.shape == (8,)
    assert keeper.output is not None


# ---- migrate-vs-hedge interaction -------------------------------------------

class _HedgeMigrate(StaticSplit):
    """Every queued request hedges; new arrivals stay at the ingress;
    R_t = 60 drives migration (>= threshold 50) without routing anything
    cloud-ward."""

    def __init__(self):
        super().__init__(60.0)
        self.migrate_threshold = 50.0

    def tier_distribution(self, R_all, num_tiers):
        d = np.zeros((R_all.shape[1], num_tiers), np.float32)
        d[:, 0] = 100.0
        return d

    def hedge(self, key, ages_s, fn_ids, latencies, valid):
        return np.ones(len(fn_ids), bool)


def test_migrated_primary_keeps_hedge_pair(model):
    """A migrated primary keeps its pair link: the race still resolves
    exactly once, `hedges_fired == hedges_won + hedges_cancelled +
    hedges_open` holds after every tick, and the request is served once."""
    cc = _two_tier(model, policy=_HedgeMigrate(), max_steps_per_tick=2)
    req = _req(0, max_new=10)
    assert cc.submit("fn", req)
    for _ in range(12):
        cc.tick()
        c = cc.metrics.counter
        assert c("hedges_fired") == (c("hedges_won")
                                     + c("hedges_cancelled")
                                     + cc.hedges_open)
        assert (cc.metrics.counter("migrations_fired")
                == c("migrations_completed") + c("migrations_aborted")
                + cc.migrations_open)
        if cc.queued == 0 and cc.in_flight == 0:
            break
    assert cc.queued == 0 and cc.in_flight == 0
    assert cc.metrics.counter("hedges_fired") == 1
    assert cc.metrics.counter("migrations_fired") >= 1
    assert req.output is not None and req.output.shape == (10,)
    served = sum(sum(r["tiers"].values()) for r in cc.log)
    assert served == 1                   # exactly one arm recorded
    samples = sum(len(t.metrics.latency_values("fn")) for t in cc.tiers)
    assert samples == 1


def test_hedge_twins_never_migrate(model):
    """Twins are duplicate work: they are evicted when the race settles,
    not shipped over a link.  Only the primary may migrate."""
    cc = _two_tier(model, policy=_HedgeMigrate(), max_steps_per_tick=2)
    req = _req(0, max_new=10)
    assert cc.submit("fn", req)
    cc.tick()                            # primary @ edge, twin @ cloud
    cc.tick()                            # migration may fire at the edge
    fired = cc.metrics.counter("migrations_fired")
    # the cloud (where the twin sits) is the last tier: no boundary fires
    # from it; and the edge's only eligible victim is the primary
    assert fired <= 1
    cc.drain()
    assert req.output is not None


# ---- policy parsing ---------------------------------------------------------

def test_policy_parse_auto_migrate():
    pol = Policy.parse("auto+migrate")
    assert isinstance(pol, MigratingOffload)
    assert pol.spec == "auto+migrate"
    assert pol.migrate_threshold == MigratingOffload.default_threshold
    assert Policy.parse("auto").migrate_threshold is None
    combo = Policy.parse("auto+net+migrate")
    assert combo.migrate_threshold is not None
    assert combo.spec == "auto+net+migrate"
    assert combo.cfg.net_aware
    hm = Policy.parse("auto+hedge+migrate")
    assert hm.migrate_threshold is not None and hasattr(hm, "hedge")


# ---- simulator parity -------------------------------------------------------

_SIM = SimConfig(duration_s=150.0, low_rps=2.0, high_rps=16.0,
                 ramp_start_s=20.0, ramp_end_s=70.0, seed=0)


def test_sim_migration_counters_and_accounting():
    """The simulator's matching in-service transfer: migrations fire
    under overload, the counter identity holds, and migration egress
    shows up in the per-link net series."""
    m = ContinuumSimulator("matmult", "auto+migrate", _SIM).run()
    assert m.migrations_fired > 0
    assert (m.migrations_fired
            == m.migrations_completed + m.migrations_aborted)
    assert m.net_links_MBps[0].max() > 0.0
    assert "migrations_fired" in m.summary()


def test_sim_migration_preserves_auto_when_disabled():
    """A migrate-capable run with the threshold never crossed is
    bit-identical to plain auto (the bookkeeping is inert)."""
    a = ContinuumSimulator("matmult", "auto", _SIM).run()
    pol = MigratingOffload(migrate_threshold=1000.0)   # unreachable
    b = ContinuumSimulator("matmult", pol, _SIM).run()
    assert b.migrations_fired == 0
    assert (a.successes, a.failures) == (b.successes, b.failures)
    np.testing.assert_array_equal(a.offload_pct, b.offload_pct)
    np.testing.assert_array_equal(a.net_MBps, b.net_MBps)


def test_sim_migration_recovers_successes():
    """The paper scenario, simulated: offloading resident work serves at
    least as many requests as routing new arrivals only."""
    a = ContinuumSimulator("matmult", "auto", _SIM).run()
    m = ContinuumSimulator("matmult", "auto+migrate", _SIM).run()
    assert m.successes >= a.successes
