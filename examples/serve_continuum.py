"""End-to-end driver: serve a small LM across the Edge-Cloud continuum.

Deploys TWO model endpoints (a dense LM and an SSM LM) through the
``repro.platform.Continuum`` facade, pushes a ramped request stream at the
edge gateway, and shows the full paper loop live: latency scrape ->
Policy (Eqs (1)-(4)) -> weighted batch routing -> *batched* per-tier
serving — each scheduler wave packs the admitted requests into one
prefill + a shared ``decode_all`` stream per endpoint.

    PYTHONPATH=src python examples/serve_continuum.py
"""

import jax
import numpy as np

from repro import configs
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.platform import Continuum, Request, TierConfig

ARCHS = ("stablelm-1.6b", "rwkv6-7b")

cc = Continuum(edge=TierConfig(slots=2, max_len=64),
               cloud=TierConfig(slots=12, max_len=64,
                                extra_latency_s=0.02),
               policy="auto", seed=0)
for arch in ARCHS:
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(jax.random.PRNGKey(hash(arch) % 2**31), cfg)
    cc.deploy(FunctionSpec(name=arch, arch=arch), cfg, params)
    print(f"deployed {arch} to cloud; replicated to edge "
          f"(writes={cc.replicator.writes})")

rng = np.random.default_rng(0)
rid = 0
print(f"\n{'round':>5} {'rps':>4} {'edge':>5} {'cloud':>5} {'waves':>6} "
      f"{'R_t%':>6}")
for rnd in range(18):
    rps = 2 if rnd < 4 else 10          # ramp: overload the 2-slot edge
    for _ in range(rng.poisson(rps)):
        arch = ARCHS[rid % 2]
        cfg = configs.get_smoke_config(arch)
        cc.submit(arch, Request(
            rid=rid, tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new=3))
        rid += 1
    rec = cc.tick()
    print(f"{rnd:>5} {rps:>4} {rec['edge']:>5} {rec['cloud']:>5} "
          f"{rec['waves']:>6} {rec['R']:>6.1f}")

edge_n = sum(r["edge"] for r in cc.log)
cloud_n = sum(r["cloud"] for r in cc.log)
waves = sum(r["waves"] for r in cc.log)
print(f"\nserved {rid} requests: edge={edge_n}, cloud={cloud_n} "
      f"({100 * cloud_n / max(rid, 1):.0f}% offloaded under overload)")
print(f"batching: {rid} requests packed into {waves} waves "
      f"({rid / max(waves, 1):.1f} requests sharing each prefill+decode "
      f"stream on average)")
print("steady-state replication writes:", cc.replicator.writes,
      "(no feedback loop)")
