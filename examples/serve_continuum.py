"""End-to-end driver: serve small LMs across a 3-tier continuum.

Declares a device -> edge -> cloud :class:`Topology`, deploys TWO model
endpoints (a dense LM and an SSM LM) through the
``repro.platform.Continuum`` facade, pushes a ramped request stream at
the device gateway, and shows the full paper loop live, generalized to N
tiers: per-tier latency scrape -> Policy (Eqs (1)-(4) per boundary) ->
categorical batch routing over the tier distribution -> *continuous*
per-tier serving — every scheduler step admits queued requests into free
slots (one bucketed prefill), runs one shared ``decode_all`` step across
all in-flight slots, and retires finished rows immediately, so short
requests never wait out a long co-resident one.

    PYTHONPATH=src python examples/serve_continuum.py
"""

import jax
import numpy as np

from repro import configs
from repro.core.replication import FunctionSpec
from repro.models import model_zoo
from repro.platform import (Continuum, LinkSpec, Request, TierSpec, Topology,
                            Trace, tier_outage)

ARCHS = ("stablelm-1.6b", "rwkv6-7b")

topo = Topology(
    tiers=(TierSpec("device", slots=1, max_len=64),
           TierSpec("edge", slots=2, max_len=64, extra_latency_s=0.005),
           TierSpec("cloud", slots=12, max_len=64, extra_latency_s=0.02)),
    links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=50e6),
           LinkSpec(rtt_s=0.04, bandwidth_Bps=100e6)))
# auto+migrate: when a boundary's R_t crosses the threshold, resident
# long decodes ship their KV-cache down-chain and resume mid-stream
# instead of holding the tier's slots hostage.  The step cap paces each
# tick, so long requests stay slot-resident ACROSS ticks — the state a
# migration can actually move.
cc = Continuum.from_topology(topo, policy="auto+migrate", seed=0,
                             max_steps_per_tick=6)
for arch in ARCHS:
    cfg = configs.get_smoke_config(arch)
    params = model_zoo.init(jax.random.PRNGKey(hash(arch) % 2**31), cfg)
    cc.deploy(FunctionSpec(name=arch, arch=arch), cfg, params)
    print(f"deployed {arch} to cloud; replicated down the chain "
          f"(writes={cc.replicator.writes})")

rng = np.random.default_rng(0)
rid = 0
names = topo.names
print(f"\n{'round':>5} {'rps':>4} " +
      " ".join(f"{n:>6}" for n in names) +
      f" {'steps':>6} {'R_t%':>6} {'backlog':>7}")
for rnd in range(18):
    rps = 2 if rnd < 4 else 10          # ramp: overload the 1-slot device
    for _ in range(rng.poisson(rps)):
        arch = ARCHS[rid % 2]
        cfg = configs.get_smoke_config(arch)
        # mixed lengths: every 5th request decodes 4x longer — under the
        # continuous scheduler the short ones overtake it mid-stream
        cc.submit(arch, Request(
            rid=rid, tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new=12 if rid % 5 == 0 else 3))
        rid += 1
    rec = cc.tick()
    row = " ".join(f"{rec['tiers'][n]:>6}" for n in names)
    print(f"{rnd:>5} {rps:>4} {row} {rec['steps']:>6} {rec['R']:>6.1f} "
          f"{sum(rec['backlog'].values()):>7}")
cc.drain()

totals = {n: sum(r["tiers"][n] for r in cc.log) for n in names}
served = sum(totals.values())
steps = sum(r["steps"] for r in cc.log)
per_tier = ", ".join(f"{n}={c}" for n, c in totals.items())
off = served - totals[names[0]]
print(f"\nserved {served}/{rid} requests: {per_tier} "
      f"({100 * off / max(served, 1):.0f}% pushed off-device under overload)")
print(f"continuous batching: {served} requests shared {steps} decode "
      f"steps; slots retire and refill mid-stream instead of waiting for "
      f"a wave to end")
print(f"per-tier gateways: spilled={sum(r['spilled'] for r in cc.log)} "
      f"down-chain, rejected={sum(r['rejected'] for r in cc.log)} "
      f"at bounded backlogs; hedges_open={cc.hedges_open}")
print(f"mid-stream migration: "
      f"{int(cc.metrics.counter('migrations_completed'))} resident "
      f"requests shipped their KV-cache down-chain and resumed without "
      f"re-prefill ({int(cc.metrics.counter('migrations_aborted'))} "
      f"aborted back to source)")
print("steady-state replication writes:", cc.replicator.writes,
      "(no feedback loop)")

# ---- traces & chaos on the live runtime: the same Trace/FaultSchedule
# the simulator takes drives the real engine.  The device tier crashes
# mid-run and comes back: its in-flight work is replayed down-chain, the
# restore re-registers every FunctionSpec through core.replication, and
# nothing is silently lost.
trace = Trace.poisson(rps=5.0, duration_s=5.0, fn_names=(ARCHS[0],),
                      seed=1, prompt_len=8, max_new=3)
cc2 = Continuum.from_topology(topo, policy="auto", seed=0, trace=trace,
                              faults=tier_outage(t0=2.0, t1=4.0, tier=0),
                              max_steps_per_tick=6)
cfg0 = configs.get_smoke_config(ARCHS[0])
cc2.deploy(FunctionSpec(name=ARCHS[0], arch=ARCHS[0]), cfg0,
           model_zoo.init(jax.random.PRNGKey(hash(ARCHS[0]) % 2**31), cfg0))
for _ in range(7):
    cc2.tick()
cc2.drain()
reqs = cc2.trace_requests
ok = sum(1 for r in reqs if r.output is not None)
assert ok + sum(1 for r in reqs if r.failed) == len(reqs) == len(trace)
print(f"\nchaos replay: device crashed t=2..4s mid-trace; served "
      f"{ok}/{len(reqs)}, replayed "
      f"{int(cc2.metrics.counter('replayed'))} off the crashed tier, "
      f"replication re-registered {int(cc2.replicators[0].writes)} specs "
      f"on restore")
