"""Train a ~100M-param dense LM for a few hundred steps with the full
training substrate: AdamW + cosine schedule, grad accumulation, atomic
checkpoints, auto-resume, and the synthetic copy-structure data stream.

    PYTHONPATH=src python examples/train_small.py [--steps 300]

On this CPU container a ~100M model at batch 8 x seq 256 takes a few
seconds per step; pass --tiny for a 30-second demo run.
"""

import argparse
import dataclasses
import os

import jax.numpy as jnp

from repro import configs
from repro.training import data
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import LoopConfig, TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
args = ap.parse_args()

base = configs.get_smoke_config("stablelm-1.6b")
if args.tiny:
    cfg, B, S = base, 8, 64
    args.steps = min(args.steps, 60)
else:
    # ~100M params: 12 x 512 x (8 heads) x d_ff 2048, 32k vocab
    cfg = dataclasses.replace(
        base, name="demo-100m", num_layers=12, d_model=512, num_heads=8,
        num_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=32768,
        param_dtype=jnp.float32, compute_dtype=jnp.float32)
    B, S = 8, 256

tcfg = TrainConfig(opt=OptimizerConfig(peak_lr=3e-4, warmup_steps=20,
                                       total_steps=args.steps),
                   accum_steps=2)
lcfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                  ckpt_every=50, log_every=10)
dcfg = data.DataConfig(batch=B, seq_len=S, span=16)

print(f"model: {cfg.param_count():,} params; batch {B} x seq {S}; "
      f"accum {tcfg.accum_steps}; ckpts -> {args.ckpt_dir}")
tr = Trainer(cfg, tcfg, lcfg, lambda s: data.stream(cfg, dcfg, s))
if tr.start_step:
    print(f"auto-resumed from step {tr.start_step}")
out = tr.run()
hist = out["history"]
for h in hist[:: max(len(hist) // 15, 1)]:
    print(f"step {h['step']:>4}  loss {h['loss']:.4f}")
print(f"\nfinal loss {hist[-1]['loss']:.4f} "
      f"(from {hist[0]['loss']:.4f}); straggler p95/p50 = "
      f"{out['straggler_ratio']:.2f}")
