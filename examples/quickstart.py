"""Quickstart: the paper's offloading controller in 40 lines.

Builds the Eqs (1)-(4) controller, feeds it a synthetic latency trace that
ramps from calm to tail-heavy and back, and plots (textually) how the
offloaded-traffic percentage R_t tracks the p95/p50 ratio — the core
behaviour of Knative Edge's scheduler, as a pure JAX program.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offload

cfg = offload.OffloadConfig()          # paper-faithful constants
state = offload.OffloadState.init(num_functions=1, cfg=cfg)
rng = np.random.default_rng(0)

print(f"{'t':>3} {'p95/p50':>8} {'R_t %':>7}  bar")
for t in range(60):
    # calm -> overloaded (tail latency spikes) -> drained
    overload = max(0.0, min((t - 10) / 10, 1.0)) - max(0.0, (t - 40) / 5)
    overload = float(np.clip(overload, 0.0, 1.0))
    lat = rng.lognormal(-2.5, 0.3, size=64).astype(np.float32)
    n_heavy = int(8 * overload)
    if n_heavy:
        lat[-n_heavy:] *= 20.0         # the tail the controller watches
    ratio = float(offload.latency_ratio(jnp.asarray(lat[None]))[0])
    state, R = offload.offload_update(state, jnp.asarray(lat[None]), cfg)
    pct = float(R[0])
    print(f"{t:>3} {ratio:>8.2f} {pct:>7.1f}  {'#' * int(pct / 2)}")

print("\nR_t rises only while the edge shows heavy tails, and decays "
      "back to 0 when the edge drains — Eqs (1)-(4) in action.")
