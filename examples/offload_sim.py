"""Reproduce the paper's experiment end-to-end in the simulator, including
the beyond-paper network-aware controller the paper's §4.2 asks for.

Runs the MatMult workload (the paper's network-bottleneck case) under:
  - edge-only (0%),
  - full offload (100%) — saturates the 100 MB/s edge->cloud link,
  - the paper's auto controller,
  - auto + net_aware=True (our extension: caps offload at link capacity).

    PYTHONPATH=src python examples/offload_sim.py
"""

import numpy as np

from repro.platform import (Continuum, SimConfig, Topology, Trace,
                            edge_brownout, tier_outage, merge_schedules)

# push the ramp high enough that the paper controller wants ~100% offload
# while the 100 MB/s link can only carry part of it — the regime where the
# paper observes "offloading makes it worse"
cfg = SimConfig(duration_s=300.0, high_rps=28.0)

rows = []
for label, policy in (
    ("edge-only", 0.0),
    ("100% offload", 100.0),
    ("auto (paper)", "auto"),
    ("auto+net-aware", "auto+net"),     # beyond-paper extension
    ("auto+migrate", "auto+migrate"),   # also moves IN-SERVICE work
):
    rows.append((label, Continuum.simulate("matmult", policy, cfg)))

print(f"{'policy':>16} {'ok':>6} {'fail':>5} {'lat(s)':>8} {'net peak':>9} "
      f"{'off peak':>8}")
for label, r in rows:
    print(f"{label:>16} {r.successes:>6} {r.failures:>5} "
          f"{np.nanmean(r.latency_avg):>8.3f} "
          f"{np.nanmax(r.net_MBps):>8.1f}M "
          f"{np.nanmax(r.offload_pct):>7.0f}%")

print("""
Reading the table:
  * edge-only drops requests once the ramp exceeds edge capacity;
  * 100% offload pushes everything through the 100 MB/s link — when the
    link is the bottleneck the paper notes offloading 'makes it worse';
  * the paper's auto controller lands between the extremes;
  * the net-aware variant keeps offload below link saturation — the
    'more sophisticated strategy' the paper's §4.2 calls for;
  * auto+migrate additionally moves requests already IN SERVICE at the
    edge once R_t crosses its threshold (remaining work resumes in the
    cloud after the state crosses the link) — the edge drains during
    the burst instead of riding it out.""")
mig = rows[-1][1]
print(f"  auto+migrate moved {mig.migrations_fired} in-service requests "
      f"({mig.migrations_completed} landed, {mig.migrations_aborted} "
      f"aborted back to the edge)")

# ---- beyond two tiers: the same controller over a device/edge/cloud chain
topo = Topology.device_edge_cloud(device_slots=2, edge_slots=4,
                                  cloud_slots=64)
print(f"\n3-tier continuum ({' -> '.join(topo.names)}, waterfall spill on):")
print(f"{'policy':>16} {'ok':>6} {'fail':>5} {'spill':>6}  per-tier")
for label, policy in (("auto (3-tier)", "auto"), ("static 50%", 50.0)):
    r = Continuum.simulate("matmult", policy, cfg, topology=topo)
    per = " ".join(f"{n}={c}" for n, c in r.tier_counts.items())
    print(f"{label:>16} {r.successes:>6} {r.failures:>5} {r.spilled:>6}  {per}")

# ---- traces & chaos: replace the built-in Poisson ramp with a bursty
# MMPP trace, and inject faults mid-run — a link brownout followed by an
# edge outage.  Crashed-tier residents are replayed (never silently
# lost), and the conservation identity successes + failures == submitted
# holds through every fault.
trace = Trace.bursty(base_rps=4.0, burst_rps=24.0, duration_s=300.0,
                     mean_on_s=30.0, mean_off_s=40.0,
                     fn_names=("matmult",), seed=7)
faults = merge_schedules(
    edge_brownout(t0=60.0, t1=120.0, link=0, bw_mult=0.1, rtt_mult=5.0),
    tier_outage(t0=180.0, t1=220.0, tier=0))
print("\nbursty trace + brownout + edge outage (same trace, both policies):")
print(f"{'policy':>16} {'ok':>6} {'fail':>5} {'replayed':>8} {'faults':>6}")
for label, policy in (("static 50%", 50.0), ("auto+migrate", "auto+migrate")):
    faults.reset()
    r = Continuum.simulate("matmult", policy, cfg, trace=trace, faults=faults)
    assert r.successes + r.failures == r.submitted
    print(f"{label:>16} {r.successes:>6} {r.failures:>5} "
          f"{r.replayed:>8} {r.faults_applied:>6}")
