"""Serving engine: jitted prefill/decode steps + a continuous-batching
instance pool per tier.

The engine is the *data plane* the paper's control plane routes to. One
:class:`Endpoint` wraps a (config, params) pair with jitted ``prefill`` and
``decode`` steps and a slot-based KV cache pool (continuous batching:
requests claim/release slots independently; one decode step advances every
active slot). Latency per request is what feeds the paper's Eq (1).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    """One inference request (token ids in, token ids out)."""
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    max_new: int = 8
    arrival_s: float = 0.0
    # filled by the engine:
    output: Optional[np.ndarray] = None
    t_first: float = 0.0
    t_done: float = 0.0


def _cache_batch_axes(cfg: ModelConfig, slots: int, max_len: int) -> list:
    """Per-leaf slot-axis of the cache pytree, or None for leaves that do
    not depend on the batch size.

    Derived exactly (not guessed from shapes, which is ambiguous when e.g.
    num_layers == slots): the slot axis is wherever the abstract cache
    shape changes when the batch size does.
    """
    a = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots, max_len, abstract=True))
    b = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots + 1, max_len, abstract=True))
    axes = []
    for la, lb in zip(a, b):
        axis = None
        for i, (x, y) in enumerate(zip(la.shape, lb.shape)):
            if x != y:
                axis = i
                break
        axes.append(axis)
    return axes


def _copy_slot_row(dst: jax.Array, src: jax.Array, slot: jax.Array,
                   axis) -> jax.Array:
    """Copy one slot's row of ``src`` into ``dst`` along ``axis``."""
    if axis is None:
        return dst
    idx = (slice(None),) * axis + (slot,)
    return dst.at[idx].set(src[idx])


class Endpoint:
    """A deployed model ("Knative Service" analogue) on one tier.

    ``slots`` is the max concurrent sequences (the KV cache pool size);
    requests batch up to ``slots`` per decode step — the TPU-idiomatic
    version of request concurrency.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 256, donate: bool = True):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model_zoo.init_cache(cfg, slots, max_len)
        self.slot_pos = np.zeros(slots, np.int32)          # next position
        self.slot_free = [True] * slots

        def _prefill(params, batch, cache):
            return model_zoo.prefill(cfg, params, batch, cache)

        def _decode(params, cache, tokens, t):
            return model_zoo.decode(cfg, params, cache, tokens, t)

        batch_axes = _cache_batch_axes(cfg, slots, max_len)

        def _rows(cache, src, slot):
            leaves, treedef = jax.tree_util.tree_flatten(cache)
            src_leaves = jax.tree_util.tree_leaves(src)
            out = [_copy_slot_row(c, s, slot, ax)
                   for c, s, ax in zip(leaves, src_leaves, batch_axes)]
            return jax.tree_util.tree_unflatten(treedef, out)

        def _reset_slot(cache, slot):
            return _rows(cache, model_zoo.init_cache(cfg, slots, max_len),
                         slot)

        def _restore_slot(cache, snap, slot):
            return _rows(cache, snap, slot)

        # ``donate`` governs both jitted steps: each call consumes the old
        # cache buffer (we always rebind ``self.cache`` to the result).
        dn = (2,) if donate else ()
        self._prefill = jax.jit(_prefill, donate_argnums=dn)
        self._decode = jax.jit(_decode, donate_argnums=(1,) if donate else ())
        self._reset = jax.jit(_reset_slot, donate_argnums=(0,) if donate else ())
        self._restore = jax.jit(_restore_slot,
                                donate_argnums=(0,) if donate else ())
        # Attention caches are self-healing on slot reuse (a cache index is
        # always overwritten at position == index before any query can
        # attend it), so only families that thread recurrent state through
        # prefill need their rows scrubbed between requests.
        self._reset_on_claim = cfg.family not in ("dense", "moe")

    # -- slot management ---------------------------------------------------
    def try_claim(self) -> Optional[int]:
        for i, free in enumerate(self.slot_free):
            if free:
                self.slot_free[i] = False
                if self._reset_on_claim:
                    self.reset_slot(i)
                return i
        return None

    def reset_slot(self, slot: int) -> None:
        """Restore one slot's cache rows to their init values.

        Required between requests for recurrent families (rwkv6 / hymba's
        SSM lanes), whose prefill starts from the row's *current* state — a
        reused slot would otherwise leak the previous request's state into
        the next prompt.
        """
        self.cache = self._reset(self.cache, jnp.asarray(slot, jnp.int32))

    def release(self, slot: int) -> None:
        self.slot_free[slot] = True
        self.slot_pos[slot] = 0

    @property
    def active(self) -> int:
        return sum(not f for f in self.slot_free)

    # -- steps --------------------------------------------------------------
    def prefill_one(self, slot: int, tokens: np.ndarray) -> int:
        """Run prefill for a single request into its slot's cache rows.

        Returns the first generated token.
        """
        return self.prefill_batch({slot: tokens})[slot]

    def prefill_batch(self, prompts: Dict[int, np.ndarray]) -> Dict[int, int]:
        """Pack multiple claimed slots' prompts into shared prefill calls.

        Prompts of equal length share one jitted prefill at batch=slots
        (continuous batching's admission step); distinct lengths run one
        call per length — recurrent families thread per-row state token by
        token, so rows cannot be padded to a common length without
        polluting that state. Returns slot -> first generated token.
        """
        by_len: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        for slot, toks in prompts.items():
            by_len.setdefault(len(toks), []).append((slot, toks))
        out: Dict[int, int] = {}
        # A prefill call writes cache rows for *every* batch row, so it
        # would clobber busy rows outside the current length group: slots
        # mid-decode, rows an earlier group just filled, and — for
        # recurrent families, whose state a zero-token prefill advances —
        # claimed rows a later group has yet to fill.  (Attention rows of
        # later groups need no protection: groups run shortest-first, so
        # their own prefill fully overwrites the polluted positions.)
        external = [s for s in range(self.slots)
                    if not self.slot_free[s] and s not in prompts]
        done: List[int] = []
        for L, group in sorted(by_len.items()):
            group_slots = {slot for slot, _ in group}
            protect = external + done
            if self._reset_on_claim:            # recurrent state families
                protect = [s for s in range(self.slots)
                           if not self.slot_free[s] and s not in group_slots]
            snap = (jax.tree_util.tree_map(jnp.copy, self.cache)
                    if protect else None)
            tok = np.zeros((self.slots, L), np.int32)
            for slot, toks in group:
                tok[slot] = toks
            logits, self.cache = self._prefill(
                self.params, {"tokens": jnp.asarray(tok)}, self.cache)
            for s in protect:
                self.cache = self._restore(self.cache, snap,
                                           jnp.asarray(s, jnp.int32))
            lg = np.asarray(logits)
            for slot, _ in group:
                self.slot_pos[slot] = L
                out[slot] = int(np.argmax(lg[slot]))
                done.append(slot)
        return out

    def decode_all(self, tokens_by_slot: Dict[int, int]) -> Dict[int, int]:
        """One decode step for every active slot. tokens_by_slot maps
        slot -> last emitted token. Returns slot -> next token."""
        tok = np.zeros(self.slots, np.int32)
        t = np.asarray(self.slot_pos, np.int32)
        for s, v in tokens_by_slot.items():
            tok[s] = v
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok), jnp.asarray(t))
        out = {}
        lg = np.asarray(logits)
        for s in tokens_by_slot:
            self.slot_pos[s] += 1
            out[s] = int(np.argmax(lg[s]))
        return out


def make_serve_step(cfg: ModelConfig,
                    mode: str) -> Callable:
    """The pure functions the dry-run lowers (no engine state).

    mode="prefill": (params, batch, cache) -> (last_logits, cache)
    mode="decode":  (params, cache, tokens, t) -> (logits, cache)
    """
    if mode == "prefill":
        def serve_step(params, batch, cache):
            return model_zoo.prefill(cfg, params, batch, cache)
        return serve_step
    if mode == "decode":
        def serve_step(params, cache, tokens, t):
            return model_zoo.decode(cfg, params, cache, tokens, t)
        return serve_step
    raise ValueError(mode)
