"""Serving engine: jitted prefill/decode steps + a continuous-batching
instance pool per tier.

The engine is the *data plane* the paper's control plane routes to. One
:class:`Endpoint` wraps a (config, params) pair with jitted ``prefill`` and
``decode`` steps and a KV cache pool (continuous batching: requests claim/
release slots independently; one decode step advances every active slot).
Latency per request is what feeds the paper's Eq (1).

The pool has two layouts:

* **dense** (default): one contiguous ``max_len`` cache row per slot —
  slot count caps concurrency regardless of how much context each row
  actually holds.
* **paged** (``paged=True``): the pool is ``total_pages`` fixed
  ``page_size``-token pages (``repro.cache.PagePool``); each request
  claims a *page table* sized to its declared extent, requests sharing a
  system/function prompt reference the same prefix pages
  (``repro.cache.PrefixRegistry``, copy-on-write past the fork point),
  and an exact-prompt hit skips prefill compute entirely.  Decode
  gathers each row's pages into the same contiguous view the dense pool
  stores and runs the *same* jitted decode program, then scatters only
  the written page back — so the token stream is bit-identical to dense
  by construction (the TPU fast path replaces the XLA gather with the
  fused paged-attention kernel in ``kernels/decode_attention.py``).
  Migration ships only the *used* pages of a row.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import PagePool, PrefixRegistry, pages_for_tokens, \
    pages_needed, token_extent
from repro.models import model_zoo
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    """One inference request (token ids in, token ids out)."""
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    max_new: int = 8
    arrival_s: float = 0.0
    # filled by the engine:
    output: Optional[np.ndarray] = None
    t_first: float = 0.0
    t_done: float = 0.0
    # charged end-to-end latency as the platform accounts it (includes
    # backdated link-crossing charges the wall-clock stamps miss)
    latency_s: Optional[float] = None
    # set by the runtime when a bounded gateway rejects/drops the request
    # (the live 503) — ``output`` will never be filled
    failed: bool = False


@dataclasses.dataclass
class PagedRow:
    """One extracted paged row: the migration payload.

    Only the pages covering the row's filled positions are shipped
    (``page_leaves``: each paged cache leaf narrowed to ``n_pages``
    pages), plus the per-slot residual state (recurrent lanes,
    rolling-window blocks — leaves the pool does not page)."""
    n_pages: int
    pos: int
    page_leaves: List[jax.Array]
    resid_leaves: List[jax.Array]

    @property
    def nbytes(self) -> float:
        return float(sum(l.nbytes for l in self.page_leaves)
                     + sum(l.nbytes for l in self.resid_leaves))


def _cache_len_axes(cfg: ModelConfig, slots: int, max_len: int) -> list:
    """Per-leaf sequence-length axis of the cache pytree, or None for
    leaves whose size does not depend on ``max_len`` (recurrent state).

    Derived exactly, like :func:`_cache_batch_axes`: the length axis is
    wherever the abstract cache shape changes when ``max_len`` does.
    """
    a = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots, max_len, abstract=True))
    b = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots, max_len + 1, abstract=True))
    axes = []
    for la, lb in zip(a, b):
        axis = None
        for i, (x, y) in enumerate(zip(la.shape, lb.shape)):
            if x != y:
                axis = i
                break
        axes.append(axis)
    return axes


def _cache_batch_axes(cfg: ModelConfig, slots: int, max_len: int) -> list:
    """Per-leaf slot-axis of the cache pytree, or None for leaves that do
    not depend on the batch size.

    Derived exactly (not guessed from shapes, which is ambiguous when e.g.
    num_layers == slots): the slot axis is wherever the abstract cache
    shape changes when the batch size does.
    """
    a = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots, max_len, abstract=True))
    b = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots + 1, max_len, abstract=True))
    axes = []
    for la, lb in zip(a, b):
        axis = None
        for i, (x, y) in enumerate(zip(la.shape, lb.shape)):
            if x != y:
                axis = i
                break
        axes.append(axis)
    return axes


def _copy_slot_row(dst: jax.Array, src: jax.Array, slot: jax.Array,
                   axis) -> jax.Array:
    """Copy one slot's row of ``src`` into ``dst`` along ``axis``."""
    if axis is None:
        return dst
    idx = (slice(None),) * axis + (slot,)
    return dst.at[idx].set(src[idx])


def _broadcast_rows(template: jax.Array, axis, n: int) -> jax.Array:
    """Tile a single-row init template to ``n`` rows along ``axis`` (cache
    init values are row-independent, so one stored row stands for all)."""
    if axis is None:
        return template
    t = jnp.moveaxis(template, axis, 0)[0]
    t = jnp.broadcast_to(t, (n,) + t.shape)
    return jnp.moveaxis(t, 0, axis)


class Endpoint:
    """A deployed model ("Knative Service" analogue) on one tier.

    ``slots`` is the max concurrent sequences; requests batch up to
    ``slots`` per decode step — the TPU-idiomatic version of request
    concurrency.  With ``paged=True`` the KV pool is ``total_pages``
    pages of ``page_size`` tokens and admission is bounded by *pages*
    (memory actually reserved), not slots alone.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 256, donate: bool = True,
                 bucket_prefill: bool = True,
                 paged: bool = False, page_size: int = 16,
                 total_pages: Optional[int] = None,
                 prefix_cache: bool = True,
                 prefix_capacity: int = 64,
                 mesh=None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.bucket_prefill = bucket_prefill
        self.slot_pos = np.zeros(slots, np.int32)          # next position
        self.slot_free = [True] * slots
        self.peak_active = 0
        # ``mesh`` switches the endpoint to shard_map tensor-parallel
        # serving (repro.serving.sharded): params/KV sharded over the
        # mesh's "model" axis, token stream bit-identical to unsharded.
        self.mesh = mesh
        self._tp = int(mesh.shape["model"]) if mesh is not None else 1
        if self._tp > 1 and paged:
            raise ValueError(
                "paged=True is not supported on tensor-parallel endpoints "
                "(page gather/scatter would cross the kv-head sharding)")

        batch_axes = _cache_batch_axes(cfg, slots, max_len)
        self._batch_axes = batch_axes
        self._len_axes = _cache_len_axes(cfg, slots, max_len)
        # Single-row init template, built ONCE: reset_slot and the
        # bucketed-prefill fresh cache tile rows from it instead of
        # materializing a full pool-sized init_cache per call.
        self._row_init = model_zoo.init_cache(cfg, 1, max_len)
        self._row_leaves = jax.tree_util.tree_leaves(self._row_init)
        self._treedef = jax.tree_util.tree_structure(self._row_init)

        # -- paged layout ---------------------------------------------------
        self.paged = bool(paged)
        self.page_size = int(page_size)
        # A leaf pages iff it is per-slot AND its length axis is the full
        # context budget immediately after the slot axis (the standard KV
        # block layout).  Recurrent state and rolling-window blocks stay
        # per-slot ("residual") and move with the row as one unit.
        self._is_paged_leaf = [
            bax is not None and sax == bax + 1
            and leaf.shape[sax] == max_len
            for leaf, bax, sax in zip(self._row_leaves, batch_axes,
                                      self._len_axes)]
        if self.paged:
            if not bucket_prefill:
                raise ValueError("paged=True requires bucket_prefill=True")
            if not (0 < page_size <= max_len) or max_len % page_size:
                raise ValueError(
                    f"page_size must divide max_len ({max_len}), "
                    f"got {page_size}")
            if not any(self._is_paged_leaf):
                raise ValueError(
                    f"model family {cfg.family!r} has no pageable cache "
                    "leaves (no full-context KV blocks)")
            self.pages_per_row = -(-max_len // page_size)
            if total_pages is None:
                total_pages = slots * self.pages_per_row
            if total_pages < self.pages_per_row:
                raise ValueError(
                    f"total_pages={total_pages} cannot hold one full row "
                    f"({self.pages_per_row} pages)")
            self.total_pages = int(total_pages)
            self.pool = PagePool(self.total_pages, self.page_size)
            self.prefix: Optional[PrefixRegistry] = (
                PrefixRegistry(self.pool, prefix_capacity)
                if prefix_cache else None)
            # physical id of the reserved always-empty page that pads
            # every table to a fixed (slots, pages_per_row) device shape
            self._null_page = self.total_pages
            self._tables: List[Optional[List[int]]] = [None] * slots
            self._table_np = np.full((slots, self.pages_per_row),
                                     self._null_page, np.int32)
            # exact-prompt prefill hits pending their (free) first token
            self._pending_first: Dict[int, Tuple[int, int]] = {}
            # miss claims carrying a registrable prompt
            self._claim_meta: Dict[int, Optional[np.ndarray]] = {}
            self.prefill_hit_tokens = 0
            self.prefill_total_tokens = 0
            self.cache = self._init_paged_pool()
        else:
            self.pages_per_row = 0
            self.total_pages = 0
            self.pool = None
            self.prefix = None
            self.cache = model_zoo.init_cache(cfg, slots, max_len)

        # Model-function indirection: the closures below call these, so
        # the dense/sharded choice is made once, here, and every pool
        # operation (masking, scatter, migration slicing) stays shared.
        if self._tp > 1:
            from repro.serving import sharded
            tp_prefill, tp_decode, pspecs, cspecs = \
                sharded.make_tp_functions(cfg, mesh, self.cache)
            self.params = sharded.shard_params(params, mesh, pspecs)
            self.cache = sharded.shard_cache(self.cache, mesh, cspecs)

            def _model_prefill(params, batch, cache, lengths=None):
                tokens = batch["tokens"]
                if lengths is None:
                    # take_along_axis at lengths-1 == S-1 is bitwise
                    # equal to the unsharded x[:, -1:] branch
                    lengths = jnp.full((tokens.shape[0],), tokens.shape[1],
                                       jnp.int32)
                return tp_prefill(params, tokens, lengths, cache)

            _model_decode = tp_decode
        else:
            def _model_prefill(params, batch, cache, lengths=None):
                return model_zoo.prefill(cfg, params, batch, cache,
                                         lengths=lengths)

            def _model_decode(params, cache, tokens, t):
                return model_zoo.decode(cfg, params, cache, tokens, t)

        def _prefill(params, batch, cache):
            return _model_prefill(params, batch, cache)

        def _decode(params, cache, tokens, t, active):
            """One decode step with a per-row active mask: inactive rows
            keep their cache rows bit-for-bit.  Under continuous batching
            slots retire (and hedge losers are cancelled) mid-stream, so a
            freed row must not drift — KV rows must not collect writes at a
            stale position and recurrent state must not advance on the
            zero-token placeholder — while its neighbors keep decoding."""
            logits, new_cache = _model_decode(params, cache, tokens, t)
            old_leaves, treedef = jax.tree_util.tree_flatten(cache)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            out = []
            for o, n, ax in zip(old_leaves, new_leaves, batch_axes):
                if ax is None:
                    out.append(n)
                    continue
                shape = [1] * n.ndim
                shape[ax] = n.shape[ax]
                out.append(jnp.where(jnp.reshape(active, shape), n, o))
            return logits, jax.tree_util.tree_unflatten(treedef, out)

        def _rows(cache, src, slot):
            leaves, treedef = jax.tree_util.tree_flatten(cache)
            src_leaves = jax.tree_util.tree_leaves(src)
            out = [_copy_slot_row(c, s, slot, ax)
                   for c, s, ax in zip(leaves, src_leaves, batch_axes)]
            return jax.tree_util.tree_unflatten(treedef, out)

        def _reset_slot(cache, template, slot):
            """Restore one slot's rows from the single-row template.
            In paged mode only the residual (non-paged) leaves are
            per-slot; pool pages are scrubbed at allocation instead."""
            leaves, treedef = jax.tree_util.tree_flatten(cache)
            tmpl = jax.tree_util.tree_leaves(template)
            out = []
            for c, s, ax, pg in zip(leaves, tmpl, batch_axes,
                                    self._is_paged_leaf):
                if ax is None or (self.paged and pg):
                    out.append(c)
                    continue
                idx = (slice(None),) * ax + (slot,)
                src_idx = (slice(None),) * ax + (0,)
                out.append(c.at[idx].set(s[src_idx]))
            return jax.tree_util.tree_unflatten(treedef, out)

        def _restore_slot(cache, snap, slot):
            return _rows(cache, snap, slot)

        def _prefill_rows(params, tokens, lengths, template):
            """Bucketed prefill compute: run the group on a *fresh* small
            cache (batch = pow2 bucket, tiled from the single-row init
            template) and return the logits + filled rows.  Both pool
            layouts scatter from this same program, so a paged endpoint's
            prefill logits are bit-identical to a dense one's."""
            Bp = tokens.shape[0]
            small = jax.tree_util.tree_unflatten(
                self._treedef,
                [_broadcast_rows(l, ax, Bp)
                 for l, ax in zip(jax.tree_util.tree_leaves(template),
                                  batch_axes)])
            return _model_prefill(params, {"tokens": tokens}, small,
                                  lengths=lengths)

        def _scatter_rows(pool, small, slot_arr):
            """Scatter a prefilled group's rows into the dense pool at
            ``slot_arr`` — other slots are never touched."""
            G = slot_arr.shape[0]
            pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
            small_leaves = jax.tree_util.tree_leaves(small)
            out = []
            for pl, sl, ax in zip(pool_leaves, small_leaves, batch_axes):
                if ax is None:
                    out.append(pl)
                    continue
                rows = jax.lax.slice_in_dim(sl, 0, G, axis=ax)
                idx = (slice(None),) * ax + (slot_arr,)
                out.append(pl.at[idx].set(rows))
            return jax.tree_util.tree_unflatten(treedef, out)

        def _extract_row(cache, slot):
            """Slice one slot's cache rows out of the pool: a pytree of
            per-slot leaves (batch axis kept at size 1) that can be
            shipped to a peer endpoint of the same model/max_len —
            mid-stream migration's unit of state."""
            leaves = jax.tree_util.tree_leaves(cache)
            return [jnp.take(l, slot[None], axis=ax)
                    for l, ax in zip(leaves, batch_axes) if ax is not None]

        def _insert_row(cache, rows, slot):
            """Scatter one extracted row state into this pool at ``slot``
            (the other side of migration: resume without re-prefill)."""
            leaves, treedef = jax.tree_util.tree_flatten(cache)
            it = iter(rows)
            out = []
            for l, ax in zip(leaves, batch_axes):
                if ax is None:
                    out.append(l)
                    continue
                idx = (slice(None),) * ax + (slot[None],)
                out.append(l.at[idx].set(next(it)))
            return jax.tree_util.tree_unflatten(treedef, out)

        # ``donate`` governs every jitted step that consumes the cache
        # (we always rebind ``self.cache`` to the result).
        dn0 = (0,) if donate else ()
        self._prefill = jax.jit(_prefill, donate_argnums=(2,) if donate else ())
        self._prefill_rows = jax.jit(_prefill_rows)
        self._scatter_rows = jax.jit(_scatter_rows, donate_argnums=dn0)
        self._decode = jax.jit(_decode, donate_argnums=(1,) if donate else ())
        self._reset = jax.jit(_reset_slot, donate_argnums=dn0)
        self._restore = jax.jit(_restore_slot, donate_argnums=dn0)
        self._extract = jax.jit(_extract_row)
        self._insert = jax.jit(_insert_row, donate_argnums=dn0)
        if self.paged:
            self._build_paged_ops(donate)
        # Length padding is sound only for the dense family: causal
        # masking hides padded positions there, but recurrent state
        # threads through every token, and MoE expert capacity is
        # sequence-global (C scales with padded S and padding tokens
        # compete for expert slots, perturbing real-token logits).  It
        # must also stay below any rolling-window width (padding must not
        # wrap over live keys).
        self._pad_len = cfg.family == "dense"
        self._len_cap = max_len
        if cfg.sliding_window is not None:
            self._len_cap = min(self._len_cap, cfg.sliding_window)
        # Attention caches are self-healing on slot reuse (a cache index is
        # always overwritten at position == index before any query can
        # attend it), so only families that thread recurrent state through
        # prefill need their rows scrubbed between requests — and only on
        # the legacy full-pool path; the bucketed path always prefills
        # rows from a fresh cache.
        self._reset_on_claim = (cfg.family not in ("dense", "moe")
                                and not bucket_prefill)

    # -- paged pool construction -------------------------------------------
    def _init_paged_pool(self):
        """Build the pooled cache pytree: paged leaves hold
        ``total_pages + 1`` pages (the extra one is the reserved null
        page), residual leaves keep their per-slot dense layout."""
        leaves = []
        for l, bax, pg in zip(self._row_leaves, self._batch_axes,
                              self._is_paged_leaf):
            if pg:
                leaves.append(_broadcast_rows(
                    self._page_template(l, bax), bax, self.total_pages + 1))
            elif bax is not None:
                leaves.append(_broadcast_rows(l, bax, self.slots))
            else:
                leaves.append(l)
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def _page_template(self, row_leaf, bax):
        """One init page of a paged leaf (init values are position-uniform,
        so the first ``page_size`` positions of the template row serve)."""
        sl = [slice(None)] * row_leaf.ndim
        sl[bax + 1] = slice(0, self.page_size)
        return row_leaf[tuple(sl)]

    def _build_paged_ops(self, donate: bool) -> None:
        batch_axes = self._batch_axes
        is_paged = self._is_paged_leaf
        page, ppr = self.page_size, self.pages_per_row
        page_tmpl = [self._page_template(l, bax)
                     for l, bax, pg in zip(self._row_leaves, batch_axes,
                                           is_paged) if pg]
        paged_bax = [bax for bax, pg in zip(batch_axes, is_paged) if pg]

        def _split(cache):
            leaves = jax.tree_util.tree_leaves(cache)
            return leaves

        def _gather(cache, tables):
            """Pooled pages -> the contiguous per-slot view the dense pool
            stores (same values, same layout: the decode program is shared
            with dense mode, pinning bit-identity)."""
            B = tables.shape[0]
            leaves = _split(cache)
            out = []
            for l, bax, pg in zip(leaves, batch_axes, is_paged):
                if not pg:
                    out.append(l)
                    continue
                g = jnp.take(l, tables.reshape(-1), axis=bax)
                shape = list(g.shape)
                split = shape[:bax] + [B, ppr, shape[bax + 1]] + shape[bax + 2:]
                merged = shape[:bax] + [B, ppr * shape[bax + 1]] + shape[bax + 2:]
                out.append(g.reshape(split).reshape(merged))
            return jax.tree_util.tree_unflatten(self._treedef, out)

        def _writeback(cache, new_dense, tables, t, active):
            """Scatter each active row's *written page* back into the pool
            (every other page is untouched by one decode step); residual
            leaves take the dense result wholesale."""
            B = tables.shape[0]
            wp = jnp.clip((t % self.max_len) // page, 0, ppr - 1)   # (B,)
            phys = tables[jnp.arange(B), wp]                        # (B,)
            pool_leaves = _split(cache)
            new_leaves = jax.tree_util.tree_leaves(new_dense)
            out = []
            for pl, nl, bax, pg in zip(pool_leaves, new_leaves, batch_axes,
                                       is_paged):
                if not pg:
                    out.append(nl if bax is not None else pl)
                    continue
                shape = list(nl.shape)
                d = nl.reshape(shape[:bax] + [B, ppr, page] + shape[bax + 2:])
                d = jnp.moveaxis(d, (bax, bax + 1), (0, 1))
                new_page = d[jnp.arange(B), wp]          # (B, ..., page, ...)
                old = jnp.moveaxis(jnp.take(pl, phys, axis=bax), bax, 0)
                mask = jnp.reshape(active, (B,) + (1,) * (new_page.ndim - 1))
                val = jnp.where(mask, new_page, old)
                pooled = jnp.moveaxis(pl, bax, 0).at[phys].set(val)
                out.append(jnp.moveaxis(pooled, 0, bax))
            return jax.tree_util.tree_unflatten(self._treedef, out)

        def _scrub_pages(cache, pids):
            """Reset freshly-allocated pages to init values (their ``pos``
            entries in particular: a recycled page must not resurrect its
            previous owner's positional validity)."""
            leaves = _split(cache)
            out = []
            ti = iter(zip(page_tmpl, paged_bax))
            for l, pg in zip(leaves, is_paged):
                if not pg:
                    out.append(l)
                    continue
                tmpl, bax = next(ti)
                idx = (slice(None),) * bax + (pids,)
                out.append(l.at[idx].set(tmpl))
            return jax.tree_util.tree_unflatten(self._treedef, out)

        def _copy_page(cache, src, dst):
            """The device half of a copy-on-write fork."""
            leaves = _split(cache)
            out = []
            for l, bax, pg in zip(leaves, batch_axes, is_paged):
                if not pg:
                    out.append(l)
                    continue
                d = (slice(None),) * bax + (dst,)
                s = (slice(None),) * bax + (src,)
                out.append(l.at[d].set(l[s]))
            return jax.tree_util.tree_unflatten(self._treedef, out)

        def _adopt_row(cache, small, row_i, pids, slot):
            """Move one prefilled row from the fresh group cache into the
            pool: its first ``len(pids)`` pages into the paged leaves,
            its residual state into the slot's dense rows."""
            n = pids.shape[0]
            pool_leaves = _split(cache)
            small_leaves = jax.tree_util.tree_leaves(small)
            out = []
            for pl, sl, bax, pg in zip(pool_leaves, small_leaves, batch_axes,
                                       is_paged):
                if bax is None:
                    out.append(pl)
                    continue
                if not pg:
                    idx = (slice(None),) * bax + (slot,)
                    src = (slice(None),) * bax + (row_i,)
                    out.append(pl.at[idx].set(sl[src]))
                    continue
                shape = list(sl.shape)
                row = jnp.take(sl, row_i, axis=bax)      # drop batch axis
                row = row.reshape(shape[:bax] + [ppr, page] + shape[bax + 2:])
                pages = jax.lax.slice_in_dim(row, 0, n, axis=bax)
                idx = (slice(None),) * bax + (pids,)
                out.append(pl.at[idx].set(pages))
            return jax.tree_util.tree_unflatten(self._treedef, out)

        def _take_pages(cache, pids):
            """Gather page contents (migration extract)."""
            leaves = _split(cache)
            return [jnp.take(l, pids, axis=bax)
                    for l, bax, pg in zip(leaves, batch_axes, is_paged)
                    if pg]

        def _put_pages(cache, page_leaves, pids):
            """Scatter shipped page contents (migration insert)."""
            leaves = _split(cache)
            it = iter(page_leaves)
            out = []
            for l, bax, pg in zip(leaves, batch_axes, is_paged):
                if not pg:
                    out.append(l)
                    continue
                idx = (slice(None),) * bax + (pids,)
                out.append(l.at[idx].set(next(it)))
            return jax.tree_util.tree_unflatten(self._treedef, out)

        def _take_resid(cache, slot):
            leaves = _split(cache)
            return [jnp.take(l, slot[None], axis=bax)
                    for l, bax, pg in zip(leaves, batch_axes, is_paged)
                    if bax is not None and not pg]

        def _put_resid(cache, resid, slot):
            leaves = _split(cache)
            it = iter(resid)
            out = []
            for l, bax, pg in zip(leaves, batch_axes, is_paged):
                if bax is None or pg:
                    out.append(l)
                    continue
                idx = (slice(None),) * bax + (slot[None],)
                out.append(l.at[idx].set(next(it)))
            return jax.tree_util.tree_unflatten(self._treedef, out)

        dn0 = (0,) if donate else ()
        self._gather = jax.jit(_gather)
        self._writeback = jax.jit(_writeback, donate_argnums=dn0)
        self._scrub = jax.jit(_scrub_pages, donate_argnums=dn0)
        self._cow = jax.jit(_copy_page, donate_argnums=dn0)
        self._adopt = jax.jit(_adopt_row, donate_argnums=dn0)
        self._take_pages = jax.jit(_take_pages)
        self._put_pages = jax.jit(_put_pages, donate_argnums=dn0)
        self._take_resid = jax.jit(_take_resid)
        self._put_resid = jax.jit(_put_resid, donate_argnums=dn0)

    # -- paged bookkeeping ---------------------------------------------------
    @property
    def free_pages(self) -> int:
        return self.pool.free_pages if self.paged else 0

    @property
    def used_pages(self) -> int:
        return self.pool.used_pages if self.paged else 0

    def page_need(self, prompt_len: int, max_new: int) -> int:
        """Pages a fresh request of this size must be able to reserve
        (ignores prefix sharing: an admission bound, never an overclaim)."""
        if not self.paged:
            return 0
        return pages_needed(prompt_len, max_new, self.page_size, self.max_len)

    def pages_for(self, n_tokens: int) -> int:
        """Pages reserving positions ``[0, n_tokens)`` (full row past
        ``max_len`` — the rolling-wrap case touches every page)."""
        if not self.paged:
            return 0
        if n_tokens > self.max_len:
            return self.pages_per_row
        return max(1, pages_for_tokens(n_tokens, self.page_size))

    def resident_page_demand(self) -> int:
        """Pages referenced by live page tables (shared pages count once
        per table — this is a *demand* signal, not an occupancy count)."""
        return sum(len(t) for t in self._tables if t is not None)

    @property
    def admissible_pages(self) -> int:
        """Pages a new claim could obtain: free pages plus pages pinned
        only by the prefix registry — those are reclaimable under
        pressure (:meth:`_alloc` evicts LRU prefixes until an allocation
        fits), so admission control must count them as available."""
        pinned: set = set()
        for t in self._tables:
            if t is not None:
                pinned.update(t)
        return self.pool.num_pages - len(pinned)

    def _alloc(self, n: int) -> Optional[List[int]]:
        """Pool allocation with registry back-pressure: when the free
        list falls short, evict LRU prefix entries (their pages free once
        no live row shares them) and retry — a request is never refused
        memory that only the prefix cache is holding."""
        ids = self.pool.alloc(n)
        while (ids is None and self.prefix is not None
               and len(self.prefix)):
            self.prefix.evict_lru()
            ids = self.pool.alloc(n)
        return ids

    @property
    def pool_nbytes(self) -> float:
        """Bytes of the KV page pool (paged) or of the per-slot KV rows
        (dense) — the denominator of resident-requests-per-GB."""
        total = 0.0
        leaves = jax.tree_util.tree_leaves(self.cache)
        for leaf, sax, pg in zip(leaves, self._len_axes, self._is_paged_leaf):
            if self.paged:
                if pg:
                    total += leaf.nbytes
            elif sax is not None:
                total += leaf.nbytes
        return total

    @property
    def prefill_hit_rate(self) -> float:
        """Fraction of offered prefill tokens whose KV was already
        resident (prefix-registry exact hits; 0 before any prefill)."""
        if not self.paged or self.prefill_total_tokens == 0:
            return 0.0
        return self.prefill_hit_tokens / self.prefill_total_tokens

    def _tables_device(self) -> jax.Array:
        return jnp.asarray(self._table_np)

    def _set_table(self, slot: int, table: List[int]) -> None:
        self._tables[slot] = table
        self._table_np[slot] = self._null_page
        self._table_np[slot, :len(table)] = table

    def _cow_page(self, slot: int, wp: int) -> None:
        """Copy-on-write fork page ``wp`` of ``slot``'s table."""
        table = self._tables[slot]
        fresh = self._alloc(1)
        if fresh is None:
            raise RuntimeError(
                f"page pool exhausted during copy-on-write (slot {slot})")
        self.cache = self._cow(self.cache,
                               jnp.asarray(table[wp], jnp.int32),
                               jnp.asarray(fresh[0], jnp.int32))
        self.pool.release([table[wp]])
        table[wp] = fresh[0]
        self._table_np[slot, wp] = fresh[0]

    def _grow_table(self, slot: int) -> None:
        """Append one scrubbed page (a caller decoded past its declared
        reservation)."""
        fresh = self._alloc(1)
        if fresh is None:
            raise RuntimeError(
                f"page pool exhausted growing slot {slot}'s table")
        self.cache = self._scrub(self.cache, jnp.asarray(fresh, jnp.int32))
        self._tables[slot].append(fresh[0])
        self._table_np[slot, len(self._tables[slot]) - 1] = fresh[0]

    # -- slot management ---------------------------------------------------
    def try_claim(self, tokens: Optional[np.ndarray] = None,
                  max_new: int = 1,
                  reserve_tokens: Optional[int] = None) -> Optional[int]:
        """Claim a slot (dense) or a slot *plus a page reservation*
        (paged).  Paged claims size the reservation from the request
        (``tokens``/``max_new``), from an explicit token extent
        (``reserve_tokens`` — the migration-landing path), or — with no
        size information — a conservative full row; an exact prompt match
        in the prefix registry shares the resident prefix pages
        (copy-on-write past the fork point) and arms a compute-free
        prefill.  Returns None when no slot (or no sufficient page run)
        is available; a failed paged claim allocates nothing."""
        slot = None
        for i, free in enumerate(self.slot_free):
            if free:
                slot = i
                break
        if slot is None:
            return None
        if self.paged:
            if not self._claim_pages(slot, tokens, max_new, reserve_tokens):
                return None
        self.slot_free[slot] = False
        self.peak_active = max(self.peak_active, self.active)
        if self._reset_on_claim:
            self.reset_slot(slot)
        return slot

    def _claim_pages(self, slot: int, tokens, max_new: int,
                     reserve_tokens: Optional[int]) -> bool:
        page = self.page_size
        if reserve_tokens is not None or tokens is None:
            n = (self.pages_for(reserve_tokens)
                 if reserve_tokens is not None else self.pages_per_row)
            ids = self._alloc(n)
            if ids is None:
                return False
            self.cache = self._scrub(self.cache, jnp.asarray(ids, jnp.int32))
            self._set_table(slot, ids)
            return True
        L = len(tokens)
        extent = token_extent(L, max_new)
        wrap = extent > self.max_len
        n_total = pages_needed(L, max_new, page, self.max_len)
        hit = (None if (wrap or self.prefix is None)
               else self.prefix.lookup(tokens))
        if hit is None:
            ids = self._alloc(n_total)
            if ids is None:
                return False
            self.cache = self._scrub(self.cache, jnp.asarray(ids, jnp.int32))
            self._set_table(slot, ids)
            # wrap rows touch every page, so their prompt pages can never
            # be pinned immutable — they are not registrable
            self._claim_meta[slot] = (np.asarray(tokens, np.int32)
                                      if (self.prefix is not None
                                          and not wrap) else None)
            return True
        # Exact-prompt hit: reference the resident prefix pages; the page
        # the first decode write lands in must be private (COW fork).
        n_pref = len(hit.page_ids)
        cow_partial = extent > L and L % page != 0
        fresh_needed = (n_total - n_pref) + (1 if cow_partial else 0)
        # retain BEFORE allocating: _alloc may evict this very entry
        # under pressure, and our references must keep its pages alive
        self.pool.retain(hit.page_ids)
        fresh = self._alloc(fresh_needed)
        if fresh is None:
            self.pool.release(hit.page_ids)
            return False
        table = list(hit.page_ids)
        fi = 0
        if cow_partial:
            cow = fresh[fi]
            fi += 1
            self.cache = self._cow(self.cache,
                                   jnp.asarray(table[L // page], jnp.int32),
                                   jnp.asarray(cow, jnp.int32))
            self.pool.release([table[L // page]])
            table[L // page] = cow
        tail = fresh[fi:]
        if tail:
            self.cache = self._scrub(self.cache, jnp.asarray(tail, jnp.int32))
            table += tail
        self._set_table(slot, table)
        self._pending_first[slot] = (hit.first_token, hit.length)
        return True

    def reset_slot(self, slot: int) -> None:
        """Restore one slot's cache rows to their init values.

        Required between requests for recurrent families (rwkv6 / hymba's
        SSM lanes), whose prefill starts from the row's *current* state — a
        reused slot would otherwise leak the previous request's state into
        the next prompt.  Copies from the single-row init template (built
        once in ``__init__``) rather than materializing a pool-sized init.
        """
        self.cache = self._reset(self.cache, self._row_init,
                                 jnp.asarray(slot, jnp.int32))

    def release(self, slot: int) -> None:
        self.slot_free[slot] = True
        self.slot_pos[slot] = 0
        if self.paged:
            table = self._tables[slot]
            if table is not None:
                self.pool.release(table)
            self._tables[slot] = None
            self._table_np[slot] = self._null_page
            self._pending_first.pop(slot, None)
            self._claim_meta.pop(slot, None)

    @property
    def active(self) -> int:
        return sum(not f for f in self.slot_free)

    # -- mid-stream migration state -----------------------------------------
    def compatible_with(self, other: "Endpoint") -> bool:
        """Row states are interchangeable between two endpoints iff they
        serve the same model at the same context budget with the same
        pool layout (every shipped leaf then has identical non-batch
        dimensions)."""
        return (other.cfg is self.cfg and other.max_len == self.max_len
                and other.paged == self.paged
                and (not self.paged or other.page_size == self.page_size)
                and getattr(other, "_tp", 1) == self._tp)

    def extract_rows(self, slots: List[int]) -> List[Any]:
        """Slice the given slots' cache rows out of the pool.

        Dense pool: one full row state per slot (each cache leaf with the
        batch axis narrowed to size 1).  Paged pool: a :class:`PagedRow`
        carrying only the pages covering the row's *filled* positions
        plus its residual leaves — a partially-filled row ships strictly
        fewer bytes than a full dense row.
        """
        if not self.paged:
            return [self._extract(self.cache, jnp.asarray(s, jnp.int32))
                    for s in slots]
        out = []
        for s in slots:
            pos = int(self.slot_pos[s])
            n = min(self.pages_for(max(pos, 1)), len(self._tables[s]))
            pids = jnp.asarray(self._tables[s][:n], jnp.int32)
            out.append(PagedRow(
                n_pages=n, pos=pos,
                page_leaves=self._take_pages(self.cache, pids),
                resid_leaves=self._take_resid(self.cache,
                                              jnp.asarray(s, jnp.int32))))
        return out

    def insert_rows(self, rows: List[Any], slots: List[int],
                    positions: List[int]) -> None:
        """Scatter extracted row states into *claimed* slots of this pool
        and set their decode positions — the receiving half of mid-stream
        migration: decode resumes at ``positions`` with no re-prefill.
        Paged rows land in the slot's reserved pages (grown on demand if
        the reservation was tighter than the shipped state).
        """
        for state, slot, pos in zip(rows, slots, positions):
            if not self.paged:
                self.cache = self._insert(self.cache, state,
                                          jnp.asarray(slot, jnp.int32))
            else:
                while len(self._tables[slot]) < state.n_pages:
                    self._grow_table(slot)
                pids = jnp.asarray(self._tables[slot][:state.n_pages],
                                   jnp.int32)
                self.cache = self._put_pages(self.cache, state.page_leaves,
                                             pids)
                self.cache = self._put_resid(self.cache, state.resid_leaves,
                                             jnp.asarray(slot, jnp.int32))
            self.slot_pos[slot] = min(pos, self.max_len)

    def cache_nbytes_per_row(self, length: int) -> float:
        """Bytes of one slot's live cache state at decode position
        ``length`` — what a migration actually ships over a link.

        Leaves with a sequence axis (KV blocks) count only their filled
        positions; recurrent state leaves (no length axis) count in full.
        In paged mode the filled extent rounds UP to page granularity —
        the transfer ships whole pages, and ``_Transit.nbytes``,
        ``link_MB`` and the simulator's payload model must agree on what
        actually crosses the link.

        Bytes are computed from each leaf's *logical* shape and dtype —
        never from its device buffer footprint.  A replicated or sharded
        template leaf on a multi-device (tensor-parallel) endpoint can
        report a physical ``nbytes`` that multiplies per device replica,
        but a migration ships the logical row exactly once.
        """
        if self.paged:
            eff = min(self.pages_for(max(length, 1)) * self.page_size,
                      self.max_len)
        else:
            eff = min(length, self.max_len)
        total = 0.0
        for leaf, bax, sax in zip(self._row_leaves, self._batch_axes,
                                  self._len_axes):
            if bax is None:
                continue
            # template: batch axis = 1, so this is already per-row
            per_row = float(np.prod(leaf.shape)
                            * np.dtype(leaf.dtype).itemsize)
            if sax is not None:
                per_row *= eff / leaf.shape[sax]
            total += per_row
        return total

    # -- steps --------------------------------------------------------------
    def prefill_one(self, slot: int, tokens: np.ndarray) -> int:
        """Run prefill for a single request into its slot's cache rows.

        Returns the first generated token.
        """
        return self.prefill_batch({slot: tokens})[slot]

    def prefill_batch(self, prompts: Dict[int, np.ndarray]) -> Dict[int, int]:
        """Pack multiple claimed slots' prompts into shared prefill calls.

        Prompts are grouped by length; each group runs one jitted prefill
        at a power-of-two *bucketed* batch (next pow2 >= group size, capped
        at the pool) on a fresh cache whose rows are scattered into the
        pool — small waves stop paying full-pool prefill cost, and a
        handful of compiled shapes are reused.  Pure-attention families
        additionally right-pad each group to a power-of-two length (causal
        masking keeps the padded tail inert).  Recurrent families thread
        per-row state token by token, so their rows are never length-padded.

        In paged mode, slots whose claim hit the prefix registry skip
        compute entirely: their prompt pages are already resident and the
        registered first token seeds their stream (bit-identical to a
        fresh prefill — the registering prefill ran the same program on
        the same inputs).  Missing prompts prefill normally, land in the
        slot's reserved pages, and register themselves for the next
        invocation.  Returns slot -> first generated token.
        """
        if self.paged:
            self.prefill_total_tokens += sum(
                len(t) for t in prompts.values())
            out: Dict[int, int] = {}
            miss: Dict[int, np.ndarray] = {}
            for slot, toks in prompts.items():
                pend = self._pending_first.pop(slot, None)
                if pend is not None:
                    first, L = pend
                    self.slot_pos[slot] = L
                    self.prefill_hit_tokens += L
                    out[slot] = first
                else:
                    miss[slot] = toks
            if miss:
                out.update(self._prefill_batch_bucketed(miss))
            return out
        if self.bucket_prefill:
            return self._prefill_batch_bucketed(prompts)
        return self._prefill_batch_padded(prompts)

    def _prefill_batch_bucketed(self,
                                prompts: Dict[int, np.ndarray]
                                ) -> Dict[int, int]:
        by_len: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        for slot, toks in prompts.items():
            by_len.setdefault(len(toks), []).append((slot, toks))
        out: Dict[int, int] = {}
        for L, group in sorted(by_len.items()):
            G = len(group)
            Bp = min(self.slots, max(1, 1 << (G - 1).bit_length()))
            Lb = L
            if self._pad_len:
                cand = 1 << max(L - 1, 0).bit_length()
                if L <= cand <= self._len_cap:
                    Lb = cand
            # Pad batch rows AND the scatter index to the pow2 bucket by
            # duplicating the last real row: jit then only ever sees
            # power-of-two shapes, and the duplicate scatter writes carry
            # identical row values (rows are batch-independent), so the
            # overlapping update is value-deterministic.
            tok = np.zeros((Bp, Lb), np.int32)
            slot_arr = np.zeros(Bp, np.int32)
            for i in range(Bp):
                slot, toks = group[min(i, G - 1)]
                tok[i, :L] = toks
                slot_arr[i] = slot
            lengths = (jnp.full(Bp, L, jnp.int32) if self._pad_len else None)
            logits, small = self._prefill_rows(
                self.params, jnp.asarray(tok), lengths, self._row_init)
            if self.paged:
                self._adopt_group(group, small, L)
            else:
                self.cache = self._scatter_rows(self.cache, small,
                                                jnp.asarray(slot_arr))
            lg = np.asarray(logits)
            for i, (slot, _) in enumerate(group):
                self.slot_pos[slot] = L
                out[slot] = int(np.argmax(lg[i]))
                if self.paged:
                    self._register_prefix(slot, out[slot])
        return out

    def _adopt_group(self, group, small, L: int) -> None:
        """Scatter one prefilled length group's rows into their slots'
        reserved pages (paged pools have no contiguous rows to scatter
        into)."""
        n = self.pages_for(max(L, 1))
        for i, (slot, _) in enumerate(group):
            pids = jnp.asarray(self._tables[slot][:n], jnp.int32)
            self.cache = self._adopt(self.cache, small,
                                     jnp.asarray(i, jnp.int32), pids,
                                     jnp.asarray(slot, jnp.int32))

    def _register_prefix(self, slot: int, first_token: int) -> None:
        """Publish a just-prefilled prompt to the prefix registry.  The
        registry's view must stay immutable while the owning row decodes
        on, so a partially-filled last page is registered as a private
        copy (the full pages are shared as-is: the owner never rewrites
        positions below its prompt length)."""
        meta = self._claim_meta.pop(slot, None)
        if meta is None or self.prefix is None:
            return
        L = len(meta)
        n = self.pages_for(max(L, 1))
        reg_ids = list(self._tables[slot][:n])
        copied = None
        if L % self.page_size != 0:
            cp = self._alloc(1)
            if cp is None:
                return                 # pool too tight to pin: skip
            self.cache = self._cow(self.cache,
                                   jnp.asarray(reg_ids[-1], jnp.int32),
                                   jnp.asarray(cp[0], jnp.int32))
            reg_ids[-1] = cp[0]
            copied = cp
        self.prefix.register(meta, reg_ids, first_token)
        if copied is not None:
            # the registry holds its own reference now (or declined to)
            self.pool.release(copied)

    def _prefill_batch_padded(self,
                              prompts: Dict[int, np.ndarray]
                              ) -> Dict[int, int]:
        """Legacy path: every length group pads to batch=slots and runs on
        the pool cache, snapshot-protecting busy rows (kept as the
        before/after baseline for ``benchmarks/serving_bench.py``)."""
        by_len: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        for slot, toks in prompts.items():
            by_len.setdefault(len(toks), []).append((slot, toks))
        out: Dict[int, int] = {}
        # A prefill call writes cache rows for *every* batch row, so it
        # would clobber busy rows outside the current length group: slots
        # mid-decode, rows an earlier group just filled, and — for
        # recurrent families, whose state a zero-token prefill advances —
        # claimed rows a later group has yet to fill.  (Attention rows of
        # later groups need no protection: groups run shortest-first, so
        # their own prefill fully overwrites the polluted positions.)
        external = [s for s in range(self.slots)
                    if not self.slot_free[s] and s not in prompts]
        done: List[int] = []
        for L, group in sorted(by_len.items()):
            group_slots = {slot for slot, _ in group}
            protect = external + done
            if self._reset_on_claim:            # recurrent state families
                protect = [s for s in range(self.slots)
                           if not self.slot_free[s] and s not in group_slots]
            snap = (jax.tree_util.tree_map(jnp.copy, self.cache)
                    if protect else None)
            tok = np.zeros((self.slots, L), np.int32)
            for slot, toks in group:
                tok[slot] = toks
            logits, self.cache = self._prefill(
                self.params, {"tokens": jnp.asarray(tok)}, self.cache)
            for s in protect:
                self.cache = self._restore(self.cache, snap,
                                           jnp.asarray(s, jnp.int32))
            lg = np.asarray(logits)
            for slot, _ in group:
                self.slot_pos[slot] = L
                out[slot] = int(np.argmax(lg[slot]))
                done.append(slot)
        return out

    def decode_all(self, tokens_by_slot: Dict[int, int]) -> Dict[int, int]:
        """One decode step for every active slot. tokens_by_slot maps
        slot -> last emitted token. Returns slot -> next token.

        Slots outside ``tokens_by_slot`` are masked inactive for the step:
        their cache rows (KV positions, recurrent state) are untouched, so
        rows that retired or were cancelled mid-stream stay frozen while
        their neighbors decode.

        Paged pools first guarantee every stepping row's *write page* is
        private (copy-on-write fork of a still-shared page, one lazily
        grown page for rows decoding past their reservation), then gather
        pages into the contiguous per-row view, run the same jitted
        decode program dense mode runs, and scatter only the written
        pages back."""
        tok = np.zeros(self.slots, np.int32)
        act = np.zeros(self.slots, bool)
        t = np.asarray(self.slot_pos, np.int32)
        for s, v in tokens_by_slot.items():
            tok[s] = v
            act[s] = True
        if not self.paged:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tok),
                                              jnp.asarray(t),
                                              jnp.asarray(act))
        else:
            for s in tokens_by_slot:
                wp = (int(self.slot_pos[s]) % self.max_len) // self.page_size
                while wp >= len(self._tables[s]):
                    self._grow_table(s)
                if self.pool.is_shared(self._tables[s][wp]):
                    self._cow_page(s, wp)
            tables = self._tables_device()
            dense = self._gather(self.cache, tables)
            logits, new_dense = self._decode(self.params, dense,
                                             jnp.asarray(tok),
                                             jnp.asarray(t),
                                             jnp.asarray(act))
            self.cache = self._writeback(self.cache, new_dense, tables,
                                         jnp.asarray(t), jnp.asarray(act))
        out = {}
        lg = np.asarray(logits)
        for s in tokens_by_slot:
            self.slot_pos[s] += 1
            out[s] = int(np.argmax(lg[s]))
        return out


def make_serve_step(cfg: ModelConfig,
                    mode: str) -> Callable:
    """The pure functions the dry-run lowers (no engine state).

    mode="prefill": (params, batch, cache) -> (last_logits, cache)
    mode="decode":  (params, cache, tokens, t) -> (logits, cache)
    """
    if mode == "prefill":
        def serve_step(params, batch, cache):
            return model_zoo.prefill(cfg, params, batch, cache)
        return serve_step
    if mode == "decode":
        def serve_step(params, cache, tokens, t):
            return model_zoo.decode(cfg, params, cache, tokens, t)
        return serve_step
    raise ValueError(mode)
