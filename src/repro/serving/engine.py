"""Serving engine: jitted prefill/decode steps + a continuous-batching
instance pool per tier.

The engine is the *data plane* the paper's control plane routes to. One
:class:`Endpoint` wraps a (config, params) pair with jitted ``prefill`` and
``decode`` steps and a slot-based KV cache pool (continuous batching:
requests claim/release slots independently; one decode step advances every
active slot). Latency per request is what feeds the paper's Eq (1).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    """One inference request (token ids in, token ids out)."""
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    max_new: int = 8
    arrival_s: float = 0.0
    # filled by the engine:
    output: Optional[np.ndarray] = None
    t_first: float = 0.0
    t_done: float = 0.0
    # charged end-to-end latency as the platform accounts it (includes
    # backdated link-crossing charges the wall-clock stamps miss)
    latency_s: Optional[float] = None
    # set by the runtime when a bounded gateway rejects/drops the request
    # (the live 503) — ``output`` will never be filled
    failed: bool = False


def _cache_len_axes(cfg: ModelConfig, slots: int, max_len: int) -> list:
    """Per-leaf sequence-length axis of the cache pytree, or None for
    leaves whose size does not depend on ``max_len`` (recurrent state).

    Derived exactly, like :func:`_cache_batch_axes`: the length axis is
    wherever the abstract cache shape changes when ``max_len`` does.
    """
    a = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots, max_len, abstract=True))
    b = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots, max_len + 1, abstract=True))
    axes = []
    for la, lb in zip(a, b):
        axis = None
        for i, (x, y) in enumerate(zip(la.shape, lb.shape)):
            if x != y:
                axis = i
                break
        axes.append(axis)
    return axes


def _cache_batch_axes(cfg: ModelConfig, slots: int, max_len: int) -> list:
    """Per-leaf slot-axis of the cache pytree, or None for leaves that do
    not depend on the batch size.

    Derived exactly (not guessed from shapes, which is ambiguous when e.g.
    num_layers == slots): the slot axis is wherever the abstract cache
    shape changes when the batch size does.
    """
    a = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots, max_len, abstract=True))
    b = jax.tree_util.tree_leaves(
        model_zoo.init_cache(cfg, slots + 1, max_len, abstract=True))
    axes = []
    for la, lb in zip(a, b):
        axis = None
        for i, (x, y) in enumerate(zip(la.shape, lb.shape)):
            if x != y:
                axis = i
                break
        axes.append(axis)
    return axes


def _copy_slot_row(dst: jax.Array, src: jax.Array, slot: jax.Array,
                   axis) -> jax.Array:
    """Copy one slot's row of ``src`` into ``dst`` along ``axis``."""
    if axis is None:
        return dst
    idx = (slice(None),) * axis + (slot,)
    return dst.at[idx].set(src[idx])


class Endpoint:
    """A deployed model ("Knative Service" analogue) on one tier.

    ``slots`` is the max concurrent sequences (the KV cache pool size);
    requests batch up to ``slots`` per decode step — the TPU-idiomatic
    version of request concurrency.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 256, donate: bool = True,
                 bucket_prefill: bool = True):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.bucket_prefill = bucket_prefill
        self.cache = model_zoo.init_cache(cfg, slots, max_len)
        self.slot_pos = np.zeros(slots, np.int32)          # next position
        self.slot_free = [True] * slots

        def _prefill(params, batch, cache):
            return model_zoo.prefill(cfg, params, batch, cache)

        batch_axes = _cache_batch_axes(cfg, slots, max_len)
        self._batch_axes = batch_axes
        self._len_axes = _cache_len_axes(cfg, slots, max_len)

        def _decode(params, cache, tokens, t, active):
            """One decode step with a per-row active mask: inactive rows
            keep their cache rows bit-for-bit.  Under continuous batching
            slots retire (and hedge losers are cancelled) mid-stream, so a
            freed row must not drift — KV rows must not collect writes at a
            stale position and recurrent state must not advance on the
            zero-token placeholder — while its neighbors keep decoding."""
            logits, new_cache = model_zoo.decode(cfg, params, cache, tokens, t)
            old_leaves, treedef = jax.tree_util.tree_flatten(cache)
            new_leaves = jax.tree_util.tree_leaves(new_cache)
            out = []
            for o, n, ax in zip(old_leaves, new_leaves, batch_axes):
                if ax is None:
                    out.append(n)
                    continue
                shape = [1] * n.ndim
                shape[ax] = n.shape[ax]
                out.append(jnp.where(jnp.reshape(active, shape), n, o))
            return logits, jax.tree_util.tree_unflatten(treedef, out)

        def _rows(cache, src, slot):
            leaves, treedef = jax.tree_util.tree_flatten(cache)
            src_leaves = jax.tree_util.tree_leaves(src)
            out = [_copy_slot_row(c, s, slot, ax)
                   for c, s, ax in zip(leaves, src_leaves, batch_axes)]
            return jax.tree_util.tree_unflatten(treedef, out)

        def _reset_slot(cache, slot):
            return _rows(cache, model_zoo.init_cache(cfg, slots, max_len),
                         slot)

        def _restore_slot(cache, snap, slot):
            return _rows(cache, snap, slot)

        def _prefill_fresh(params, tokens, pool, slot_arr, lengths):
            """Bucketed prefill: run the group on a *fresh* small cache
            (batch = pow2 bucket, not the full pool) and scatter only the
            claimed rows back, so other slots are never touched — no
            snapshot/restore protection needed."""
            small = model_zoo.init_cache(cfg, tokens.shape[0], max_len)
            logits, small = model_zoo.prefill(cfg, params, {"tokens": tokens},
                                              small, lengths=lengths)
            G = slot_arr.shape[0]
            pool_leaves, treedef = jax.tree_util.tree_flatten(pool)
            small_leaves = jax.tree_util.tree_leaves(small)
            out = []
            for pl, sl, ax in zip(pool_leaves, small_leaves, batch_axes):
                if ax is None:
                    out.append(pl)
                    continue
                rows = jax.lax.slice_in_dim(sl, 0, G, axis=ax)
                idx = (slice(None),) * ax + (slot_arr,)
                out.append(pl.at[idx].set(rows))
            return logits, jax.tree_util.tree_unflatten(treedef, out)

        def _extract_row(cache, slot):
            """Slice one slot's cache rows out of the pool: a pytree of
            per-slot leaves (batch axis kept at size 1) that can be
            shipped to a peer endpoint of the same model/max_len —
            mid-stream migration's unit of state."""
            leaves = jax.tree_util.tree_leaves(cache)
            return [jnp.take(l, slot[None], axis=ax)
                    for l, ax in zip(leaves, batch_axes) if ax is not None]

        def _insert_row(cache, rows, slot):
            """Scatter one extracted row state into this pool at ``slot``
            (the other side of migration: resume without re-prefill)."""
            leaves, treedef = jax.tree_util.tree_flatten(cache)
            it = iter(rows)
            out = []
            for l, ax in zip(leaves, batch_axes):
                if ax is None:
                    out.append(l)
                    continue
                idx = (slice(None),) * ax + (slot[None],)
                out.append(l.at[idx].set(next(it)))
            return jax.tree_util.tree_unflatten(treedef, out)

        # ``donate`` governs every jitted step that consumes the cache
        # (we always rebind ``self.cache`` to the result).
        dn = (2,) if donate else ()
        self._prefill = jax.jit(_prefill, donate_argnums=dn)
        self._prefill_fresh = jax.jit(_prefill_fresh, donate_argnums=dn)
        self._decode = jax.jit(_decode, donate_argnums=(1,) if donate else ())
        self._reset = jax.jit(_reset_slot, donate_argnums=(0,) if donate else ())
        self._restore = jax.jit(_restore_slot,
                                donate_argnums=(0,) if donate else ())
        self._extract = jax.jit(_extract_row)
        self._insert = jax.jit(_insert_row,
                               donate_argnums=(0,) if donate else ())
        # Length padding is sound only for the dense family: causal
        # masking hides padded positions there, but recurrent state
        # threads through every token, and MoE expert capacity is
        # sequence-global (C scales with padded S and padding tokens
        # compete for expert slots, perturbing real-token logits).  It
        # must also stay below any rolling-window width (padding must not
        # wrap over live keys).
        self._pad_len = cfg.family == "dense"
        self._len_cap = max_len
        if cfg.sliding_window is not None:
            self._len_cap = min(self._len_cap, cfg.sliding_window)
        # Attention caches are self-healing on slot reuse (a cache index is
        # always overwritten at position == index before any query can
        # attend it), so only families that thread recurrent state through
        # prefill need their rows scrubbed between requests — and only on
        # the legacy full-pool path; the bucketed path always prefills
        # rows from a fresh cache.
        self._reset_on_claim = (cfg.family not in ("dense", "moe")
                                and not bucket_prefill)

    # -- slot management ---------------------------------------------------
    def try_claim(self) -> Optional[int]:
        for i, free in enumerate(self.slot_free):
            if free:
                self.slot_free[i] = False
                if self._reset_on_claim:
                    self.reset_slot(i)
                return i
        return None

    def reset_slot(self, slot: int) -> None:
        """Restore one slot's cache rows to their init values.

        Required between requests for recurrent families (rwkv6 / hymba's
        SSM lanes), whose prefill starts from the row's *current* state — a
        reused slot would otherwise leak the previous request's state into
        the next prompt.
        """
        self.cache = self._reset(self.cache, jnp.asarray(slot, jnp.int32))

    def release(self, slot: int) -> None:
        self.slot_free[slot] = True
        self.slot_pos[slot] = 0

    @property
    def active(self) -> int:
        return sum(not f for f in self.slot_free)

    # -- mid-stream migration state -----------------------------------------
    def compatible_with(self, other: "Endpoint") -> bool:
        """Row states are interchangeable between two endpoints iff they
        serve the same model at the same context budget (every cache leaf
        then has identical non-batch dimensions)."""
        return other.cfg is self.cfg and other.max_len == self.max_len

    def extract_rows(self, slots: List[int]) -> List[List[jax.Array]]:
        """Slice the given slots' cache rows out of the pool.

        Returns one row state per slot — a pytree (list) of per-slot
        leaves, each the corresponding cache leaf with the batch axis
        narrowed to size 1.  Leaves that do not depend on the batch size
        are omitted (they are parameters of the pool, not of a request).
        One jitted gather per row keeps a single compiled shape
        regardless of how many rows migrate at once.
        """
        return [self._extract(self.cache, jnp.asarray(s, jnp.int32))
                for s in slots]

    def insert_rows(self, rows: List[List[jax.Array]], slots: List[int],
                    positions: List[int]) -> None:
        """Scatter extracted row states into *claimed* slots of this pool
        and set their decode positions — the receiving half of mid-stream
        migration: decode resumes at ``positions`` with no re-prefill.
        """
        for state, slot, pos in zip(rows, slots, positions):
            self.cache = self._insert(self.cache, state,
                                      jnp.asarray(slot, jnp.int32))
            self.slot_pos[slot] = min(pos, self.max_len)

    def cache_nbytes_per_row(self, length: int) -> float:
        """Bytes of one slot's live cache state at decode position
        ``length`` — what a migration actually ships over a link.

        Leaves with a sequence axis (KV blocks) count only their filled
        positions; recurrent state leaves (no length axis) count in full.
        """
        total = 0.0
        leaves = jax.tree_util.tree_leaves(self.cache)
        for leaf, bax, sax in zip(leaves, self._batch_axes, self._len_axes):
            if bax is None:
                continue
            per_row = leaf.nbytes / leaf.shape[bax]
            if sax is not None:
                per_row *= min(length, self.max_len) / leaf.shape[sax]
            total += per_row
        return total

    # -- steps --------------------------------------------------------------
    def prefill_one(self, slot: int, tokens: np.ndarray) -> int:
        """Run prefill for a single request into its slot's cache rows.

        Returns the first generated token.
        """
        return self.prefill_batch({slot: tokens})[slot]

    def prefill_batch(self, prompts: Dict[int, np.ndarray]) -> Dict[int, int]:
        """Pack multiple claimed slots' prompts into shared prefill calls.

        Prompts are grouped by length; each group runs one jitted prefill
        at a power-of-two *bucketed* batch (next pow2 >= group size, capped
        at the pool) on a fresh cache whose rows are scattered into the
        pool — small waves stop paying full-pool prefill cost, and a
        handful of compiled shapes are reused.  Pure-attention families
        additionally right-pad each group to a power-of-two length (causal
        masking keeps the padded tail inert).  Recurrent families thread
        per-row state token by token, so their rows are never length-padded.
        Returns slot -> first generated token.
        """
        if self.bucket_prefill:
            return self._prefill_batch_bucketed(prompts)
        return self._prefill_batch_padded(prompts)

    def _prefill_batch_bucketed(self,
                                prompts: Dict[int, np.ndarray]
                                ) -> Dict[int, int]:
        by_len: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        for slot, toks in prompts.items():
            by_len.setdefault(len(toks), []).append((slot, toks))
        out: Dict[int, int] = {}
        for L, group in sorted(by_len.items()):
            G = len(group)
            Bp = min(self.slots, max(1, 1 << (G - 1).bit_length()))
            Lb = L
            if self._pad_len:
                cand = 1 << max(L - 1, 0).bit_length()
                if L <= cand <= self._len_cap:
                    Lb = cand
            # Pad batch rows AND the scatter index to the pow2 bucket by
            # duplicating the last real row: jit then only ever sees
            # power-of-two shapes, and the duplicate scatter writes carry
            # identical row values (rows are batch-independent), so the
            # overlapping update is value-deterministic.
            tok = np.zeros((Bp, Lb), np.int32)
            slot_arr = np.zeros(Bp, np.int32)
            for i in range(Bp):
                slot, toks = group[min(i, G - 1)]
                tok[i, :L] = toks
                slot_arr[i] = slot
            lengths = (jnp.full(Bp, L, jnp.int32) if self._pad_len else None)
            logits, self.cache = self._prefill_fresh(
                self.params, jnp.asarray(tok), self.cache,
                jnp.asarray(slot_arr), lengths)
            lg = np.asarray(logits)
            for i, (slot, _) in enumerate(group):
                self.slot_pos[slot] = L
                out[slot] = int(np.argmax(lg[i]))
        return out

    def _prefill_batch_padded(self,
                              prompts: Dict[int, np.ndarray]
                              ) -> Dict[int, int]:
        """Legacy path: every length group pads to batch=slots and runs on
        the pool cache, snapshot-protecting busy rows (kept as the
        before/after baseline for ``benchmarks/serving_bench.py``)."""
        by_len: Dict[int, List[Tuple[int, np.ndarray]]] = {}
        for slot, toks in prompts.items():
            by_len.setdefault(len(toks), []).append((slot, toks))
        out: Dict[int, int] = {}
        # A prefill call writes cache rows for *every* batch row, so it
        # would clobber busy rows outside the current length group: slots
        # mid-decode, rows an earlier group just filled, and — for
        # recurrent families, whose state a zero-token prefill advances —
        # claimed rows a later group has yet to fill.  (Attention rows of
        # later groups need no protection: groups run shortest-first, so
        # their own prefill fully overwrites the polluted positions.)
        external = [s for s in range(self.slots)
                    if not self.slot_free[s] and s not in prompts]
        done: List[int] = []
        for L, group in sorted(by_len.items()):
            group_slots = {slot for slot, _ in group}
            protect = external + done
            if self._reset_on_claim:            # recurrent state families
                protect = [s for s in range(self.slots)
                           if not self.slot_free[s] and s not in group_slots]
            snap = (jax.tree_util.tree_map(jnp.copy, self.cache)
                    if protect else None)
            tok = np.zeros((self.slots, L), np.int32)
            for slot, toks in group:
                tok[slot] = toks
            logits, self.cache = self._prefill(
                self.params, {"tokens": jnp.asarray(tok)}, self.cache)
            for s in protect:
                self.cache = self._restore(self.cache, snap,
                                           jnp.asarray(s, jnp.int32))
            lg = np.asarray(logits)
            for slot, _ in group:
                self.slot_pos[slot] = L
                out[slot] = int(np.argmax(lg[slot]))
                done.append(slot)
        return out

    def decode_all(self, tokens_by_slot: Dict[int, int]) -> Dict[int, int]:
        """One decode step for every active slot. tokens_by_slot maps
        slot -> last emitted token. Returns slot -> next token.

        Slots outside ``tokens_by_slot`` are masked inactive for the step:
        their cache rows (KV positions, recurrent state) are untouched, so
        rows that retired or were cancelled mid-stream stay frozen while
        their neighbors decode."""
        tok = np.zeros(self.slots, np.int32)
        act = np.zeros(self.slots, bool)
        t = np.asarray(self.slot_pos, np.int32)
        for s, v in tokens_by_slot.items():
            tok[s] = v
            act[s] = True
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok), jnp.asarray(t),
                                          jnp.asarray(act))
        out = {}
        lg = np.asarray(logits)
        for s in tokens_by_slot:
            self.slot_pos[s] += 1
            out[s] = int(np.argmax(lg[s]))
        return out


def make_serve_step(cfg: ModelConfig,
                    mode: str) -> Callable:
    """The pure functions the dry-run lowers (no engine state).

    mode="prefill": (params, batch, cache) -> (last_logits, cache)
    mode="decode":  (params, cache, tokens, t) -> (logits, cache)
    """
    if mode == "prefill":
        def serve_step(params, batch, cache):
            return model_zoo.prefill(cfg, params, batch, cache)
        return serve_step
    if mode == "decode":
        def serve_step(params, cache, tokens, t):
            return model_zoo.decode(cfg, params, cache, tokens, t)
        return serve_step
    raise ValueError(mode)
