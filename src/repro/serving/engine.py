"""Serving engine: jitted prefill/decode steps + a continuous-batching
instance pool per tier.

The engine is the *data plane* the paper's control plane routes to. One
:class:`Endpoint` wraps a (config, params) pair with jitted ``prefill`` and
``decode`` steps and a slot-based KV cache pool (continuous batching:
requests claim/release slots independently; one decode step advances every
active slot). Latency per request is what feeds the paper's Eq (1).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo
from repro.models.common import ModelConfig


@dataclasses.dataclass
class Request:
    """One inference request (token ids in, token ids out)."""
    rid: int
    tokens: np.ndarray            # (prompt_len,)
    max_new: int = 8
    arrival_s: float = 0.0
    # filled by the engine:
    output: Optional[np.ndarray] = None
    t_first: float = 0.0
    t_done: float = 0.0


class Endpoint:
    """A deployed model ("Knative Service" analogue) on one tier.

    ``slots`` is the max concurrent sequences (the KV cache pool size);
    requests batch up to ``slots`` per decode step — the TPU-idiomatic
    version of request concurrency.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 max_len: int = 256, donate: bool = True):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = model_zoo.init_cache(cfg, slots, max_len)
        self.slot_pos = np.zeros(slots, np.int32)          # next position
        self.slot_free = [True] * slots

        def _prefill(params, batch, cache):
            return model_zoo.prefill(cfg, params, batch, cache)

        def _decode(params, cache, tokens, t):
            return model_zoo.decode(cfg, params, cache, tokens, t)

        dn = (2,) if donate else ()
        self._prefill = jax.jit(_prefill, donate_argnums=())
        self._decode = jax.jit(_decode, donate_argnums=(1,) if donate else ())

    # -- slot management ---------------------------------------------------
    def try_claim(self) -> Optional[int]:
        for i, free in enumerate(self.slot_free):
            if free:
                self.slot_free[i] = False
                return i
        return None

    def release(self, slot: int) -> None:
        self.slot_free[slot] = True
        self.slot_pos[slot] = 0

    @property
    def active(self) -> int:
        return sum(not f for f in self.slot_free)

    # -- steps --------------------------------------------------------------
    def prefill_one(self, slot: int, tokens: np.ndarray) -> int:
        """Run prefill for a single request into its slot's cache rows.

        For simplicity each prefill runs at batch=slots with only the target
        row meaningful (single-program batching); production would pack
        multiple prompts. Returns the first generated token.
        """
        L = len(tokens)
        tok = np.zeros((self.slots, L), np.int32)
        tok[slot] = tokens
        logits, self.cache = self._prefill(self.params, {"tokens": jnp.asarray(tok)},
                                           self.cache)
        self.slot_pos[slot] = L
        return int(np.argmax(np.asarray(logits)[slot]))

    def decode_all(self, tokens_by_slot: Dict[int, int]) -> Dict[int, int]:
        """One decode step for every active slot. tokens_by_slot maps
        slot -> last emitted token. Returns slot -> next token."""
        tok = np.zeros(self.slots, np.int32)
        t = np.asarray(self.slot_pos, np.int32)
        for s, v in tokens_by_slot.items():
            tok[s] = v
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok), jnp.asarray(t))
        out = {}
        lg = np.asarray(logits)
        for s in tokens_by_slot:
            self.slot_pos[s] += 1
            out[s] = int(np.argmax(lg[s]))
        return out


def make_serve_step(cfg: ModelConfig,
                    mode: str) -> Callable:
    """The pure functions the dry-run lowers (no engine state).

    mode="prefill": (params, batch, cache) -> (last_logits, cache)
    mode="decode":  (params, cache, tokens, t) -> (logits, cache)
    """
    if mode == "prefill":
        def serve_step(params, batch, cache):
            return model_zoo.prefill(cfg, params, batch, cache)
        return serve_step
    if mode == "decode":
        def serve_step(params, cache, tokens, t):
            return model_zoo.decode(cfg, params, cache, tokens, t)
        return serve_step
    raise ValueError(mode)
