"""Exact tensor-parallel serving over ``shard_map``: bit-identical decode.

A cost-modeled tier whose ``mesh_shape`` spans more than one device runs
its :class:`~repro.serving.engine.Endpoint` through this module: params
and KV cache live sharded over the mesh's ``"model"`` axis and every
prefill/decode step runs inside one ``shard_map``.

The layout is the **weight-gather** tensor-parallel scheme, chosen so the
sharded token stream is *bit-identical* to the unsharded engine (pinned
by ``tests/test_sharded_tier.py`` on forced host devices):

* Column-parallel mats shard their *output* dim — ``wq``/``wk``/``wv``
  (heads), ``wi``/``wg`` (ffn), ``lm_head`` (vocab), the embed table
  (model dim) — exactly :func:`repro.launch.sharding.param_shardings`'s
  ``serve_replicated`` layout, so launch-side checkpoints drop in as-is.
  Output-dim slicing never splits a contraction, so each local block of
  the result is the same dot XLA runs unsharded.
* Row-parallel mats (``attn/wo``, ``mlp/wo``) are *stored* sharded on
  their contraction dim but ``all_gather(tiled=True)``-reconstructed
  right before their einsum — a bitwise concatenation, so the einsum
  sees inputs identical to the unsharded program instead of the psum of
  per-shard partial dots (float addition reordering is where psum TP
  loses bit-parity).  The activations feeding them (attention ``o``,
  MLP ``act``) are all-gathered the same way.
* Norms, residual stream, rope, cache writes and the attention kernels
  are replicated or per-head — reused **unmodified** from
  :mod:`repro.models.transformer` (head-count slicing preserves the GQA
  group size because ``validate_tp`` requires both head counts divide
  ``tp``; the kernels read head counts from shapes, not the config).

The *pricing* of a sharded tier deliberately uses the other scheme —
:mod:`repro.launch.tier_cost`'s psum layout (2 all-reduces per layer) —
because that is what a deployment at pod scale would run; this module is
what lets CPU tests pin parity.  See docs/architecture.md.
"""

from __future__ import annotations

import functools
import inspect
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer
from repro.models.common import (ModelConfig, apply_norm, embed_tokens)

try:  # moved across jax versions; serving gates on availability
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:  # pragma: no cover - newer jax exports it at top level
    from jax import shard_map as _shard_map

_CHECK_KW = ("check_vma" if "check_vma"
             in inspect.signature(_shard_map).parameters else "check_rep")

AXIS = "model"                 # TP axis name (mesh is ("data", "model"))

PyTree = Any


def tier_mesh(mesh_shape: Tuple[int, int]) -> Optional[Mesh]:
    """Build the tier's ``("data", "model")`` mesh, or ``None`` when this
    host has too few devices (the endpoint then falls back to the
    unsharded path — numerically identical, just unsharded, so CPU dev
    boxes can run cloud-tier topologies)."""
    need = int(mesh_shape[0]) * int(mesh_shape[1])
    have = len(jax.devices())
    if have < need:
        warnings.warn(
            f"mesh_shape {tuple(mesh_shape)} needs {need} devices, host "
            f"has {have}: deploying unsharded (bit-identical fallback)")
        return None
    from repro.launch import mesh as mesh_mod
    return mesh_mod.make_mesh(tuple(int(a) for a in mesh_shape),
                              ("data", "model"))


def validate_tp(cfg: ModelConfig, tp: int) -> None:
    """Reject configs the exact weight-gather TP scheme cannot serve.

    Exactness needs every sharded output dim to divide ``tp`` (a
    replicate-on-indivisible fallback would silently change the layout
    the parity tests pin), and the reused transformer blocks must be the
    dense family's.  Note this is stricter than the *cost model*, which
    ceils head counts — a pricing choice, documented in
    docs/architecture.md.
    """
    if tp <= 1:
        return
    if cfg.family != "dense":
        raise ValueError(
            f"tensor-parallel serving covers the dense family, "
            f"got {cfg.family!r}")
    if cfg.use_pallas:
        raise ValueError("tensor-parallel serving requires the lax "
                         "attention path (use_pallas=False)")
    if cfg.tie_embeddings:
        raise ValueError("tensor-parallel serving requires an untied "
                         "lm_head (vocab-sharded output head)")
    for field, value in (("num_heads", cfg.num_heads),
                         ("num_kv_heads", cfg.num_kv_heads),
                         ("d_ff", cfg.d_ff),
                         ("vocab_size", cfg.vocab_size),
                         ("d_model", cfg.d_model)):
        if value % tp:
            raise ValueError(
                f"exact TP needs {field} divisible by tp={tp}, "
                f"got {value}")


# --------------------------------------------------------------------------
# Spec builders (PartitionSpec pytrees for shard_map in/out_specs)
# --------------------------------------------------------------------------


def tp_param_specs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    """PartitionSpec per parameter path — the launch ``serve_replicated``
    layout (column mats shard outputs, row mats shard contractions,
    norms replicated), which is exactly what the weight-gather scheme
    stores."""
    from repro.launch import sharding as launch_sharding
    return {path: s.spec for path, s in
            launch_sharding.param_shardings(cfg, mesh,
                                            "serve_replicated").items()}


def _kv_leaf_spec(ndim: int) -> P:
    """k/v leaves shard their kv-heads dim (axis ndim-2 in both the
    stacked (L,B,W,Hkv,Dh) and per-layer (B,W,Hkv,Dh) layouts)."""
    spec = [None] * ndim
    spec[ndim - 2] = AXIS
    return P(*spec)


def tp_cache_specs(cache: PyTree) -> PyTree:
    """PartitionSpec pytree for a KV cache: k/v shard kv-heads over the
    model axis (each shard owns its local heads' history — the dual of
    the head-sharded qkv projections); ``pos`` is replicated.

    This is deliberately NOT :func:`repro.launch.sharding.cache_shardings`
    (whose flash-decode layout shards ``cache_seq``): sharding the
    sequence would split the attention *contraction* and reintroduce the
    psum reordering the weight-gather scheme exists to avoid.
    """
    def one(tree: Dict[str, jax.Array]) -> Dict[str, P]:
        out = {}
        for key, leaf in tree.items():
            if key in ("k", "v"):
                out[key] = _kv_leaf_spec(leaf.ndim)
            else:
                out[key] = P()
        return out

    if isinstance(cache, dict):
        return one(cache)
    return [one(layer) for layer in cache]


def shard_params(params: PyTree, mesh: Mesh,
                 specs: Dict[str, P]) -> PyTree:
    return jax.device_put(
        params, {k: NamedSharding(mesh, specs[k]) for k in params})


def shard_cache(cache: PyTree, mesh: Mesh, specs: PyTree) -> PyTree:
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(cache, shardings)


# --------------------------------------------------------------------------
# The per-layer block (mirrors transformer.dense_layer op-for-op)
# --------------------------------------------------------------------------


def _gather(x: jax.Array, axis_name: str, axis: int) -> jax.Array:
    return jax.lax.all_gather(x, axis_name, axis=axis, tiled=True)


def _tp_attention_block(cfg: ModelConfig, axis: str, p, x, positions,
                        cache, mode: str, layer_idx, prefix: str = "attn/"):
    """transformer.attention_block with local heads + weight-gather wo.

    Everything up to the output projection reuses the unsharded code on
    the local head slice (norm replicated; qkv/rope/cache-write/kernels
    are per-head); then ``o`` and the contraction-sharded ``wo`` are
    all-gathered so the final einsum is the unsharded program verbatim.
    """
    window = transformer._window_for_layer(cfg, layer_idx)
    h = apply_norm(cfg, p, prefix + "norm", x)
    if mode == "decode":
        q, k, v = transformer.qkv_project(cfg, p, h, positions, prefix)
        cache = transformer._cache_write(cache, k, v, positions)
        q1 = q[:, 0]
        from repro.models import attention
        o = attention.decode_attention(cfg, q1, cache["k"], cache["v"],
                                       positions[:, 0], cache["pos"],
                                       window=window)
        o = o[:, None]
    else:
        q, k, v = transformer.qkv_project(cfg, p, h, positions, prefix)
        from repro.models import attention
        o = attention.flash_attention(cfg, q, k, v, positions, positions,
                                      causal=True, window=window)
        if mode == "prefill":
            cache = transformer._cache_write(cache, k, v, positions)
    o = _gather(o, axis, axis=2)                       # (B,S,Hq,Dh) full
    wo = _gather(p[prefix + "wo"], axis, axis=0)       # (Hq,Dh,d) full
    out = jnp.einsum("bshk,hkd->bsd", o, wo.astype(x.dtype))
    return out, cache


def _tp_mlp_block(cfg: ModelConfig, axis: str, p, x,
                  prefix: str = "mlp/") -> jax.Array:
    h = apply_norm(cfg, p, prefix + "norm", x)
    gate = jnp.einsum("bsd,df->bsf", h, p[prefix + "wi"].astype(x.dtype))
    up = None
    if cfg.activation == "swiglu":
        up = jnp.einsum("bsd,df->bsf", h, p[prefix + "wg"].astype(x.dtype))
    act = transformer.activate(cfg, gate, up)
    act = _gather(act, axis, axis=2)                   # (B,S,F) full
    wd = _gather(p[prefix + "wo"], axis, axis=0)       # (F,d) full
    return jnp.einsum("bsf,fd->bsd", act, wd.astype(x.dtype))


def _tp_layer(cfg: ModelConfig, axis: str, p, x, positions, cache,
              mode: str, layer_idx=None, meta=None):
    a, cache = _tp_attention_block(cfg, axis, p, x, positions, cache,
                                   mode, layer_idx)
    x = x + a
    x = x + _tp_mlp_block(cfg, axis, x=x, p=p)
    return x, cache, {}


def _tp_embeds(cfg: ModelConfig, axis: str, params, batch):
    """assemble_embeds with the model-dim-sharded table: local row
    gather, then all-gather the embedding columns (a bitwise concat)."""
    emb = embed_tokens(params["embed"], batch["tokens"], cfg.compute_dtype)
    emb = _gather(emb, axis, axis=2)
    B, S = emb.shape[0], emb.shape[1]
    offset = batch.get("offset")
    positions = jnp.arange(S, dtype=jnp.int32)[None, :] + (
        offset[:, None].astype(jnp.int32) if offset is not None else 0)
    positions = jnp.broadcast_to(positions, (B, S))
    return emb, positions


def _tp_output_head(cfg: ModelConfig, axis: str, params, x) -> jax.Array:
    """output_head with the vocab-sharded lm_head: local logits columns,
    all-gathered (column-slicing a dot's output dim is bitwise-safe)."""
    x = apply_norm(cfg, params, "final_norm", x)
    w = params["lm_head"]          # validate_tp rejects tied embeddings
    if cfg.opt_bf16_dots:
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                            w.astype(jnp.float32))
    return _gather(logits, axis, axis=2)


# --------------------------------------------------------------------------
# shard_map-wrapped model functions (the Endpoint's drop-in backends)
# --------------------------------------------------------------------------


def make_tp_functions(cfg: ModelConfig, mesh: Mesh, cache: PyTree):
    """Build ``(tp_prefill, tp_decode, param_specs, cache_specs)``.

    ``tp_decode(params, cache, tokens, t)`` mirrors
    ``transformer.decode_step``; ``tp_prefill(params, tokens, lengths,
    cache)`` mirrors ``transformer.prefill`` with ``lengths`` always
    materialized (``take_along_axis`` at ``lengths-1 == S-1`` is bitwise
    equal to the ``x[:, -1:]`` branch).  Prefill runs through shard_map
    too — compiling it under GSPMD instead would psum the row-parallel
    projections and break bit-parity.
    """
    tp = mesh.shape[AXIS]
    validate_tp(cfg, tp)
    pspecs = tp_param_specs(cfg, mesh)
    cspecs = tp_cache_specs(cache)
    rep = P()

    def layer_fn(cfg_, p, x, positions, c, mode, layer_idx, meta=None):
        return _tp_layer(cfg_, AXIS, p, x, positions, c, mode,
                         layer_idx, meta=meta)

    def _decode_local(params, cache, tokens, t):
        batch = {"tokens": tokens[:, None], "offset": t}
        emb, positions = _tp_embeds(cfg, AXIS, params, batch)
        x, cache, _ = transformer.forward(cfg, params, emb, positions,
                                          cache, "decode", layer_fn)
        logits = _tp_output_head(cfg, AXIS, params, x)
        return logits[:, 0], cache

    def _prefill_local(params, tokens, lengths, cache):
        emb, positions = _tp_embeds(cfg, AXIS, params, {"tokens": tokens})
        x, cache, _ = transformer.forward(cfg, params, emb, positions,
                                          cache, "prefill", layer_fn)
        idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0,
                       x.shape[1] - 1)
        xl = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = _tp_output_head(cfg, AXIS, params, xl)
        return logits[:, 0], cache

    smap = functools.partial(_shard_map, mesh=mesh, **{_CHECK_KW: False})
    tp_decode = smap(_decode_local, in_specs=(pspecs, cspecs, rep, rep),
                     out_specs=(rep, cspecs))
    tp_prefill = smap(_prefill_local, in_specs=(pspecs, rep, rep, cspecs),
                      out_specs=(rep, cspecs))
    return tp_prefill, tp_decode, pspecs, cspecs
