"""The live N-tier continuum runtime.

This is the live (non-simulated) integration of every paper component:

    EdgeCloudContinuum (over a Topology chain, ingress at tier 0)
      ├── tier 0..N-1:  Gateway (bounded backlog queue) + Endpoint pool
      │                 (slots/model) + MetricsRegistry + per-function
      │                 Autoscaler (Knative-KPA concurrency)
      ├── ReplicationController  (deepest-tier spec -> shallower tiers,
      │                           selective merge)
      ├── ControlLoop + Policy   (Eqs (1)-(4) / static / net-aware / hedged
      │                           — one controller boundary per adjacent
      │                           tier pair, the same loop the simulator
      │                           drives)
      └── Router                 (vectorized categorical assignment of the
                                  queued batch over the tier distribution)

Requests enter at the ingress gateway (``submit``); each scheduler tick
runs one scrape-and-update cycle through the shared
:class:`repro.core.policy.ControlLoop`, assigns the ingress batch over
the tiers by the composed R_t distribution, and serves **each tier's own
gateway** with a *continuous-batching decode loop*: every scheduler step
runs one shared ``decode_all`` step across all slot-resident requests,
retires finished rows immediately, and admits queued requests into the
freed slots the same step (packed bucketed prefill) — so a short request
never waits out a long co-resident one, and the losing twin of a hedge
pair is **cancelled** (slot evicted, no latency recorded) the step its
sibling completes.  ``scheduler="wave"`` keeps the legacy
run-to-completion wave drain as the before/after baseline, and
``max_steps_per_tick`` lets long requests stay slot-resident across
ticks.  Moving a request down the chain — routing past a
boundary or (with ``topology.waterfall``) spilling a stalled tier's load
— crosses the corresponding :class:`~repro.core.topology.LinkSpec`,
charging its RTT + payload serialization to the request's latency clock
and counting the boundary crossing.

Policies carrying a ``migrate_threshold`` (``"auto+migrate"``) extend
offloading to **slot-resident** work: when a boundary's R_t reaches the
threshold, the tier cancels its most slot-hungry in-flight rows (longest
remaining decode first), extracts their KV/state rows from the cache
pool, and ships them over the link — ``nbytes`` = live cache bytes at
the row's position plus the token tail — and the destination re-admits
them into free slots *without re-prefill*, resuming decode at the same
position (token-stream bit-identity is pinned by tests).  A landing that
finds the destination full ABORTS: the row resumes at its source, never
lost; transfers still in flight when a step-capped tick ends land on a
later tick.

The controller sees the continuum the way the paper's Knative deployment
does (queue-proxy depth/age gauges per component): boundary b is fed tier
b's latency windows, tier b's **own gateway backlog ages**, and the
demand that actually **crossed** into tier b this interval (the
per-boundary ``arrivals`` form of ``ControlLoop.step_tiers``), so an
intermediate boundary's R_t rises when its own backlog ages — before its
completions drain — and ``auto+net`` caps each boundary by the link it
actually crosses.  Requests an admission budget could not serve stay queued in
their tier's gateway (the ingress gateway's backlog re-enters routing;
deeper backlogs belong to their tier), which is exactly the simulator's
per-tier queue state.

The historical two-tier constructor (``edge=..., cloud=...``) builds a
2-tier :class:`~repro.core.topology.Topology` via :meth:`Topology.pair`;
``edge``/``cloud`` remain as attribute aliases for the ingress/deepest
tiers.  Everything model-related goes through ``serving.engine.Endpoint``;
tier capacities are expressed in concurrent slots, so the same runtime
works with real TPU meshes (slots = per-pod batch) or the CPU tests
(slots=4).
"""

from __future__ import annotations

import dataclasses
import math
import time
import warnings
import zlib
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import offload
from repro.core.autoscaler import Autoscaler
from repro.core.metrics import MetricsRegistry
from repro.core.policy import AutoOffload, ControlLoop, Policy, PolicySpec
from repro.core.replication import (AutoscalingPolicy, FunctionSpec,
                                    ReplicationController)
from repro.core.topology import TierSpec, Topology
from repro.models.common import ModelConfig
from repro.serving.engine import Endpoint, Request
from repro.workloads.faults import (LINK_KINDS, FaultEvent, FaultSchedule,
                                    LinkState)
from repro.workloads.trace import Trace


@dataclasses.dataclass
class TierConfig:
    """Legacy two-tier tier shape (sugar for a named
    :class:`~repro.core.topology.TierSpec` via ``Topology.pair``)."""
    slots: int = 4
    max_len: int = 256
    # synthetic per-request overhead (edge->cloud WAN RTT), seconds
    extra_latency_s: float = 0.0
    # default KPA bounds for functions deployed without an explicit policy
    autoscaling: Optional[AutoscalingPolicy] = None
    stable_window_s: float = 60.0
    panic_window_s: float = 6.0


@dataclasses.dataclass
class _Queued:
    """One gateway queue entry (+ hedge bookkeeping)."""
    fn: str
    req: Request
    t_submit: float
    tick_no: int = 0
    hedge: bool = False
    pair: Optional["_HedgePair"] = None


@dataclasses.dataclass
class _InFlight:
    """One slot-resident request inside a tier's continuous decode loop."""
    item: _Queued
    slot: int
    toks: List[int]               # generated tokens so far (first from prefill)
    need: int                     # total tokens to generate
    done_at: float = 0.0


@dataclasses.dataclass
class _Transit:
    """One migrated request's extracted state, in flight over a link.

    Created by :meth:`EdgeCloudContinuum._fire_migrations` (the source
    tier already cancelled the row and freed its slot); resolved by
    :meth:`EdgeCloudContinuum._land_migrations` once the wall clock
    passes ``t_land`` — possibly ticks later, when the link is slow.
    """
    item: _Queued
    fn: str
    rows: List                     # Endpoint.extract_rows state (one row)
    pos: int                       # decode position at extraction
    toks: List[int]                # tokens generated so far
    need: int                      # total tokens to generate
    src: int                       # source tier index
    dst: int                       # destination tier index
    t_land: float                  # wall-clock landing time
    nbytes: float                  # cache bytes + token tail shipped


@dataclasses.dataclass
class _HedgePair:
    """Links a primary request to its hedge twin so only the winning
    arm's latency feeds the controller.

    Under the continuous scheduler the race settles the moment one arm
    finishes: ``winner`` flips from ``None`` to ``"primary"``/``"twin"``
    and :meth:`EdgeCloudContinuum._evict_loser` cancels the slot-resident
    sibling the same scheduler step.  The legacy wave scheduler still uses
    :meth:`note` + latency comparison (both arms run to completion there).
    """
    fn: str
    # continuous-scheduler resolution state
    winner: Optional[str] = None            # None | "primary" | "twin"
    winner_req: Optional[Request] = None
    primary_ref: Optional[Tuple[int, _InFlight]] = None   # (tier_idx, rec)
    twin_ref: Optional[Tuple[int, _InFlight]] = None
    # wave-scheduler bookkeeping (legacy run-to-completion path)
    primary_lat: Optional[float] = None
    primary_tier: Optional["Tier"] = None
    twin_lat: Optional[float] = None
    twin_tier: Optional["Tier"] = None
    twin_req: Optional[Request] = None

    def note(self, item: "_Queued", tier: "Tier", lat: float) -> None:
        if item.hedge:
            self.twin_lat, self.twin_tier = lat, tier
            self.twin_req = item.req
        else:
            self.primary_lat, self.primary_tier = lat, tier

    def set_ref(self, hedge: bool, tier_idx: int, rec: _InFlight) -> None:
        """Remember where an arm is slot-resident so the loser can be
        evicted the step its sibling completes."""
        if hedge:
            self.twin_ref = (tier_idx, rec)
        else:
            self.primary_ref = (tier_idx, rec)


class Gateway:
    """One tier's bounded backlog queue (the Knative queue-proxy stand-in).

    Requests wait here between scheduler ticks; the controller boundary
    of the owning tier reads the backlog's ages each scrape.  ``capacity``
    bounds the *resting* backlog (``None`` = unbounded): client submits
    and requeues past it are rejected (the live 503), while in-tick
    placement uses ``force=True`` because a routed request may still be
    served this very tick.
    """

    def __init__(self, capacity: Optional[int] = None):
        self.capacity = capacity
        self.items: Deque[_Queued] = deque()
        self.rejected = 0

    def push(self, item: _Queued, force: bool = False) -> bool:
        if (not force and self.capacity is not None
                and len(self.items) >= self.capacity):
            self.rejected += 1
            return False
        self.items.append(item)
        return True

    def pop_all(self) -> List[_Queued]:
        items = list(self.items)
        self.items.clear()
        return items

    def backlog_ages(self, now: float, tick_no: int,
                     fn_ids: Dict[str, int],
                     num_functions: int) -> List[List[float]]:
        """Per-function ages of true *backlog*: entries that survived a
        previous scheduler round.  Fresh arrivals have waited ~0 s —
        mixing those into X_l(t) would drag p50 toward zero and fire
        Eq (1) spuriously."""
        ages: List[List[float]] = [[] for _ in range(num_functions)]
        for item in self.items:
            if item.tick_no < tick_no:
                ages[fn_ids[item.fn]].append(now - item.t_submit)
        return ages

    def __len__(self) -> int:
        return len(self.items)


class Tier:
    """One serving location: endpoints by function name + metrics +
    per-function KPA autoscalers.

    ``cfg`` may be a legacy :class:`TierConfig` or an N-tier
    :class:`~repro.core.topology.TierSpec` — both carry the same serving
    fields."""

    def __init__(self, name: str, cfg):
        self.name = name
        self.cfg = cfg
        self.endpoints: Dict[str, Endpoint] = {}
        self.autoscalers: Dict[str, Autoscaler] = {}
        self.metrics = MetricsRegistry([])
        # continuous-batching decode loop state: fn -> slot -> _InFlight
        self.inflight: Dict[str, Dict[int, _InFlight]] = {}

    def deploy(self, fn_name: str, model_cfg: ModelConfig, params,
               autoscaling: Optional[AutoscalingPolicy] = None) -> None:
        """Stand up this tier's endpoint pool for one function.

        A cost-modeled :class:`TierSpec` must arrive *resolved*
        (``Topology.costed``/``resolve_costs``): its ``slots`` are then
        already HBM-clamped by the same ``hlo_cost`` pricing that set
        the simulator's service rate — the sim<->live shared-cost-model
        contract.  ``spec.model`` names the architecture that *priced*
        the tier; ``model_cfg`` is what this pool actually serves (tests
        deploy smoke-sized configs against production-priced specs).  A
        ``mesh_shape`` deploys the pool shard_map tensor-parallel when
        the host has enough devices, else falls back unsharded with a
        warning (bit-identical either way).
        """
        if getattr(self.cfg, "model", None) is not None and \
                not getattr(self.cfg, "resolved", True):
            raise ValueError(
                f"tier {self.name!r} declares a cost model "
                f"({self.cfg.model}) but is unresolved; build the chain "
                f"via Topology.costed(...) or call .resolve_costs() "
                f"before deploying")
        mesh = None
        mesh_shape = getattr(self.cfg, "mesh_shape", None)
        if mesh_shape is not None and (
                int(mesh_shape[0]) * int(mesh_shape[1])) > 1:
            from repro.serving import sharded
            mesh = sharded.tier_mesh(mesh_shape)
        page_size = getattr(self.cfg, "page_size", None)
        self.endpoints[fn_name] = Endpoint(
            model_cfg, params, slots=self.cfg.slots, max_len=self.cfg.max_len,
            paged=page_size is not None,
            page_size=page_size if page_size is not None else 16,
            total_pages=getattr(self.cfg, "pool_pages", None),
            mesh=mesh)
        self.inflight.setdefault(fn_name, {})
        self.metrics.register(fn_name)
        # A TierSpec that declares its own KPA bounds governs its whole
        # pool (e.g. an intermediate tier pinned to zero with max_scale=0).
        # Legacy TierConfig keeps its documented fallback semantics: the
        # function's spec wins, the tier's bounds apply only when the
        # function has none.
        if isinstance(self.cfg, TierSpec) and self.cfg.autoscaling is not None:
            policy = self.cfg.autoscaling
        else:
            policy = autoscaling or self.cfg.autoscaling or AutoscalingPolicy()
        self.autoscalers[fn_name] = Autoscaler(
            policy,
            stable_window_s=self.cfg.stable_window_s,
            panic_window_s=self.cfg.panic_window_s)

    # -- capacity ----------------------------------------------------------
    def free_slots(self, fn_name: str) -> int:
        ep = self.endpoints[fn_name]
        return ep.slots - ep.active

    def capacity(self, fn_name: str) -> int:
        """Admitted concurrency right now: ceil(replicas x target
        concurrency), bounded by the KV-cache pool. 0 when scaled to zero.
        A fractional target under-one admits *less* than one request per
        replica (e.g. 2 replicas x 0.5 admit 1), not one per replica.
        On a cost-modeled tier the pool bound (``Endpoint.slots``) is the
        HBM-derived slot count from ``launch/tier_cost.py`` — the same
        number the simulator's ``_SimTier`` pools use, so live KPA
        admission and simulated capacity share one cost model."""
        asc = self.autoscalers[fn_name]
        want = math.ceil(asc.replicas * asc.policy.target_concurrency)
        return min(self.endpoints[fn_name].slots, want)

    def replicas(self, fn_name: str) -> int:
        return self.autoscalers[fn_name].replicas

    def inflight_count(self, fn_name: str) -> int:
        return len(self.inflight.get(fn_name, ()))

    def admission_budget(self, fn_name: str, items: List["_Queued"],
                         cap: Optional[int] = None) -> int:
        """How many of ``items`` (in order) this tier can admit right
        now.  Dense pools: free slots (bounded by ``cap``, the caller's
        KPA-admitted concurrency).  Paged pools additionally walk the
        queue head charging each request the pages it must be able to
        reserve (``Endpoint.page_need`` — sharing-blind, so never an
        overclaim): admission is gated on *memory actually reserved*,
        not slot count alone."""
        ep = self.endpoints[fn_name]
        budget = self.free_slots(fn_name)
        if cap is not None:
            budget = min(budget, cap)
        budget = max(0, min(budget, len(items)))
        if not ep.paged or budget == 0:
            return budget
        free = ep.admissible_pages
        n = 0
        for item in items[:budget]:
            need = ep.page_need(len(item.req.tokens),
                                max(item.req.max_new, 1))
            if need > free:
                break
            free -= need
            n += 1
        return n

    # -- continuous-batching decode loop ------------------------------------
    # One scheduler step is: decode every in-flight slot once (``step``),
    # retire finished rows immediately, then admit queued requests into the
    # freed slots (``admit``) — so a short request never waits for a long
    # co-resident one, and a cancelled hedge loser's slot is reusable the
    # same step it is evicted.

    def admit(self, fn_name: str, items: List[_Queued]
              ) -> Tuple[List[_InFlight], List[_InFlight]]:
        """Claim slots for ``items`` and run one packed bucketed prefill.

        Returns ``(in_flight, finished)``: requests needing only their
        prefill token retire immediately (their slot frees right away);
        the rest join the tier's in-flight set for the shared
        ``decode_all`` stream.  The caller sizes admissions within
        ``free_slots`` — over-admission raises, as in ``serve_batch``.
        """
        ep = self.endpoints[fn_name]
        claimed: List[Tuple[_Queued, int]] = []
        for item in items:
            slot = ep.try_claim(tokens=item.req.tokens,
                                max_new=max(item.req.max_new, 1))
            if slot is None:
                for _, s in claimed:
                    ep.release(s)
                raise RuntimeError(
                    f"{self.name}/{fn_name}: admission of {len(items)} "
                    f"exceeds free slots/pages — scheduler admitted past "
                    f"capacity")
            claimed.append((item, slot))
        try:
            firsts = ep.prefill_batch(
                {slot: item.req.tokens for item, slot in claimed})
        # lint: ignore[swallowed-exception] -- cleanup-and-reraise: slots
        # must be released on ANY prefill failure or they leak forever
        except Exception:
            for _, s in claimed:
                ep.release(s)
            raise
        now = time.perf_counter()
        in_flight: List[_InFlight] = []
        finished: List[_InFlight] = []
        for item, slot in claimed:
            item.req.t_first = now
            rec = _InFlight(item, slot, [firsts[slot]],
                            max(item.req.max_new, 1))
            if rec.need == 1:
                rec.done_at = now
                ep.release(slot)
                finished.append(rec)
            else:
                self.inflight[fn_name][slot] = rec
                in_flight.append(rec)
        return in_flight, finished

    def step(self, fn_name: str) -> List[_InFlight]:
        """One shared ``decode_all`` step over every in-flight slot of
        ``fn_name``; finished rows are retired (slot released) immediately
        and returned."""
        fl = self.inflight.get(fn_name)
        if not fl:
            return []
        ep = self.endpoints[fn_name]
        nxt = ep.decode_all({slot: rec.toks[-1] for slot, rec in fl.items()})
        now = time.perf_counter()
        finished: List[_InFlight] = []
        for slot, tok in nxt.items():
            rec = fl[slot]
            rec.toks.append(tok)
            if len(rec.toks) >= rec.need:
                rec.done_at = now
                ep.release(slot)
                del fl[slot]
                finished.append(rec)
        return finished

    def cancel(self, fn_name: str, slot: int) -> _InFlight:
        """Evict one in-flight request mid-decode (a hedge loser): the
        slot frees immediately and no latency sample is recorded."""
        rec = self.inflight[fn_name].pop(slot)
        self.endpoints[fn_name].release(slot)
        return rec

    def finish(self, fn_name: str, rec: _InFlight) -> float:
        """Fill the request's output from a retired in-flight record and
        return its end-to-end latency (metrics recording is the caller's
        call — hedge losers never record)."""
        req = rec.item.req
        req.output = np.asarray(rec.toks, np.int32)
        req.t_done = rec.done_at
        req.latency_s = (rec.done_at - rec.item.t_submit
                         + self.cfg.extra_latency_s)
        return req.latency_s

    # -- serving -----------------------------------------------------------
    def serve_batch(self, fn_name: str,
                    items: List[Tuple[Request, float]],
                    record: Optional[List[bool]] = None
                    ) -> List[Tuple[np.ndarray, float]]:
        """Serve a wave of requests together on one endpoint.

        All prompts share packed prefill calls and one ``decode_all``
        stream; each request's latency is measured from its submit
        timestamp to the decode step that finished it. ``record`` masks
        which latencies feed this tier's metrics (hedged arms defer to the
        pair winner). The caller is responsible for sizing waves within
        ``free_slots`` — admission past the pool raises instead of
        silently corrupting a live slot's KV cache (the old ``slot = 0``
        fallback).
        """
        ep = self.endpoints[fn_name]
        claimed: List[Tuple[Request, float, int]] = []
        for req, t_submit in items:
            slot = ep.try_claim(tokens=req.tokens,
                                max_new=max(req.max_new, 1))
            if slot is None:
                for _, _, s in claimed:
                    ep.release(s)
                raise RuntimeError(
                    f"{self.name}/{fn_name}: wave of {len(items)} exceeds "
                    f"free slots/pages — scheduler admitted past capacity")
            claimed.append((req, t_submit, slot))

        try:
            firsts = ep.prefill_batch(
                {slot: req.tokens for req, _, slot in claimed})
            now = time.perf_counter()
            outs: Dict[int, List[int]] = {}
            need: Dict[int, int] = {}
            done_at: Dict[int, float] = {}
            active: Dict[int, int] = {}
            for req, _, slot in claimed:
                outs[slot] = [firsts[slot]]
                need[slot] = max(req.max_new, 1)
                done_at[slot] = now
                req.t_first = now
                if need[slot] > 1:
                    active[slot] = firsts[slot]
            while active:
                nxt = ep.decode_all(active)
                now = time.perf_counter()
                for s, tok in nxt.items():
                    outs[s].append(tok)
                    if len(outs[s]) >= need[s]:
                        del active[s]
                        done_at[s] = now
                    else:
                        active[s] = tok
        # lint: ignore[swallowed-exception] -- cleanup-and-reraise: decode
        # slots must be released on ANY mid-stream failure or they leak
        except Exception:
            for _, _, s in claimed:
                ep.release(s)
            raise

        results: List[Tuple[np.ndarray, float]] = []
        for i, (req, t_submit, slot) in enumerate(claimed):
            lat = done_at[slot] - t_submit + self.cfg.extra_latency_s
            if record is None or record[i]:
                self.metrics.record_latency(fn_name, lat)
            req.output = np.asarray(outs[slot], np.int32)
            req.t_done = done_at[slot]
            req.latency_s = lat
            ep.release(slot)
            results.append((req.output, lat))
        return results

    def serve_one(self, fn_name: str, req: Request,
                  now_s: float = 0.0) -> Tuple[np.ndarray, float]:
        """Serial single-request path (the pre-batching baseline)."""
        del now_s
        [(out, lat)] = self.serve_batch(fn_name, [(req, time.perf_counter())])
        return out, lat


class EdgeCloudContinuum:
    """The full platform: replication + policy-driven offloading across an
    N-tier topology, with per-tier gateways and a continuous-batching
    scheduler (``scheduler="wave"`` keeps the legacy wave drain)."""

    def __init__(self, edge=None, cloud=None,
                 policy: PolicySpec = "auto",
                 offload_cfg: Optional[offload.OffloadConfig] = None,
                 window: int = 64, seed: int = 0,
                 control_interval_s: float = 1.0,
                 max_waves_per_tick: Optional[int] = None,
                 topology: Optional[Topology] = None,
                 reject_latency_s: float = 0.005,
                 scheduler: str = "continuous",
                 max_steps_per_tick: Optional[int] = None,
                 req_bytes: Optional[float] = None,
                 trace: Optional[Trace] = None,
                 faults: Optional[FaultSchedule] = None,
                 trace_vocab: int = 128,
                 trace_prompts: str = "random",
                 eq1: str = "window",
                 sketch=None):
        if trace_prompts not in ("random", "per_fn"):
            raise ValueError(
                f"trace_prompts must be 'random' or 'per_fn', "
                f"got {trace_prompts!r}")
        if scheduler not in ("continuous", "wave"):
            raise ValueError(
                f"scheduler must be 'continuous' or 'wave', got {scheduler!r}")
        if topology is None:
            if edge is None or cloud is None:
                raise ValueError(
                    "pass either topology=... or the 2-tier edge=/cloud= pair")
            topology = Topology.pair(edge, cloud)
        self.topology = topology
        self.tiers: List[Tier] = [Tier(spec.name, spec)
                                  for spec in topology.tiers]
        self.gateways: List[Gateway] = [
            Gateway(None if spec.queue_depth_per_slot is None
                    else spec.slots * spec.queue_depth_per_slot)
            for spec in topology.tiers]
        self.offload_cfg = offload_cfg or offload.OffloadConfig()
        self._policy_spec: PolicySpec = policy
        # Average request payload hint for net-aware caps.  The simulator
        # derives this from its workload profile; the live runtime takes
        # it as a constructor hint so an auto+net deployment can divide
        # its links by the real payload (and sim-live R_t parity holds).
        self.req_bytes = req_bytes
        self.policy = Policy.parse(policy, offload_cfg=self.offload_cfg,
                                   req_bytes=req_bytes)
        if scheduler == "wave" and self.policy.migrate_threshold is not None:
            # the wave scheduler runs every admitted request to
            # completion — there is no slot-resident state to migrate
            warnings.warn(
                "mid-stream migration (migrate_threshold="
                f"{self.policy.migrate_threshold}) requires the "
                "continuous scheduler; scheduler='wave' will never "
                "migrate", stacklevel=2)
        self.window = window
        self.control_interval_s = control_interval_s
        # Eq-(1) front end for the control loop: "window" (exact sorted
        # percentiles, the golden-pinned default) or "sketch" (streaming
        # histogram quantiles drained from the tier registries each
        # scrape — the sub-millisecond 10k-function path).
        self.eq1 = eq1
        self.sketch = sketch
        # Fast rejections are part of the latency distribution Eq (1)
        # scrapes (queue-proxy 503 semantics, same as the simulator).
        self.reject_latency_s = reject_latency_s
        # One reconciler per shallower tier: each edge cluster mirrors the
        # cloud specs independently, so a crashed tier's view can be wiped
        # and rebuilt (scale-from-zero re-registration) without touching
        # its siblings.  ``replicator`` keeps the historical single-edge
        # attribute as a view of the first one.
        self.replicators: List[ReplicationController] = [
            ReplicationController()
            for _ in range(max(len(self.tiers) - 1, 1))]
        self.cloud_specs: Dict[str, FunctionSpec] = {}
        self._artifacts: Dict[str, Tuple[ModelConfig, object]] = {}
        self.fn_names: List[str] = []
        self._fn_ids: Dict[str, int] = {}
        self.control: Optional[ControlLoop] = None
        self.key = jax.random.PRNGKey(seed)
        # Demand per boundary since the last scrape: boundary b counts the
        # requests that *reached* tier b (submit, routing, or spill) —
        # what its net-aware cap divides the link capacity by.
        self._num_boundaries = max(len(self.tiers) - 1, 1)
        # One (F,) count vector per boundary, indexed by function id —
        # the controller scrape hands these straight to the batched
        # ControlLoop without any per-function Python.
        self._crossings: List[np.ndarray] = [
            np.zeros(0, np.int64) for _ in range(self._num_boundaries)]
        # Platform-level counters (hedging outcomes etc.).
        self.metrics = MetricsRegistry([])
        # Mid-stream migrations currently in flight over a link, and the
        # cumulative per-link egress bytes (every crossing: routing,
        # spill, hedge twins, migrated cache state) — the live
        # counterpart of the simulator's net_links_MBps series.
        self.migrations: List[_Transit] = []
        self.link_bytes: List[float] = [0.0] * len(topology.links)
        self._link_bytes_seen: List[float] = [0.0] * len(topology.links)
        # None = drain every gateway every tick; an int caps the admission
        # rounds per tick, so overload leaves per-tier *backlogs* whose
        # in-flight ages the next scrape mixes into Eq (1) (the
        # simulator's onset signal, now per boundary).
        self.max_waves_per_tick = max_waves_per_tick
        # "continuous" (default): persistent in-flight slots, one shared
        # decode step per scheduler step, retire-and-admit mid-stream.
        # "wave": the legacy run-to-completion wave drain (kept as the
        # before/after baseline for benchmarks/serving_bench.py).
        self.scheduler = scheduler
        # Continuous scheduler only: cap the decode steps one tick may run,
        # letting long requests stay slot-resident ACROSS ticks (new
        # arrivals are admitted into freed slots next tick, mid-request).
        # None = run each tick until all admitted work retires.
        self.max_steps_per_tick = max_steps_per_tick
        self.log: List[Dict] = []
        self._clock = 0.0          # logical control-plane time (scrapes)
        self._tick_no = 0
        self._rejected_seen = 0    # for per-tick deltas in tick() records
        # Fault overlay (repro.workloads.faults): links are crossed
        # through their mutable LinkState (identity multipliers while
        # healthy) and crashed tiers forward traffic but cannot serve.
        # The schedule is applied against the logical clock at the top of
        # each tick; apply_fault() is also public so tests can drive the
        # live runtime and the simulator through identical fault events.
        self.link_state: List[LinkState] = [LinkState(l)
                                            for l in topology.links]
        self.tier_up: List[bool] = [True] * len(self.tiers)
        self.faults = faults
        if faults is not None:
            faults.validate(len(self.tiers))
            faults.reset()
        # Trace-driven arrivals (repro.workloads.trace): rows are
        # submitted at the top of the tick covering their arrival time,
        # with prompt tokens drawn from a dedicated deterministic RNG.
        self.trace = trace
        self.trace_vocab = trace_vocab
        # "random": every arrival draws fresh prompt tokens (the
        # historical behavior).  "per_fn": a function's prompt is a
        # deterministic function of (fn, prompt_len) — invocations of the
        # same function share their prompt, modeling the shared
        # system/function prompt that makes prefix caching pay.
        self.trace_prompts = trace_prompts
        self.trace_requests: List[Request] = []
        self._trace_pos = 0
        self._trace_rng = np.random.default_rng(seed)

    # Ingress / deepest tier aliases (the historical two-tier attributes).
    @property
    def edge(self) -> Tier:
        return self.tiers[0]

    @property
    def replicator(self) -> ReplicationController:
        """The ingress tier's reconciler (historical single-edge view)."""
        return self.replicators[0]

    @property
    def cloud(self) -> Tier:
        return self.tiers[-1]

    @property
    def queue(self) -> Deque[_Queued]:
        """The ingress gateway's queue (historical attribute)."""
        return self.gateways[0].items

    @property
    def queued(self) -> int:
        """Total backlog across every tier's gateway."""
        return sum(len(g) for g in self.gateways)

    @property
    def in_flight(self) -> int:
        """Slot-resident requests across every tier plus migrated state
        still in flight over a link (continuous scheduler; nonzero
        between ticks only under ``max_steps_per_tick`` or while a
        cross-tick migration is landing)."""
        return (sum(t.inflight_count(fn)
                    for t in self.tiers for fn in t.endpoints)
                + len(self.migrations))

    @property
    def migrations_open(self) -> int:
        """Mid-stream migrations fired but not yet landed/aborted."""
        return len(self.migrations)

    @property
    def hedges_open(self) -> int:
        """Hedge pairs still racing (fired but neither won nor cancelled)."""
        c = self.metrics.counter
        return int(c("hedges_fired") - c("hedges_won")
                   - c("hedges_cancelled"))

    # -- deployment (paper §3.3.1) ------------------------------------------
    def deploy(self, spec: FunctionSpec, model_cfg: ModelConfig, params) -> None:
        """Deploy to the deepest tier; replication mirrors the spec to
        every shallower tier of the chain."""
        self.cloud.deploy(spec.name, model_cfg, params, spec.autoscaling)
        self.cloud_specs[spec.name] = spec
        self._artifacts[spec.name] = (model_cfg, params)
        for i, tier in enumerate(self.tiers[:-1]):
            changed = self.replicators[i].reconcile(self.cloud_specs)
            if changed.get(spec.name, True):
                tier.deploy(spec.name, model_cfg, params, spec.autoscaling)
        if spec.name not in self.fn_names:
            self._fn_ids[spec.name] = len(self.fn_names)
            self.fn_names.append(spec.name)
            self._crossings = [np.concatenate([c, np.zeros(1, np.int64)])
                               for c in self._crossings]
            # Each boundary parses the policy against ITS link's capacity,
            # so auto+net caps offload by the link actually being crossed
            # (mirrors the simulator's per-boundary policies).
            links = self.topology.links
            boundary_policies = [
                Policy.parse(self._policy_spec, offload_cfg=self.offload_cfg,
                             link_bytes_per_s=(
                                 links[min(b, len(links) - 1)].bandwidth_Bps
                                 if links else None),
                             req_bytes=self.req_bytes)
                for b in range(self._num_boundaries)]
            self.control = ControlLoop(
                self.policy, len(self.fn_names), window=self.window,
                control_interval_s=self.control_interval_s,
                num_tiers=len(self.tiers),
                boundary_policies=boundary_policies,
                eq1=self.eq1, sketch=self.sketch)

    # -- request path (paper §3.3.2) ------------------------------------------
    def submit(self, fn_name: str, req: Request) -> bool:
        """Queue a request at the ingress gateway.  Returns False when the
        bounded backlog is full (the live 503 — a fast rejection whose
        latency feeds Eq (1)'s bimodality, as in the simulator)."""
        req.arrival_s = time.perf_counter()
        item = _Queued(fn_name, req, req.arrival_s, tick_no=self._tick_no)
        # Every arrival is ingress demand, admitted or not — the simulator
        # counts a 503'd arrival into arrivals_in_interval the same way.
        self._count_crossing(0, fn_name)
        if not self.gateways[0].push(item):
            req.failed = True
            self._reject(0, fn_name)
            return False
        return True

    def _count_crossing(self, b: int, fn: str) -> None:
        if b < self._num_boundaries:
            i = self._fn_ids.get(fn)
            if i is not None:
                self._crossings[b][i] += 1

    def _reject(self, ti: int, fn: str) -> None:
        self.metrics.inc("rejected")
        if ti < len(self.tiers) - 1 or len(self.tiers) == 1:
            self.tiers[ti].metrics.record_latency(fn, self.reject_latency_s)

    def _cross_link(self, item: _Queued, l: int) -> None:
        """Move one queued request over link l (tier l -> tier l+1):
        charge RTT + payload serialization to its latency clock (by
        backdating the submit stamp, so both the measured latency and the
        backlog age include time in flight, as in the simulator) and count
        the boundary crossing for per-boundary demand."""
        if l < len(self.topology.links):
            item.t_submit -= self.link_state[l].latency_s(
                item.req.tokens.nbytes)
            self.link_bytes[l] += item.req.tokens.nbytes
        if not item.hedge:
            self._count_crossing(l + 1, item.fn)

    # -- fault injection (repro.workloads.faults) -----------------------------

    def _route_target(self, j: int) -> Optional[int]:
        """Resolve an assigned tier against the fault state: crashed
        tiers forward but cannot serve, a partitioned link cuts off
        everything past it.  Prefer the shallowest serviceable tier at or
        past the assignment, else the deepest one before it; None when
        nothing can serve (the request 503s)."""
        if self.faults is None and all(self.tier_up):
            return j
        reach = 0
        for l in range(len(self.tiers) - 1):
            if not self.link_state[l].up:
                break
            reach = l + 1
        up = [i for i in range(reach + 1) if self.tier_up[i]]
        if not up:
            return None
        for i in up:
            if i >= j:
                return i
        return up[-1]

    def apply_fault(self, ev: FaultEvent) -> None:
        """Apply one fault event NOW (also driven by the ``faults=``
        schedule at the top of each tick).  Public so tests can push the
        simulator and the live runtime through identical fault scripts."""
        self.metrics.inc("faults_applied")
        if ev.kind in LINK_KINDS:
            ls = self.link_state[ev.target]
            ls.apply(ev)
            # a net-aware boundary re-caps against the changed link
            if self.control is not None:
                pol = self.control.policies[
                    min(ev.target, len(self.control.policies) - 1)]
                if isinstance(pol, AutoOffload):
                    pol.set_link_capacity(ls.effective_capacity())
        elif ev.kind == "crash_tier":
            self._crash_tier(ev.target)
        else:
            self._restore_tier(ev.target)

    def _replay(self, item: _Queued, away_from: int) -> None:
        """Re-route one request lost to a crash/partition: back into a
        reachable serviceable gateway (original submit stamp — the lost
        work stays on its latency clock), or failed when nothing can
        serve.  Nothing is ever silently dropped."""
        self.metrics.inc("replayed")
        tgt = self._route_target(away_from)
        if tgt is None or not self.gateways[tgt].push(item, force=True):
            item.req.failed = True
            self._reject(0, item.fn)

    def _crash_tier(self, i: int) -> None:
        """Tier ``i`` goes down: slots, in-flight rows, backlog, and the
        tier's replicated specs are lost.  Every resident primary replays
        at a reachable tier; hedge arms resolve so the conservation and
        hedge identities hold (a lost twin concedes to its primary, a
        primary whose twin already won adopts the twin's result)."""
        tier = self.tiers[i]
        self.tier_up[i] = False
        lost: List[_Queued] = self.gateways[i].pop_all()
        for fn, fl in tier.inflight.items():
            for rec in fl.values():
                item = rec.item
                pair = item.pair
                if item.hedge:
                    # a lost twin concedes: the primary serves normally
                    if pair.winner is None:
                        pair.winner = "primary"
                        self.metrics.inc("hedges_cancelled")
                    continue
                if pair is not None and pair.winner == "twin":
                    self._adopt(item, pair)      # already served by twin
                    continue
                lost.append(item)
        # the crashed pool is gone: endpoints, autoscalers, in-flight
        # rows, and (for a shallower tier) the replicated edge view —
        # restore rebuilds all of it through the reconciler
        tier.endpoints = {}
        tier.autoscalers = {}
        tier.inflight = {}
        if i < len(self.tiers) - 1:
            self.replicators[i] = ReplicationController()
        for item in lost:
            self._replay(item, i)

    def _restore_tier(self, i: int) -> None:
        """Tier ``i`` comes back empty.  A shallower tier re-registers
        its functions through the replication path — fresh reconciler,
        every spec reports changed, redeploy from the stored artifacts —
        and the fresh autoscalers start at ``min_scale`` (scale-from-zero
        when the policy allows it).  The deepest tier redeploys directly
        (it *is* the spec source)."""
        self.tier_up[i] = True
        if i < len(self.tiers) - 1:
            changed = self.replicators[i].reconcile(self.cloud_specs)
        else:
            changed = {name: True for name in self.cloud_specs}
        for name, spec in self.cloud_specs.items():
            if changed.get(name, True):
                model_cfg, params = self._artifacts[name]
                self.tiers[i].deploy(name, model_cfg, params,
                                     spec.autoscaling)

    # -- trace-driven arrivals (repro.workloads.trace) ------------------------

    def _ingest_trace(self) -> int:
        """Submit every trace row arriving within the interval this tick
        covers.  Rows name functions by the trace's ``fn_names``; names
        not deployed here fall back to deployment order by index."""
        if self.trace is None:
            return 0
        horizon = self._clock + self.control_interval_s
        n = 0
        while (self._trace_pos < len(self.trace)
               and float(self.trace.t[self._trace_pos]) < horizon):
            i = self._trace_pos
            self._trace_pos += 1
            name = self.trace.fn_names[int(self.trace.fn[i])]
            if name not in self._fn_ids:
                if not self.fn_names:
                    raise RuntimeError(
                        "trace ingestion before any function is deployed")
                name = self.fn_names[int(self.trace.fn[i])
                                     % len(self.fn_names)]
            L = max(int(self.trace.prompt_len[i]), 1)
            if self.trace_prompts == "per_fn":
                fn_rng = np.random.default_rng(
                    zlib.crc32(f"{name}:{L}".encode()))
                tokens = fn_rng.integers(0, self.trace_vocab,
                                         L).astype(np.int32)
            else:
                tokens = self._trace_rng.integers(
                    0, self.trace_vocab, L).astype(np.int32)
            req = Request(
                rid=len(self.trace_requests),
                tokens=tokens,
                max_new=max(int(self.trace.max_new[i]), 1))
            self.trace_requests.append(req)
            self.submit(name, req)
            n += 1
        return n

    def controller_update(self) -> np.ndarray:
        """One scrape-and-update cycle through the shared ControlLoop:
        every boundary b sees tier b's latency windows, tier b's own
        gateway backlog ages, and the demand that crossed into tier b
        since the last scrape; returns the ingress boundary's R_t
        percentages."""
        now = time.perf_counter()
        qages = []
        for b in range(self.control.num_boundaries):
            tier_i = min(b, len(self.tiers) - 1)   # 1-tier chain: b=0
            qages.append(self.gateways[tier_i].backlog_ages(
                now, self._tick_no, self._fn_ids, len(self.fn_names)))
        arrivals = list(self._crossings)
        if self.control.eq1 == "sketch":
            # Streaming scrape: only the samples recorded since the last
            # tick leave each tier's registry (no windows, no sort).
            samples = [
                self.tiers[min(b, len(self.tiers) - 1)].metrics.drain_fresh()
                for b in range(self.control.num_boundaries)]
            R_all = self.control.step_stream(samples, queue_ages=qages,
                                             arrivals=arrivals)
        else:
            lats, valids = [], []
            for b in range(self.control.num_boundaries):
                tier_i = min(b, len(self.tiers) - 1)
                lat, valid = self.tiers[tier_i].metrics.latency_windows(
                    self.window)
                lats.append(lat)
                valids.append(valid)
            R_all = self.control.step_tiers(lats, valids, queue_ages=qages,
                                            arrivals=arrivals)
        self._crossings = [np.zeros_like(c) for c in self._crossings]
        return R_all[0]

    def _latency_windows(self):
        """(F, W) ingress-tier latency windows in deployment order."""
        return self.edge.metrics.latency_windows(self.window)

    # -- scheduler ------------------------------------------------------------
    def tick(self) -> Dict[str, float]:
        """One scheduler round: controller update, tier assignment of the
        ingress batch, then the per-tier serving loop.

        ``scheduler="continuous"`` (default) runs the continuous-batching
        decode loop — each scheduler step decodes every in-flight slot
        once, retires finished rows immediately (cancelling their hedge
        siblings), and admits queued requests into the freed slots the
        same step.  ``scheduler="wave"`` keeps the legacy
        run-to-completion wave drain as the before/after baseline."""
        # Chaos first: fault events due on the logical clock reshape the
        # continuum before anything routes, then trace rows arriving in
        # this tick's interval enter the ingress gateway (their demand is
        # part of this very scrape).
        if self.faults is not None:
            for ev in self.faults.due(self._clock):
                self.apply_fault(ev)
        self._ingest_trace()
        R = self.controller_update()
        self._clock += self.control_interval_s
        self._tick_no += 1
        # Mid-stream migration: boundaries whose R_t crossed their
        # policy's threshold ship slot-resident victims down-chain NOW —
        # freed slots are admissible this very tick, the state lands
        # when its link transfer completes (possibly ticks later).
        mig_fired = self._fire_migrations()
        last = len(self.tiers) - 1
        hedged = 0
        pairs: List[_HedgePair] = []
        twins: List[Tuple[int, _Queued]] = []

        # Route the ingress gateway's queue (fresh arrivals + ingress
        # backlog) over the tiers; each assigned request crosses the links
        # down to its tier's gateway.  Deeper gateways' backlogs are NOT
        # re-routed: like the simulator's per-tier queues, they belong to
        # their tier until served or spilled.
        items = self.gateways[0].pop_all()
        if items:
            fn_ids = np.asarray([self._fn_ids[it.fn] for it in items],
                                np.int32)
            self.key, sub = jax.random.split(self.key)
            tier_idx = self.control.route_tiers(sub, fn_ids)
            now = time.perf_counter()
            ages = np.asarray([now - it.t_submit for it in items], np.float32)
            lat, valid = self._latency_windows()
            self.key, hk = jax.random.split(self.key)
            hedge = self.control.hedge(hk, ages, fn_ids, lat, valid)
            for it, tj, hedge_it in zip(items, tier_idx, hedge):
                j = self._route_target(int(tj))
                if j is None:
                    # no serviceable tier is reachable: the live 503
                    it.req.failed = True
                    self._reject(0, it.fn)
                    continue
                if bool(hedge_it) and it.pair is None:
                    # backup request on another tier (straggler hedge);
                    # only the winning arm's latency feeds the windows.
                    # An already-paired leftover is never re-hedged.
                    # The twin is stamped before the primary crosses any
                    # link, so it does not inherit the primary's hop cost.
                    bj = self._route_target(0 if j == last else last)
                    if bj is not None:
                        twin = Request(rid=it.req.rid, tokens=it.req.tokens,
                                       max_new=it.req.max_new,
                                       arrival_s=it.req.arrival_s)
                        pair = _HedgePair(fn=it.fn)
                        it.pair = pair
                        twin_item = _Queued(it.fn, twin, it.t_submit,
                                            tick_no=self._tick_no,
                                            hedge=True, pair=pair)
                        # the twin travels from the ingress gateway to its
                        # backup tier, paying the same links a routed
                        # request would (no crossing counters: it is
                        # duplicate work, not demand) — else the
                        # twin-vs-primary win comparison is biased toward
                        # the free-riding twin
                        for l in range(bj):
                            self._cross_link(twin_item, l)
                        twins.append((bj, twin_item))
                        pairs.append(pair)
                        hedged += 1
                for l in range(j):
                    self._cross_link(it, l)
                self.gateways[j].push(it, force=True)
        if hedged:
            self.metrics.inc("hedges_fired", hedged)

        # This tick's work: every tier's gateway contents + hedge twins.
        pending: Dict[Tuple[int, str], List[_Queued]] = {}
        for ti, gw in enumerate(self.gateways):
            for it in gw.pop_all():
                pending.setdefault((ti, it.fn), []).append(it)
        for bj, it in twins:
            pending.setdefault((bj, it.fn), []).append(it)

        # KPA scrape: every (tier, fn) observes its assigned concurrency —
        # queued plus already slot-resident, including zeros (that is what
        # ages idle functions to zero).
        for ti, tier in enumerate(self.tiers):
            for fn, asc in tier.autoscalers.items():
                ep = tier.endpoints.get(fn)
                if ep is not None and ep.paged:
                    # Paged pools meter demand in pages (memory actually
                    # reserved), normalized to full-row equivalents so the
                    # target-concurrency units match the dense scrape: a
                    # half-row request is half a unit of demand.
                    ppr = ep.pages_per_row
                    pages = sum(
                        ep.page_need(len(it.req.tokens),
                                     max(it.req.max_new, 1))
                        for it in pending.get((ti, fn), []))
                    pages += ep.resident_page_demand()
                    pages += sum(
                        ep.pages_for(max(tr.pos + tr.need - len(tr.toks), 1))
                        for tr in self.migrations
                        if tr.dst == ti and tr.fn == fn)
                    conc = pages / ppr
                else:
                    conc = (len(pending.get((ti, fn), []))
                            + tier.inflight_count(fn)
                            # migrated state headed here is inbound demand
                            # — the destination must not scale to zero
                            # under it
                            + sum(1 for tr in self.migrations
                                  if tr.dst == ti and tr.fn == fn))
                asc.observe(self._clock, float(conc))
                asc.desired(self._clock)

        if self.scheduler == "wave":
            body = self._run_waves(pending, pairs)
        else:
            body = self._run_continuous(pending)

        # Per-tick rejection count, like every sibling field (submit-time
        # rejections since the last tick land in this tick's record).
        rejected_total = sum(g.rejected for g in self.gateways)
        rejected_tick = rejected_total - self._rejected_seen
        self._rejected_seen = rejected_total
        served = body.pop("served")
        # Per-tick link egress (MB), like every sibling field — routing,
        # spill, twins, and migrated cache state all count.
        link_MB = [(b - s) / 1e6 for b, s in
                   zip(self.link_bytes, self._link_bytes_seen)]
        self._link_bytes_seen = list(self.link_bytes)
        rec = {"R": float(R.mean()) if len(R) else 0.0,
               "edge": served[self.tiers[0].name],
               "cloud": served[self.tiers[-1].name],
               "tiers": dict(served),
               "hedged": hedged,
               "migrations_fired": mig_fired,
               **body,
               "link_MB": link_MB,
               "backlog": {t.name: len(g)
                           for t, g in zip(self.tiers, self.gateways)},
               "rejected": rejected_tick,
               "replicas": {t.name: {fn: t.replicas(fn)
                                     for fn in t.autoscalers}
                            for t in self.tiers}}
        self.log.append(rec)
        return rec

    # -- continuous-batching scheduler (the default) --------------------------

    def _adopt(self, item: _Queued, pair: _HedgePair) -> None:
        """A losing/stranded primary's client still gets the winning
        twin's completed result (served once, by the twin)."""
        item.req.output = pair.winner_req.output
        item.req.t_first = pair.winner_req.t_first
        item.req.t_done = pair.winner_req.t_done
        item.req.latency_s = pair.winner_req.latency_s

    def _evict_loser(self, pair: _HedgePair) -> None:
        """Cancel the losing arm of a just-resolved pair if it is still
        slot-resident: the slot frees this very scheduler step (the next
        admission can claim it), no latency sample is recorded for the
        evicted arm, and a cancelled primary adopts the winner's output."""
        ref = pair.primary_ref if pair.winner == "twin" else pair.twin_ref
        if ref is None:
            return
        ti, rec = ref
        tier = self.tiers[ti]
        if tier.inflight.get(pair.fn, {}).get(rec.slot) is rec:
            tier.cancel(pair.fn, rec.slot)
            if pair.winner == "twin":
                self._adopt(rec.item, pair)

    def _settle_resolved(self, item: _Queued) -> bool:
        """A queued item whose hedge pair already resolved never runs: a
        losing twin is dropped, a primary whose twin won adopts the twin's
        completed result.  Returns True when the item leaves the queue."""
        pair = item.pair
        if pair is None or pair.winner is None:
            return False
        if item.hedge:
            return True
        if pair.winner == "twin":
            self._adopt(item, pair)
            return True
        item.pair = None           # twin lost/abandoned: runs normally
        return False

    # -- mid-stream migration (continuous scheduler only) ----------------------

    def _fire_migrations(self) -> int:
        """Launch mid-stream migrations for every boundary whose policy
        carries a ``migrate_threshold`` that its current R_t reaches.

        Tier b selects ``ceil(eligible * R_t/100)`` victims among its
        slot-resident rows — longest remaining decode first (the most
        slot-hungry work) — cancels them locally via the eviction
        machinery, extracts their KV/state rows, and ships them over
        link b: ``nbytes`` is the live cache bytes at the row's decode
        position plus its token tail, the transfer occupies the
        request's clock until it lands, and the bytes count toward the
        link's egress like any other crossing.  Hedge twins and rows of
        already-resolved pairs never migrate (duplicate work is evicted,
        not shipped).
        """
        if self.control is None or self.scheduler != "continuous":
            return 0
        fired = 0
        now = time.perf_counter()
        for b in range(min(self._num_boundaries, len(self.tiers) - 1)):
            pol = self.control.policies[b]
            thr = pol.migrate_threshold
            if thr is None:
                continue
            if not (self.link_state[b].up and self.tier_up[b + 1]):
                continue       # no migrating into a partition/crash
            tier, dst = self.tiers[b], self.tiers[b + 1]
            link = self.link_state[b]
            for fn, fl in tier.inflight.items():
                if not fl:
                    continue
                R_b = float(self.control.R_all[b][self._fn_ids[fn]])
                if R_b < thr:
                    continue
                ep = tier.endpoints[fn]
                dep = dst.endpoints.get(fn)
                if dep is None or not ep.compatible_with(dep):
                    continue       # rows only transplant onto a twin pool
                eligible = [
                    rec for rec in fl.values()
                    if not rec.item.hedge
                    and (rec.item.pair is None
                         or rec.item.pair.winner is None)
                    and rec.need - len(rec.toks) >= pol.migrate_min_remaining]
                n = min(len(eligible), math.ceil(len(eligible) * R_b / 100.0))
                if n <= 0:
                    continue
                eligible.sort(key=lambda r: (-(r.need - len(r.toks)), r.slot))
                victims = eligible[:n]
                states = ep.extract_rows([r.slot for r in victims])
                for rec, state in zip(victims, states):
                    pos = int(ep.slot_pos[rec.slot])
                    tier.cancel(fn, rec.slot)      # slot frees NOW
                    nbytes = (ep.cache_nbytes_per_row(pos)
                              + 4.0 * (len(rec.item.req.tokens)
                                       + len(rec.toks)))
                    self.link_bytes[b] += nbytes
                    self._count_crossing(b + 1, fn)
                    self.migrations.append(_Transit(
                        item=rec.item, fn=fn, rows=state, pos=pos,
                        toks=rec.toks, need=rec.need, src=b, dst=b + 1,
                        t_land=now + link.latency_s(nbytes),
                        nbytes=nbytes))
                    fired += 1
        if fired:
            self.metrics.inc("migrations_fired", fired)
        return fired

    def _readmit(self, ti: int, tr: _Transit, force: bool = False) -> bool:
        """Insert a landed row state into tier ``ti``'s pool and resume
        its decode (no re-prefill).  Respects the autoscaler-admitted
        budget unless ``force`` (the migration analogue of the
        scale-from-zero floor: a resident request implies >= 1 desired
        replica, so a both-ends-scaled-to-zero deadlock resumes anyway).
        """
        tier = self.tiers[ti]
        ep = tier.endpoints.get(tr.fn)
        if ep is None:             # tier crashed: its pool is gone
            return False
        if not force and min(
                tier.free_slots(tr.fn),
                tier.capacity(tr.fn) - tier.inflight_count(tr.fn)) <= 0:
            return False
        # the landing row must reserve pages for its remaining decode —
        # a page-full destination aborts the migration (in pages, like
        # admission), even under force
        extent = max(tr.pos + max(tr.need - len(tr.toks), 0), 1)
        if ep.paged and ep.admissible_pages < ep.pages_for(extent):
            return False
        slot = ep.try_claim(reserve_tokens=extent if ep.paged else None)
        if slot is None:
            return False
        ep.insert_rows([tr.rows], [slot], [tr.pos])
        rec = _InFlight(tr.item, slot, tr.toks, tr.need)
        tier.inflight[tr.fn][slot] = rec
        if tr.item.pair is not None:
            tr.item.pair.set_ref(tr.item.hedge, ti, rec)
        return True

    def _abort_transit(self, tr: _Transit) -> None:
        """A transit that can never land at its destination: resume at
        the source, or — when the source too is crashed or has no free
        slot — replay the request from scratch at a reachable gateway.
        Counted aborted either way; never lost, never left in transit."""
        self.metrics.inc("migrations_aborted")
        pair = tr.item.pair
        if pair is not None and pair.winner is not None:
            if pair.winner == "twin":
                self._adopt(tr.item, pair)
            return
        if self.tier_up[tr.src] and self._readmit(tr.src, tr, force=True):
            return
        self._replay(tr.item, tr.src)

    def _land_migrations(self) -> Tuple[int, int]:
        """Resolve in-flight migrations whose transfer completed.

        A landing row re-enters decode at the destination; a full
        destination ABORTS the migration and the row resumes at its
        source instead — never lost (both ends full: it stays in
        transit and is retried next scheduler step).  A row whose hedge
        pair resolved against it mid-flight is dropped (its twin already
        served the request) and counts as aborted.  Returns
        ``(completed, aborted)``.
        """
        if not self.migrations:
            return 0, 0
        now = time.perf_counter()
        completed = aborted = 0
        still: List[_Transit] = []
        for tr in self.migrations:
            if (not self.link_state[tr.dst - 1].up
                    or not self.tier_up[tr.dst]):
                # the link partitioned (or the destination crashed) with
                # the transfer in flight: the state never arrives —
                # abort back to the source NOW, not at t_land, so
                # drain() can never spin on an unlandable transit
                self._abort_transit(tr)
                aborted += 1
                continue
            if now < tr.t_land:
                still.append(tr)
                continue
            pair = tr.item.pair
            if pair is not None and pair.winner is not None:
                if pair.winner == "twin":
                    self._adopt(tr.item, pair)
                self.metrics.inc("migrations_aborted")
                aborted += 1
            elif self._readmit(tr.dst, tr):
                self.metrics.inc("migrations_completed")
                completed += 1
            elif self._readmit(tr.src, tr):
                self.metrics.inc("migrations_aborted")
                aborted += 1
            else:
                still.append(tr)
        self.migrations = still
        return completed, aborted

    def _run_continuous(self, pending: Dict[Tuple[int, str], List[_Queued]]
                        ) -> Dict:
        """The continuous-batching decode loop over every tier.

        Each iteration is one scheduler step: (1) one shared ``decode_all``
        step per endpoint with in-flight slots, retiring finished rows
        immediately (a retiring hedge arm wins its pair and evicts its
        slot-resident sibling); (2) one admission pass packing queued
        requests into the freed slots (bucketed prefill), capped at
        ``max_waves_per_tick`` admission rounds.  With
        ``max_steps_per_tick`` set, long requests stay slot-resident
        across ticks; otherwise the tick runs until all admitted work
        retires, preserving the PR-1..3 per-tick window semantics."""
        served: Dict[str, int] = {t.name: 0 for t in self.tiers}
        last = len(self.tiers) - 1
        waves = steps = spilled = 0
        won = cancelled = 0
        mig_completed = mig_aborted = 0

        def adm_capped() -> bool:
            return (self.max_waves_per_tick is not None
                    and waves >= self.max_waves_per_tick)

        def stp_capped() -> bool:
            return (self.max_steps_per_tick is not None
                    and steps >= self.max_steps_per_tick)

        def retire(ti: int, fn: str, rec: _InFlight) -> None:
            """A finished row left its slot: resolve its hedge pair and
            record/serve it (losers record nothing)."""
            nonlocal won, cancelled
            tier = self.tiers[ti]
            item = rec.item
            lat = tier.finish(fn, rec)
            pair = item.pair
            arm = "twin" if item.hedge else "primary"
            if pair is not None and pair.winner is None:
                # first arm home wins; the sibling's slot is evicted NOW
                pair.winner = arm
                pair.winner_req = item.req
                if item.hedge:
                    won += 1
                    self.metrics.inc("hedges_won")
                else:
                    cancelled += 1
                    self.metrics.inc("hedges_cancelled")
                self._evict_loser(pair)
            elif pair is not None and pair.winner != arm:
                return             # losing arm outran its eviction: drop
            tier.metrics.record_latency(fn, lat)
            served[tier.name] += 1

        def admit_batch(ti: int, fn: str, batch: List[_Queued]) -> None:
            in_flight, finished = self.tiers[ti].admit(fn, batch)
            for rec in in_flight:
                if rec.item.pair is not None:
                    rec.item.pair.set_ref(rec.item.hedge, ti, rec)
            for rec in finished:
                retire(ti, fn, rec)

        def admit_round() -> bool:
            admitted_any = False
            for (ti, fn), lst in pending.items():
                if not lst:
                    continue
                lst[:] = [it for it in lst if not self._settle_resolved(it)]
                tier = self.tiers[ti]
                budget = tier.admission_budget(
                    fn, lst,
                    cap=tier.capacity(fn) - tier.inflight_count(fn))
                if budget <= 0 or not lst:
                    continue
                batch, pending[(ti, fn)] = lst[:budget], lst[budget:]
                admit_batch(ti, fn, batch)
                admitted_any = True
            return admitted_any

        def await_landing() -> None:
            """Nothing to decode or admit until a transfer lands: wait
            out the earliest link arrival (sub-tick landings; a
            step-capped tick instead breaks out of the loop and the
            landing happens a later tick — the cross-tick case)."""
            nonlocal mig_completed, mig_aborted
            wait = (min(tr.t_land for tr in self.migrations)
                    - time.perf_counter())
            if wait > 0:
                time.sleep(wait)
            c, a = self._land_migrations()
            mig_completed += c
            mig_aborted += a
            if not (c or a):
                # Landing blocked on capacity at BOTH ends (e.g. scaled
                # to zero): resume anyway — the migration analogue of
                # the scale-from-zero floor.  Only a transit whose link
                # transfer has actually completed may be force-landed;
                # one exists, since we just slept to the earliest t_land.
                now = time.perf_counter()
                idx = next(i for i, tr in enumerate(self.migrations)
                           if tr.t_land <= now)
                tr = self.migrations.pop(idx)
                if self._readmit(tr.dst, tr, force=True):
                    self.metrics.inc("migrations_completed")
                    mig_completed += 1
                elif self._readmit(tr.src, tr, force=True):
                    self.metrics.inc("migrations_aborted")
                    mig_aborted += 1
                else:
                    raise RuntimeError(
                        "scheduler wedged: migrated state cannot "
                        "land on any tier")

        while True:
            # (0) land migrated state whose link transfer completed: the
            # rows re-enter the destination's decode stream mid-tick
            c, a = self._land_migrations()
            mig_completed += c
            mig_aborted += a
            # (1) one decode step across every endpoint with work
            stepped = False
            for ti, tier in enumerate(self.tiers):
                for fn in tier.endpoints:
                    if tier.inflight_count(fn) == 0:
                        continue
                    stepped = True
                    for rec in tier.step(fn):
                        retire(ti, fn, rec)
            if stepped:
                steps += 1
            # (2) admit into freed slots, same step — also under a step
            # cap, so paced ticks keep admitting fresh arrivals into free
            # slots alongside the slot-resident work
            admitted = False
            if not adm_capped():
                admitted = admit_round()
                if admitted:
                    waves += 1
            if stepped and stp_capped():
                break              # in-flight work carries over to next tick
            if self.in_flight == 0:
                if not any(pending.values()):
                    break
                if adm_capped():
                    break          # leftovers requeue below
            if stepped or admitted:
                continue
            if not any(pending.values()):
                if not self.migrations:
                    break          # only resolved-pair items were swept
                await_landing()    # idle until the next transfer arrives
                continue
            # Stalled: nothing decoding, nothing admissible.
            progress = False
            if self.topology.waterfall:
                # Waterfall: a tier with no admitted capacity (e.g. scaled
                # to zero with scale-up disabled) spills its pending load
                # over the link to the next tier's work queue.
                for (ti, fn), lst in list(pending.items()):
                    tier = self.tiers[ti]
                    if (lst and ti < last
                            and self.link_state[ti].up
                            and self.tier_up[ti + 1]
                            and tier.admission_budget(
                                fn, lst[:1],
                                cap=tier.capacity(fn)
                                - tier.inflight_count(fn)) <= 0):
                        for it in lst:
                            self._cross_link(it, ti)
                        pending.setdefault((ti + 1, fn), []).extend(lst)
                        pending[(ti, fn)] = []
                        spilled += len(lst)
                        progress = True
            if progress:
                continue
            # Scale-from-zero floor: a queued request implies >= 1 desired
            # replica next scrape; don't deadlock on degenerate autoscaling
            # bounds in the meantime.
            for (ti, fn), lst in pending.items():
                if lst and self.tiers[ti].admission_budget(fn, lst[:1]) > 0:
                    admit_batch(ti, fn, [lst.pop(0)])
                    waves += 1
                    progress = True
                    break
            if not progress:
                if self.migrations:
                    await_landing()    # a landing frees slots/capacity
                    continue
                raise RuntimeError("scheduler wedged: pending work but "
                                   "no free slot on any tier")

        # Tick over: unserved hedge twins are abandoned — the pair resolves
        # to the primary, which records normally when it completes.
        for lst in pending.values():
            for it in lst:
                if it.hedge and it.pair.winner is None:
                    it.pair.winner = "primary"
                    cancelled += 1
                    self.metrics.inc("hedges_cancelled")
        # Unserved primaries whose twin already won adopt the twin's
        # result; the rest go back to *their tier's* gateway, keeping
        # their original submit time and tick stamp so the backlog age the
        # next scrape reads stays monotone.  A primary whose twin is still
        # slot-resident (steps capped) keeps its pair link — the race
        # settles next tick.
        adopted = 0
        requeue: Dict[int, List[_Queued]] = {}
        for (ti, fn), lst in pending.items():
            for it in lst:
                if it.hedge:
                    continue
                pair = it.pair
                if pair is not None and pair.winner == "twin":
                    self._adopt(it, pair)
                    adopted += 1
                    continue
                if pair is not None and pair.winner == "primary":
                    it.pair = None
                requeue.setdefault(ti, []).append(it)
        for ti, lst in requeue.items():
            for it in sorted(lst, key=lambda it: it.t_submit):
                if not self.gateways[ti].push(it):
                    # the tier's bounded backlog is full: the request is
                    # dropped for good (queue-proxy 503) and says so
                    it.req.failed = True
                    self._reject(ti, it.fn)
                    if it.pair is not None and it.pair.winner is None:
                        # a dropped primary can never adopt: abandon the
                        # race and evict its still-running twin too
                        it.pair.winner = "primary"
                        cancelled += 1
                        self.metrics.inc("hedges_cancelled")
                        self._evict_loser(it.pair)
        return {"served": served, "hedges_won": won,
                "hedges_cancelled": cancelled, "spilled": spilled,
                "waves": waves, "steps": steps,
                "migrated": mig_completed,
                "migrations_aborted": mig_aborted,
                "inflight": self.in_flight}

    # -- legacy run-to-completion wave scheduler -------------------------------

    def _run_waves(self, pending: Dict[Tuple[int, str], List[_Queued]],
                   pairs: List[_HedgePair]) -> Dict:
        """Drain every tier's gateway in autoscaler-budgeted waves, each
        run to completion (the pre-async baseline kept for
        ``bench_continuous_vs_wave``)."""
        served: Dict[str, int] = {t.name: 0 for t in self.tiers}
        last = len(self.tiers) - 1
        waves = spilled = 0

        def dispatch(ti: int, fn: str, batch: List[_Queued]) -> None:
            nonlocal waves
            tier = self.tiers[ti]
            record = [it.pair is None for it in batch]
            results = tier.serve_batch(
                fn, [(it.req, it.t_submit) for it in batch], record=record)
            waves += 1
            for it, (_, lat) in zip(batch, results):
                if it.pair is not None:
                    it.pair.note(it, tier, lat)
                if not it.hedge:
                    served[tier.name] += 1

        def capped() -> bool:
            return (self.max_waves_per_tick is not None
                    and waves >= self.max_waves_per_tick)

        # Drain in waves: each wave packs up to the autoscaler-admitted
        # concurrency into one batched serve (shared prefill + decode_all).
        while any(pending.values()) and not capped():
            progress = False
            for (ti, fn), lst in pending.items():
                if not lst or capped():
                    continue
                tier = self.tiers[ti]
                budget = tier.admission_budget(fn, lst,
                                               cap=tier.capacity(fn))
                if budget <= 0:
                    continue
                batch, pending[(ti, fn)] = lst[:budget], lst[budget:]
                dispatch(ti, fn, batch)
                progress = True
            if not progress and self.topology.waterfall:
                # Waterfall: a tier with no admitted capacity (e.g. scaled
                # to zero with scale-up disabled) spills its pending load
                # over the link to the next tier's work queue.
                for (ti, fn), lst in list(pending.items()):
                    tier = self.tiers[ti]
                    if (lst and ti < last
                            and self.link_state[ti].up
                            and self.tier_up[ti + 1]
                            and tier.admission_budget(
                                fn, lst[:1], cap=tier.capacity(fn)) <= 0):
                        for it in lst:
                            self._cross_link(it, ti)
                        pending.setdefault((ti + 1, fn), []).extend(lst)
                        pending[(ti, fn)] = []
                        spilled += len(lst)
                        progress = True
            if not progress:
                # Scale-from-zero floor: a queued request implies >= 1
                # desired replica next scrape; don't deadlock on degenerate
                # autoscaling bounds in the meantime.
                for (ti, fn), lst in pending.items():
                    if lst and self.tiers[ti].admission_budget(
                            fn, lst[:1]) > 0:
                        dispatch(ti, fn, [lst.pop(0)])
                        progress = True
                        break
                if not progress:
                    raise RuntimeError("scheduler wedged: pending work but "
                                       "no free slot on any tier")

        # Wave budget exhausted: unserved primaries whose hedge twin
        # already completed adopt the twin's result (served once, by the
        # twin — never requeued and served a second time); the rest go
        # back to *their tier's* gateway, keeping their submit time and
        # tick stamp so the next scrape sees their queue age at the
        # boundary they actually wait at.  Unserved hedge twins are
        # dropped.
        adopted = 0
        requeue: Dict[int, List[_Queued]] = {}
        for (ti, fn), lst in pending.items():
            for it in lst:
                if it.hedge:
                    continue
                pair = it.pair
                if pair is not None and pair.twin_lat is not None:
                    pair.winner = "twin"
                    pair.winner_req = pair.twin_req
                    self._adopt(it, pair)
                    pair.twin_tier.metrics.record_latency(it.fn,
                                                          pair.twin_lat)
                    served[pair.twin_tier.name] += 1
                    adopted += 1
                    continue
                if pair is not None:
                    # the unserved twin is dropped with its primary
                    # requeued: the hedge is over (counted cancelled)
                    pair.winner = "primary"
                it.pair = None       # a requeued primary records normally
                requeue.setdefault(ti, []).append(it)
        for ti, lst in requeue.items():
            for it in sorted(lst, key=lambda it: it.t_submit):
                if not self.gateways[ti].push(it):
                    # the tier's bounded backlog is full: the request is
                    # dropped for good (queue-proxy 503) and says so
                    it.req.failed = True
                    self._reject(ti, it.fn)

        # Resolve hedge pairs: only the winning arm's latency feeds the
        # controller windows, so a slow loser cannot bias R_t.  Both arms
        # ran to completion here (no mid-flight cancellation in wave mode);
        # ``winner`` is stamped so pair-level accounting stays consistent.
        won = adopted
        cancelled = 0
        for pair in pairs:
            if pair.primary_lat is None:
                if pair.winner == "primary" and pair.twin_lat is None:
                    cancelled += 1   # both arms unserved: hedge abandoned
                continue         # primary requeued or adopted; handled above
            if pair.twin_lat is not None and pair.twin_lat < pair.primary_lat:
                pair.twin_tier.metrics.record_latency(pair.fn, pair.twin_lat)
                pair.winner = "twin"
                won += 1
            else:
                pair.primary_tier.metrics.record_latency(pair.fn,
                                                         pair.primary_lat)
                pair.winner = "primary"
                cancelled += 1
        if won:
            self.metrics.inc("hedges_won", won)
        if cancelled:
            self.metrics.inc("hedges_cancelled", cancelled)
        return {"served": served, "hedges_won": won,
                "hedges_cancelled": cancelled, "spilled": spilled,
                "waves": waves, "steps": 0, "migrated": 0,
                "migrations_aborted": 0, "inflight": 0}
