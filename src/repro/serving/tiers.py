"""The two-tier Edge-Cloud continuum runtime.

This is the live (non-simulated) integration of every paper component:

    EdgeCloudContinuum
      ├── edge tier:  Endpoint pool (small slots/model) + MetricsRegistry
      ├── cloud tier: Endpoint pool (large slots)       + MetricsRegistry
      ├── ReplicationController  (cloud spec -> edge, selective merge)
      ├── OffloadController      (Eqs (1)-(4) on edge latency windows)
      ├── Router                 (batch split by R_t percentage)
      └── Autoscaler per tier    (Knative-KPA-style concurrency scaling)

Requests enter at the edge gateway (``submit``); each scheduler tick
drains the queue, routes a fraction to the cloud per the controller, runs
prefill+decode on both tiers, and records per-request latency back into
the metrics that drive the next controller update — the same closed loop
as the paper's Knative Edge, at batch granularity.

Everything model-related goes through ``serving.engine.Endpoint``; tier
capacities are expressed in concurrent slots, so the same runtime works
with real TPU meshes (slots = per-pod batch) or the CPU tests (slots=4).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import offload, router
from repro.core.metrics import MetricsRegistry
from repro.core.replication import (EdgeServiceState, FunctionSpec,
                                    ReplicationController)
from repro.models.common import ModelConfig
from repro.serving.engine import Endpoint, Request


@dataclasses.dataclass
class TierConfig:
    slots: int = 4
    max_len: int = 256
    # synthetic per-request overhead (edge->cloud WAN RTT), seconds
    extra_latency_s: float = 0.0


class Tier:
    """One serving location: endpoints by function name + metrics."""

    def __init__(self, name: str, cfg: TierConfig):
        self.name = name
        self.cfg = cfg
        self.endpoints: Dict[str, Endpoint] = {}
        self.metrics = MetricsRegistry([])

    def deploy(self, fn_name: str, model_cfg: ModelConfig, params) -> None:
        self.endpoints[fn_name] = Endpoint(
            model_cfg, params, slots=self.cfg.slots, max_len=self.cfg.max_len)
        self.metrics.register(fn_name)

    def serve_one(self, fn_name: str, req: Request, now_s: float) -> Tuple[np.ndarray, float]:
        """Prefill + greedy decode for one request; returns (tokens, latency)."""
        ep = self.endpoints[fn_name]
        t0 = time.perf_counter()
        slot = ep.try_claim()
        if slot is None:
            # queue-free fallback: serve anyway at batch position 0 cost —
            # the scheduler above is responsible for not oversubscribing.
            slot = 0
        try:
            tok = ep.prefill_one(slot, req.tokens)
            out = [tok]
            for _ in range(req.max_new - 1):
                nxt = ep.decode_all({slot: out[-1]})
                out.append(nxt[slot])
        finally:
            ep.release(slot)
        lat = time.perf_counter() - t0 + self.cfg.extra_latency_s
        self.metrics.record_latency(fn_name, lat)
        return np.asarray(out, np.int32), lat


class EdgeCloudContinuum:
    """The full platform: replication + offloading across two tiers."""

    def __init__(self, edge: TierConfig, cloud: TierConfig,
                 offload_cfg: offload.OffloadConfig = offload.OffloadConfig(),
                 window: int = 64, seed: int = 0):
        self.edge = Tier("edge", edge)
        self.cloud = Tier("cloud", cloud)
        self.offload_cfg = offload_cfg
        self.window = window
        self.replicator = ReplicationController()
        self.cloud_specs: Dict[str, FunctionSpec] = {}
        self.fn_names: List[str] = []
        self.state: Optional[offload.OffloadState] = None
        self.key = jax.random.PRNGKey(seed)
        self.queue: Deque[Tuple[str, Request]] = deque()
        self.log: List[Dict] = []
        self._clock = 0.0

    # -- deployment (paper §3.3.1) ------------------------------------------
    def deploy(self, spec: FunctionSpec, model_cfg: ModelConfig, params) -> None:
        """Deploy to the cloud; replication mirrors it to the edge."""
        self.cloud.deploy(spec.name, model_cfg, params)
        self.cloud_specs[spec.name] = spec
        changed = self.replicator.reconcile(self.cloud_specs)
        if changed.get(spec.name, True):
            self.edge.deploy(spec.name, model_cfg, params)
        if spec.name not in self.fn_names:
            self.fn_names.append(spec.name)
            self.state = offload.OffloadState.init(len(self.fn_names),
                                                   self.offload_cfg)

    # -- request path (paper §3.3.2) ------------------------------------------
    def submit(self, fn_name: str, req: Request) -> None:
        self.queue.append((fn_name, req))

    def controller_update(self) -> np.ndarray:
        """One scrape-and-update cycle; returns R_t percentages."""
        lats, valid = self._latency_windows()
        self.state, R = offload.offload_update(
            self.state, jnp.asarray(lats), self.offload_cfg,
            valid=jnp.asarray(valid))
        return np.asarray(R)

    def _latency_windows(self):
        """(F, W) edge-tier latency windows in deployment order."""
        return self.edge.metrics.latency_windows(self.window)

    def tick(self) -> Dict[str, float]:
        """One scheduler round: update controller, drain queue, serve."""
        R = self.controller_update()
        served_edge = served_cloud = 0
        n = len(self.queue)
        if n:
            fn_ids = np.asarray([self.fn_names.index(f) for f, _ in self.queue],
                                np.int32)
            self.key, sub = jax.random.split(self.key)
            to_cloud = np.asarray(router.route_batch(
                sub, jnp.asarray(R), jnp.asarray(fn_ids), len(self.fn_names)))
            items = [self.queue.popleft() for _ in range(n)]
            for (fn, req), cloudward in zip(items, to_cloud):
                tier = self.cloud if bool(cloudward) else self.edge
                out, lat = tier.serve_one(fn, req, self._clock)
                req.output = out
                if cloudward:
                    served_cloud += 1
                else:
                    served_edge += 1
        rec = {"R": float(R.mean()) if len(R) else 0.0,
               "edge": served_edge, "cloud": served_cloud}
        self.log.append(rec)
        return rec
