"""The live N-tier continuum runtime.

This is the live (non-simulated) integration of every paper component:

    EdgeCloudContinuum (over a Topology chain, ingress at tier 0)
      ├── tier 0..N-1:  Gateway (bounded backlog queue) + Endpoint pool
      │                 (slots/model) + MetricsRegistry + per-function
      │                 Autoscaler (Knative-KPA concurrency)
      ├── ReplicationController  (deepest-tier spec -> shallower tiers,
      │                           selective merge)
      ├── ControlLoop + Policy   (Eqs (1)-(4) / static / net-aware / hedged
      │                           — one controller boundary per adjacent
      │                           tier pair, the same loop the simulator
      │                           drives)
      └── Router                 (vectorized categorical assignment of the
                                  queued batch over the tier distribution)

Requests enter at the ingress gateway (``submit``); each scheduler tick
runs one scrape-and-update cycle through the shared
:class:`repro.core.policy.ControlLoop`, assigns the ingress batch over
the tiers by the composed R_t distribution, and drains **each tier's own
gateway** in autoscaler-budgeted *waves*: every wave packs up to a tier's
admitted concurrency into one ``Endpoint`` prefill + a shared
``decode_all`` stream, so co-scheduled requests advance together
(continuous batching).  Moving a request down the chain — routing past a
boundary or (with ``topology.waterfall``) spilling a stalled tier's load
— crosses the corresponding :class:`~repro.core.topology.LinkSpec`,
charging its RTT + payload serialization to the request's latency clock
and counting the boundary crossing.

The controller sees the continuum the way the paper's Knative deployment
does (queue-proxy depth/age gauges per component): boundary b is fed tier
b's latency windows, tier b's **own gateway backlog ages**, and the
demand that actually **crossed** into tier b this interval (the
per-boundary ``arrivals`` form of ``ControlLoop.step_tiers``), so an
intermediate boundary's R_t rises when its own backlog ages — before its
completions drain — and ``auto+net`` caps each boundary by the link it
actually crosses.  Requests a wave budget could not serve stay queued in
their tier's gateway (the ingress gateway's backlog re-enters routing;
deeper backlogs belong to their tier), which is exactly the simulator's
per-tier queue state.

The historical two-tier constructor (``edge=..., cloud=...``) builds a
2-tier :class:`~repro.core.topology.Topology` via :meth:`Topology.pair`;
``edge``/``cloud`` remain as attribute aliases for the ingress/deepest
tiers.  Everything model-related goes through ``serving.engine.Endpoint``;
tier capacities are expressed in concurrent slots, so the same runtime
works with real TPU meshes (slots = per-pod batch) or the CPU tests
(slots=4).
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import offload
from repro.core.autoscaler import Autoscaler
from repro.core.metrics import MetricsRegistry
from repro.core.policy import ControlLoop, Policy, PolicySpec
from repro.core.replication import (AutoscalingPolicy, FunctionSpec,
                                    ReplicationController)
from repro.core.topology import TierSpec, Topology
from repro.models.common import ModelConfig
from repro.serving.engine import Endpoint, Request


@dataclasses.dataclass
class TierConfig:
    """Legacy two-tier tier shape (sugar for a named
    :class:`~repro.core.topology.TierSpec` via ``Topology.pair``)."""
    slots: int = 4
    max_len: int = 256
    # synthetic per-request overhead (edge->cloud WAN RTT), seconds
    extra_latency_s: float = 0.0
    # default KPA bounds for functions deployed without an explicit policy
    autoscaling: Optional[AutoscalingPolicy] = None
    stable_window_s: float = 60.0
    panic_window_s: float = 6.0


@dataclasses.dataclass
class _Queued:
    """One gateway queue entry (+ hedge bookkeeping)."""
    fn: str
    req: Request
    t_submit: float
    tick_no: int = 0
    hedge: bool = False
    pair: Optional["_HedgePair"] = None


@dataclasses.dataclass
class _HedgePair:
    """Links a primary request to its hedge twin so only the winning
    arm's latency feeds the controller."""
    fn: str
    primary_lat: Optional[float] = None
    primary_tier: Optional["Tier"] = None
    twin_lat: Optional[float] = None
    twin_tier: Optional["Tier"] = None
    twin_req: Optional[Request] = None

    def note(self, item: "_Queued", tier: "Tier", lat: float) -> None:
        if item.hedge:
            self.twin_lat, self.twin_tier = lat, tier
            self.twin_req = item.req
        else:
            self.primary_lat, self.primary_tier = lat, tier


class Gateway:
    """One tier's bounded backlog queue (the Knative queue-proxy stand-in).

    Requests wait here between scheduler ticks; the controller boundary
    of the owning tier reads the backlog's ages each scrape.  ``capacity``
    bounds the *resting* backlog (``None`` = unbounded): client submits
    and requeues past it are rejected (the live 503), while in-tick
    placement uses ``force=True`` because a routed request may still be
    served this very tick.
    """

    def __init__(self, capacity: Optional[int] = None):
        self.capacity = capacity
        self.items: Deque[_Queued] = deque()
        self.rejected = 0

    def push(self, item: _Queued, force: bool = False) -> bool:
        if (not force and self.capacity is not None
                and len(self.items) >= self.capacity):
            self.rejected += 1
            return False
        self.items.append(item)
        return True

    def pop_all(self) -> List[_Queued]:
        items = list(self.items)
        self.items.clear()
        return items

    def backlog_ages(self, now: float, tick_no: int,
                     fn_ids: Dict[str, int],
                     num_functions: int) -> List[List[float]]:
        """Per-function ages of true *backlog*: entries that survived a
        previous scheduler round.  Fresh arrivals have waited ~0 s —
        mixing those into X_l(t) would drag p50 toward zero and fire
        Eq (1) spuriously."""
        ages: List[List[float]] = [[] for _ in range(num_functions)]
        for item in self.items:
            if item.tick_no < tick_no:
                ages[fn_ids[item.fn]].append(now - item.t_submit)
        return ages

    def __len__(self) -> int:
        return len(self.items)


class Tier:
    """One serving location: endpoints by function name + metrics +
    per-function KPA autoscalers.

    ``cfg`` may be a legacy :class:`TierConfig` or an N-tier
    :class:`~repro.core.topology.TierSpec` — both carry the same serving
    fields."""

    def __init__(self, name: str, cfg):
        self.name = name
        self.cfg = cfg
        self.endpoints: Dict[str, Endpoint] = {}
        self.autoscalers: Dict[str, Autoscaler] = {}
        self.metrics = MetricsRegistry([])

    def deploy(self, fn_name: str, model_cfg: ModelConfig, params,
               autoscaling: Optional[AutoscalingPolicy] = None) -> None:
        self.endpoints[fn_name] = Endpoint(
            model_cfg, params, slots=self.cfg.slots, max_len=self.cfg.max_len)
        self.metrics.register(fn_name)
        # A TierSpec that declares its own KPA bounds governs its whole
        # pool (e.g. an intermediate tier pinned to zero with max_scale=0).
        # Legacy TierConfig keeps its documented fallback semantics: the
        # function's spec wins, the tier's bounds apply only when the
        # function has none.
        if isinstance(self.cfg, TierSpec) and self.cfg.autoscaling is not None:
            policy = self.cfg.autoscaling
        else:
            policy = autoscaling or self.cfg.autoscaling or AutoscalingPolicy()
        self.autoscalers[fn_name] = Autoscaler(
            policy,
            stable_window_s=self.cfg.stable_window_s,
            panic_window_s=self.cfg.panic_window_s)

    # -- capacity ----------------------------------------------------------
    def free_slots(self, fn_name: str) -> int:
        ep = self.endpoints[fn_name]
        return ep.slots - ep.active

    def capacity(self, fn_name: str) -> int:
        """Admitted concurrency right now: ceil(replicas x target
        concurrency), bounded by the KV-cache pool. 0 when scaled to zero.
        A fractional target under-one admits *less* than one request per
        replica (e.g. 2 replicas x 0.5 admit 1), not one per replica."""
        asc = self.autoscalers[fn_name]
        want = math.ceil(asc.replicas * asc.policy.target_concurrency)
        return min(self.endpoints[fn_name].slots, want)

    def replicas(self, fn_name: str) -> int:
        return self.autoscalers[fn_name].replicas

    # -- serving -----------------------------------------------------------
    def serve_batch(self, fn_name: str,
                    items: List[Tuple[Request, float]],
                    record: Optional[List[bool]] = None
                    ) -> List[Tuple[np.ndarray, float]]:
        """Serve a wave of requests together on one endpoint.

        All prompts share packed prefill calls and one ``decode_all``
        stream; each request's latency is measured from its submit
        timestamp to the decode step that finished it. ``record`` masks
        which latencies feed this tier's metrics (hedged arms defer to the
        pair winner). The caller is responsible for sizing waves within
        ``free_slots`` — admission past the pool raises instead of
        silently corrupting a live slot's KV cache (the old ``slot = 0``
        fallback).
        """
        ep = self.endpoints[fn_name]
        claimed: List[Tuple[Request, float, int]] = []
        for req, t_submit in items:
            slot = ep.try_claim()
            if slot is None:
                for _, _, s in claimed:
                    ep.release(s)
                raise RuntimeError(
                    f"{self.name}/{fn_name}: wave of {len(items)} exceeds "
                    f"free slots — scheduler admitted past capacity")
            claimed.append((req, t_submit, slot))

        try:
            firsts = ep.prefill_batch(
                {slot: req.tokens for req, _, slot in claimed})
            now = time.perf_counter()
            outs: Dict[int, List[int]] = {}
            need: Dict[int, int] = {}
            done_at: Dict[int, float] = {}
            active: Dict[int, int] = {}
            for req, _, slot in claimed:
                outs[slot] = [firsts[slot]]
                need[slot] = max(req.max_new, 1)
                done_at[slot] = now
                req.t_first = now
                if need[slot] > 1:
                    active[slot] = firsts[slot]
            while active:
                nxt = ep.decode_all(active)
                now = time.perf_counter()
                for s, tok in nxt.items():
                    outs[s].append(tok)
                    if len(outs[s]) >= need[s]:
                        del active[s]
                        done_at[s] = now
                    else:
                        active[s] = tok
        except Exception:
            for _, _, s in claimed:
                ep.release(s)
            raise

        results: List[Tuple[np.ndarray, float]] = []
        for i, (req, t_submit, slot) in enumerate(claimed):
            lat = done_at[slot] - t_submit + self.cfg.extra_latency_s
            if record is None or record[i]:
                self.metrics.record_latency(fn_name, lat)
            req.output = np.asarray(outs[slot], np.int32)
            req.t_done = done_at[slot]
            ep.release(slot)
            results.append((req.output, lat))
        return results

    def serve_one(self, fn_name: str, req: Request,
                  now_s: float = 0.0) -> Tuple[np.ndarray, float]:
        """Serial single-request path (the pre-batching baseline)."""
        del now_s
        [(out, lat)] = self.serve_batch(fn_name, [(req, time.perf_counter())])
        return out, lat


class EdgeCloudContinuum:
    """The full platform: replication + policy-driven offloading across an
    N-tier topology, with per-tier gateways and a batched wave scheduler."""

    def __init__(self, edge=None, cloud=None,
                 policy: PolicySpec = "auto",
                 offload_cfg: Optional[offload.OffloadConfig] = None,
                 window: int = 64, seed: int = 0,
                 control_interval_s: float = 1.0,
                 max_waves_per_tick: Optional[int] = None,
                 topology: Optional[Topology] = None,
                 reject_latency_s: float = 0.005):
        if topology is None:
            if edge is None or cloud is None:
                raise ValueError(
                    "pass either topology=... or the 2-tier edge=/cloud= pair")
            topology = Topology.pair(edge, cloud)
        self.topology = topology
        self.tiers: List[Tier] = [Tier(spec.name, spec)
                                  for spec in topology.tiers]
        self.gateways: List[Gateway] = [
            Gateway(None if spec.queue_depth_per_slot is None
                    else spec.slots * spec.queue_depth_per_slot)
            for spec in topology.tiers]
        self.offload_cfg = offload_cfg or offload.OffloadConfig()
        self._policy_spec: PolicySpec = policy
        self.policy = Policy.parse(policy, offload_cfg=self.offload_cfg)
        self.window = window
        self.control_interval_s = control_interval_s
        # Fast rejections are part of the latency distribution Eq (1)
        # scrapes (queue-proxy 503 semantics, same as the simulator).
        self.reject_latency_s = reject_latency_s
        self.replicator = ReplicationController()
        self.cloud_specs: Dict[str, FunctionSpec] = {}
        self.fn_names: List[str] = []
        self._fn_ids: Dict[str, int] = {}
        self.control: Optional[ControlLoop] = None
        self.key = jax.random.PRNGKey(seed)
        # Demand per boundary since the last scrape: boundary b counts the
        # requests that *reached* tier b (submit, routing, or spill) —
        # what its net-aware cap divides the link capacity by.
        self._num_boundaries = max(len(self.tiers) - 1, 1)
        self._crossings: List[Dict[str, int]] = [
            {} for _ in range(self._num_boundaries)]
        # Platform-level counters (hedging outcomes etc.).
        self.metrics = MetricsRegistry([])
        # None = drain every gateway every tick; an int caps the batched
        # waves per tick, so overload leaves per-tier *backlogs* whose
        # in-flight ages the next scrape mixes into Eq (1) (the
        # simulator's onset signal, now per boundary).
        self.max_waves_per_tick = max_waves_per_tick
        self.log: List[Dict] = []
        self._clock = 0.0          # logical control-plane time (scrapes)
        self._tick_no = 0
        self._rejected_seen = 0    # for per-tick deltas in tick() records

    # Ingress / deepest tier aliases (the historical two-tier attributes).
    @property
    def edge(self) -> Tier:
        return self.tiers[0]

    @property
    def cloud(self) -> Tier:
        return self.tiers[-1]

    @property
    def queue(self) -> Deque[_Queued]:
        """The ingress gateway's queue (historical attribute)."""
        return self.gateways[0].items

    @property
    def queued(self) -> int:
        """Total backlog across every tier's gateway."""
        return sum(len(g) for g in self.gateways)

    # -- deployment (paper §3.3.1) ------------------------------------------
    def deploy(self, spec: FunctionSpec, model_cfg: ModelConfig, params) -> None:
        """Deploy to the deepest tier; replication mirrors the spec to
        every shallower tier of the chain."""
        self.cloud.deploy(spec.name, model_cfg, params, spec.autoscaling)
        self.cloud_specs[spec.name] = spec
        changed = self.replicator.reconcile(self.cloud_specs)
        if changed.get(spec.name, True):
            for tier in self.tiers[:-1]:
                tier.deploy(spec.name, model_cfg, params, spec.autoscaling)
        if spec.name not in self.fn_names:
            self._fn_ids[spec.name] = len(self.fn_names)
            self.fn_names.append(spec.name)
            # Each boundary parses the policy against ITS link's capacity,
            # so auto+net caps offload by the link actually being crossed
            # (mirrors the simulator's per-boundary policies).
            links = self.topology.links
            boundary_policies = [
                Policy.parse(self._policy_spec, offload_cfg=self.offload_cfg,
                             link_bytes_per_s=(
                                 links[min(b, len(links) - 1)].bandwidth_Bps
                                 if links else None))
                for b in range(self._num_boundaries)]
            self.control = ControlLoop(
                self.policy, len(self.fn_names), window=self.window,
                control_interval_s=self.control_interval_s,
                num_tiers=len(self.tiers),
                boundary_policies=boundary_policies)

    # -- request path (paper §3.3.2) ------------------------------------------
    def submit(self, fn_name: str, req: Request) -> bool:
        """Queue a request at the ingress gateway.  Returns False when the
        bounded backlog is full (the live 503 — a fast rejection whose
        latency feeds Eq (1)'s bimodality, as in the simulator)."""
        req.arrival_s = time.perf_counter()
        item = _Queued(fn_name, req, req.arrival_s, tick_no=self._tick_no)
        # Every arrival is ingress demand, admitted or not — the simulator
        # counts a 503'd arrival into arrivals_in_interval the same way.
        self._count_crossing(0, fn_name)
        if not self.gateways[0].push(item):
            req.failed = True
            self._reject(0, fn_name)
            return False
        return True

    def _count_crossing(self, b: int, fn: str) -> None:
        if b < self._num_boundaries:
            self._crossings[b][fn] = self._crossings[b].get(fn, 0) + 1

    def _reject(self, ti: int, fn: str) -> None:
        self.metrics.inc("rejected")
        if ti < len(self.tiers) - 1 or len(self.tiers) == 1:
            self.tiers[ti].metrics.record_latency(fn, self.reject_latency_s)

    def _cross_link(self, item: _Queued, l: int) -> None:
        """Move one queued request over link l (tier l -> tier l+1):
        charge RTT + payload serialization to its latency clock (by
        backdating the submit stamp, so both the measured latency and the
        backlog age include time in flight, as in the simulator) and count
        the boundary crossing for per-boundary demand."""
        if l < len(self.topology.links):
            item.t_submit -= self.topology.links[l].latency_s(
                item.req.tokens.nbytes)
        if not item.hedge:
            self._count_crossing(l + 1, item.fn)

    def controller_update(self) -> np.ndarray:
        """One scrape-and-update cycle through the shared ControlLoop:
        every boundary b sees tier b's latency windows, tier b's own
        gateway backlog ages, and the demand that crossed into tier b
        since the last scrape; returns the ingress boundary's R_t
        percentages."""
        now = time.perf_counter()
        lats, valids, qages = [], [], []
        for b in range(self.control.num_boundaries):
            tier_i = min(b, len(self.tiers) - 1)   # 1-tier chain: b=0
            lat, valid = self.tiers[tier_i].metrics.latency_windows(
                self.window)
            lats.append(lat)
            valids.append(valid)
            qages.append(self.gateways[tier_i].backlog_ages(
                now, self._tick_no, self._fn_ids, len(self.fn_names)))
        arrivals = [[c.get(fn, 0) for fn in self.fn_names]
                    for c in self._crossings]
        R_all = self.control.step_tiers(lats, valids, queue_ages=qages,
                                        arrivals=arrivals)
        for c in self._crossings:
            c.clear()
        return R_all[0]

    def _latency_windows(self):
        """(F, W) ingress-tier latency windows in deployment order."""
        return self.edge.metrics.latency_windows(self.window)

    # -- scheduler ------------------------------------------------------------
    def tick(self) -> Dict[str, float]:
        """One scheduler round: controller update, tier assignment of the
        ingress batch, then drain every tier's gateway in waves (spilling
        down the chain when waterfall is on)."""
        R = self.controller_update()
        self._clock += self.control_interval_s
        self._tick_no += 1
        served: Dict[str, int] = {t.name: 0 for t in self.tiers}
        last = len(self.tiers) - 1
        hedged = waves = spilled = 0
        pairs: List[_HedgePair] = []
        twins: List[Tuple[int, _Queued]] = []

        # Route the ingress gateway's queue (fresh arrivals + ingress
        # backlog) over the tiers; each assigned request crosses the links
        # down to its tier's gateway.  Deeper gateways' backlogs are NOT
        # re-routed: like the simulator's per-tier queues, they belong to
        # their tier until served or spilled.
        items = self.gateways[0].pop_all()
        if items:
            fn_ids = np.asarray([self._fn_ids[it.fn] for it in items],
                                np.int32)
            self.key, sub = jax.random.split(self.key)
            tier_idx = self.control.route_tiers(sub, fn_ids)
            now = time.perf_counter()
            ages = np.asarray([now - it.t_submit for it in items], np.float32)
            lat, valid = self._latency_windows()
            self.key, hk = jax.random.split(self.key)
            hedge = self.control.hedge(hk, ages, fn_ids, lat, valid)
            for it, tj, hedge_it in zip(items, tier_idx, hedge):
                j = int(tj)
                if bool(hedge_it):
                    # backup request on another tier (straggler hedge);
                    # only the winning arm's latency feeds the windows.
                    # The twin is stamped before the primary crosses any
                    # link, so it does not inherit the primary's hop cost.
                    bj = 0 if j == last else last
                    twin = Request(rid=it.req.rid, tokens=it.req.tokens,
                                   max_new=it.req.max_new,
                                   arrival_s=it.req.arrival_s)
                    pair = _HedgePair(fn=it.fn)
                    it.pair = pair
                    twin_item = _Queued(it.fn, twin, it.t_submit,
                                        tick_no=self._tick_no,
                                        hedge=True, pair=pair)
                    # the twin travels from the ingress gateway to its
                    # backup tier, paying the same links a routed request
                    # would (no crossing counters: it is duplicate work,
                    # not demand) — else the twin-vs-primary win
                    # comparison is biased toward the free-riding twin
                    for l in range(bj):
                        self._cross_link(twin_item, l)
                    twins.append((bj, twin_item))
                    pairs.append(pair)
                    hedged += 1
                for l in range(j):
                    self._cross_link(it, l)
                self.gateways[j].push(it, force=True)

        # This tick's work: every tier's gateway contents + hedge twins.
        pending: Dict[Tuple[int, str], List[_Queued]] = {}
        for ti, gw in enumerate(self.gateways):
            for it in gw.pop_all():
                pending.setdefault((ti, it.fn), []).append(it)
        for bj, it in twins:
            pending.setdefault((bj, it.fn), []).append(it)

        # KPA scrape: every (tier, fn) observes its assigned concurrency
        # (including zeros — that is what ages idle functions to zero).
        for ti, tier in enumerate(self.tiers):
            for fn, asc in tier.autoscalers.items():
                asc.observe(self._clock, float(len(pending.get((ti, fn), []))))
                asc.desired(self._clock)

        def dispatch(ti: int, fn: str, batch: List[_Queued]) -> None:
            nonlocal waves
            tier = self.tiers[ti]
            record = [it.pair is None for it in batch]
            results = tier.serve_batch(
                fn, [(it.req, it.t_submit) for it in batch], record=record)
            waves += 1
            for it, (_, lat) in zip(batch, results):
                if it.pair is not None:
                    it.pair.note(it, tier, lat)
                if not it.hedge:
                    served[tier.name] += 1

        def capped() -> bool:
            return (self.max_waves_per_tick is not None
                    and waves >= self.max_waves_per_tick)

        # Drain in waves: each wave packs up to the autoscaler-admitted
        # concurrency into one batched serve (shared prefill + decode_all).
        while any(pending.values()) and not capped():
            progress = False
            for (ti, fn), lst in pending.items():
                if not lst or capped():
                    continue
                tier = self.tiers[ti]
                budget = min(tier.free_slots(fn), tier.capacity(fn))
                if budget <= 0:
                    continue
                batch, pending[(ti, fn)] = lst[:budget], lst[budget:]
                dispatch(ti, fn, batch)
                progress = True
            if not progress and self.topology.waterfall:
                # Waterfall: a tier with no admitted capacity (e.g. scaled
                # to zero with scale-up disabled) spills its pending load
                # over the link to the next tier's work queue.
                for (ti, fn), lst in list(pending.items()):
                    tier = self.tiers[ti]
                    if (lst and ti < last
                            and min(tier.free_slots(fn),
                                    tier.capacity(fn)) <= 0):
                        for it in lst:
                            self._cross_link(it, ti)
                        pending.setdefault((ti + 1, fn), []).extend(lst)
                        pending[(ti, fn)] = []
                        spilled += len(lst)
                        progress = True
            if not progress:
                # Scale-from-zero floor: a queued request implies >= 1
                # desired replica next scrape; don't deadlock on degenerate
                # autoscaling bounds in the meantime.
                for (ti, fn), lst in pending.items():
                    if lst and self.tiers[ti].free_slots(fn) > 0:
                        dispatch(ti, fn, [lst.pop(0)])
                        progress = True
                        break
                if not progress:
                    raise RuntimeError("scheduler wedged: pending work but "
                                       "no free slot on any tier")

        # Wave budget exhausted: unserved primaries whose hedge twin
        # already completed adopt the twin's result (served once, by the
        # twin — never requeued and served a second time); the rest go
        # back to *their tier's* gateway, keeping their submit time and
        # tick stamp so the next scrape sees their queue age at the
        # boundary they actually wait at.  Unserved hedge twins are
        # dropped.
        adopted = 0
        requeue: Dict[int, List[_Queued]] = {}
        for (ti, fn), lst in pending.items():
            for it in lst:
                if it.hedge:
                    continue
                pair = it.pair
                if pair is not None and pair.twin_lat is not None:
                    it.req.output = pair.twin_req.output
                    it.req.t_first = pair.twin_req.t_first
                    it.req.t_done = pair.twin_req.t_done
                    pair.twin_tier.metrics.record_latency(it.fn,
                                                          pair.twin_lat)
                    served[pair.twin_tier.name] += 1
                    adopted += 1
                    continue
                it.pair = None       # a requeued primary records normally
                requeue.setdefault(ti, []).append(it)
        for ti, lst in requeue.items():
            for it in sorted(lst, key=lambda it: it.t_submit):
                if not self.gateways[ti].push(it):
                    # the tier's bounded backlog is full: the request is
                    # dropped for good (queue-proxy 503) and says so
                    it.req.failed = True
                    self._reject(ti, it.fn)

        # Resolve hedge pairs: only the winning arm's latency feeds the
        # controller windows, so a slow loser cannot bias R_t.
        won = adopted
        for pair in pairs:
            if pair.primary_lat is None:
                continue         # primary requeued or adopted; handled above
            if pair.twin_lat is not None and pair.twin_lat < pair.primary_lat:
                pair.twin_tier.metrics.record_latency(pair.fn, pair.twin_lat)
                won += 1
            else:
                pair.primary_tier.metrics.record_latency(pair.fn,
                                                         pair.primary_lat)
        if hedged:
            self.metrics.inc("hedges_fired", hedged)
        if won:
            self.metrics.inc("hedges_won", won)

        # Per-tick rejection count, like every sibling field (submit-time
        # rejections since the last tick land in this tick's record).
        rejected_total = sum(g.rejected for g in self.gateways)
        rejected_tick = rejected_total - self._rejected_seen
        self._rejected_seen = rejected_total
        rec = {"R": float(R.mean()) if len(R) else 0.0,
               "edge": served[self.tiers[0].name],
               "cloud": served[self.tiers[-1].name],
               "tiers": dict(served),
               "hedged": hedged, "hedges_won": won,
               "spilled": spilled, "waves": waves,
               "backlog": {t.name: len(g)
                           for t, g in zip(self.tiers, self.gateways)},
               "rejected": rejected_tick,
               "replicas": {t.name: {fn: t.replicas(fn)
                                     for fn in t.autoscalers}
                            for t in self.tiers}}
        self.log.append(rec)
        return rec
