"""The live N-tier continuum runtime.

This is the live (non-simulated) integration of every paper component:

    EdgeCloudContinuum (over a Topology chain, ingress at tier 0)
      ├── tier 0..N-1:  Endpoint pool (slots/model) + MetricsRegistry
      │                 + per-function Autoscaler (Knative-KPA concurrency)
      ├── ReplicationController  (deepest-tier spec -> shallower tiers,
      │                           selective merge)
      ├── ControlLoop + Policy   (Eqs (1)-(4) / static / net-aware / hedged
      │                           — one controller boundary per adjacent
      │                           tier pair, the same loop the simulator
      │                           drives)
      └── Router                 (vectorized categorical assignment of the
                                  queued batch over the tier distribution)

Requests enter at the ingress gateway (``submit``); each scheduler tick
runs one scrape-and-update cycle through the shared
:class:`repro.core.policy.ControlLoop` (per-tier latency windows +
in-flight queue ages + demand RPS), assigns the queued batch over the
tiers by the composed R_t distribution, and drains it in
autoscaler-budgeted *waves*: every wave packs up to a tier's admitted
concurrency into one ``Endpoint`` prefill + a shared ``decode_all``
stream, so co-scheduled requests advance together (continuous batching).
With ``topology.waterfall`` on, a tier with no admitted capacity spills
its pending load to the next tier down the chain instead of wedging.
Completed latencies feed the per-tier metrics that drive the next
controller update — the same closed loop as the paper's Knative Edge, at
batch granularity.

The historical two-tier constructor (``edge=..., cloud=...``) builds a
2-tier :class:`~repro.core.topology.Topology` via :meth:`Topology.pair`;
``edge``/``cloud`` remain as attribute aliases for the ingress/deepest
tiers.  Everything model-related goes through ``serving.engine.Endpoint``;
tier capacities are expressed in concurrent slots, so the same runtime
works with real TPU meshes (slots = per-pod batch) or the CPU tests
(slots=4).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import offload
from repro.core.autoscaler import Autoscaler
from repro.core.metrics import MetricsRegistry
from repro.core.policy import ControlLoop, Policy, PolicySpec
from repro.core.replication import (AutoscalingPolicy, FunctionSpec,
                                    ReplicationController)
from repro.core.topology import TierSpec, Topology
from repro.models.common import ModelConfig
from repro.serving.engine import Endpoint, Request


@dataclasses.dataclass
class TierConfig:
    """Legacy two-tier tier shape (sugar for a named
    :class:`~repro.core.topology.TierSpec` via ``Topology.pair``)."""
    slots: int = 4
    max_len: int = 256
    # synthetic per-request overhead (edge->cloud WAN RTT), seconds
    extra_latency_s: float = 0.0
    # default KPA bounds for functions deployed without an explicit policy
    autoscaling: Optional[AutoscalingPolicy] = None
    stable_window_s: float = 60.0
    panic_window_s: float = 6.0


@dataclasses.dataclass
class _Queued:
    """One gateway queue entry (+ hedge bookkeeping)."""
    fn: str
    req: Request
    t_submit: float
    tick_no: int = 0
    hedge: bool = False
    pair: Optional["_HedgePair"] = None


@dataclasses.dataclass
class _HedgePair:
    """Links a primary request to its hedge twin so only the winning
    arm's latency feeds the controller."""
    fn: str
    primary_lat: Optional[float] = None
    primary_tier: Optional["Tier"] = None
    twin_lat: Optional[float] = None
    twin_tier: Optional["Tier"] = None

    def note(self, item: "_Queued", tier: "Tier", lat: float) -> None:
        if item.hedge:
            self.twin_lat, self.twin_tier = lat, tier
        else:
            self.primary_lat, self.primary_tier = lat, tier


class Tier:
    """One serving location: endpoints by function name + metrics +
    per-function KPA autoscalers.

    ``cfg`` may be a legacy :class:`TierConfig` or an N-tier
    :class:`~repro.core.topology.TierSpec` — both carry the same serving
    fields."""

    def __init__(self, name: str, cfg):
        self.name = name
        self.cfg = cfg
        self.endpoints: Dict[str, Endpoint] = {}
        self.autoscalers: Dict[str, Autoscaler] = {}
        self.metrics = MetricsRegistry([])

    def deploy(self, fn_name: str, model_cfg: ModelConfig, params,
               autoscaling: Optional[AutoscalingPolicy] = None) -> None:
        self.endpoints[fn_name] = Endpoint(
            model_cfg, params, slots=self.cfg.slots, max_len=self.cfg.max_len)
        self.metrics.register(fn_name)
        # A TierSpec that declares its own KPA bounds governs its whole
        # pool (e.g. an intermediate tier pinned to zero with max_scale=0).
        # Legacy TierConfig keeps its documented fallback semantics: the
        # function's spec wins, the tier's bounds apply only when the
        # function has none.
        if isinstance(self.cfg, TierSpec) and self.cfg.autoscaling is not None:
            policy = self.cfg.autoscaling
        else:
            policy = autoscaling or self.cfg.autoscaling or AutoscalingPolicy()
        self.autoscalers[fn_name] = Autoscaler(
            policy,
            stable_window_s=self.cfg.stable_window_s,
            panic_window_s=self.cfg.panic_window_s)

    # -- capacity ----------------------------------------------------------
    def free_slots(self, fn_name: str) -> int:
        ep = self.endpoints[fn_name]
        return ep.slots - ep.active

    def capacity(self, fn_name: str) -> int:
        """Admitted concurrency right now: replicas x target concurrency,
        bounded by the KV-cache pool. 0 when scaled to zero."""
        asc = self.autoscalers[fn_name]
        want = int(asc.replicas * max(asc.policy.target_concurrency, 1.0))
        return min(self.endpoints[fn_name].slots, want)

    def replicas(self, fn_name: str) -> int:
        return self.autoscalers[fn_name].replicas

    # -- serving -----------------------------------------------------------
    def serve_batch(self, fn_name: str,
                    items: List[Tuple[Request, float]],
                    record: Optional[List[bool]] = None
                    ) -> List[Tuple[np.ndarray, float]]:
        """Serve a wave of requests together on one endpoint.

        All prompts share packed prefill calls and one ``decode_all``
        stream; each request's latency is measured from its submit
        timestamp to the decode step that finished it. ``record`` masks
        which latencies feed this tier's metrics (hedged arms defer to the
        pair winner). The caller is responsible for sizing waves within
        ``free_slots`` — admission past the pool raises instead of
        silently corrupting a live slot's KV cache (the old ``slot = 0``
        fallback).
        """
        ep = self.endpoints[fn_name]
        claimed: List[Tuple[Request, float, int]] = []
        for req, t_submit in items:
            slot = ep.try_claim()
            if slot is None:
                for _, _, s in claimed:
                    ep.release(s)
                raise RuntimeError(
                    f"{self.name}/{fn_name}: wave of {len(items)} exceeds "
                    f"free slots — scheduler admitted past capacity")
            claimed.append((req, t_submit, slot))

        try:
            firsts = ep.prefill_batch(
                {slot: req.tokens for req, _, slot in claimed})
            now = time.perf_counter()
            outs: Dict[int, List[int]] = {}
            need: Dict[int, int] = {}
            done_at: Dict[int, float] = {}
            active: Dict[int, int] = {}
            for req, _, slot in claimed:
                outs[slot] = [firsts[slot]]
                need[slot] = max(req.max_new, 1)
                done_at[slot] = now
                req.t_first = now
                if need[slot] > 1:
                    active[slot] = firsts[slot]
            while active:
                nxt = ep.decode_all(active)
                now = time.perf_counter()
                for s, tok in nxt.items():
                    outs[s].append(tok)
                    if len(outs[s]) >= need[s]:
                        del active[s]
                        done_at[s] = now
                    else:
                        active[s] = tok
        except Exception:
            for _, _, s in claimed:
                ep.release(s)
            raise

        results: List[Tuple[np.ndarray, float]] = []
        for i, (req, t_submit, slot) in enumerate(claimed):
            lat = done_at[slot] - t_submit + self.cfg.extra_latency_s
            if record is None or record[i]:
                self.metrics.record_latency(fn_name, lat)
            req.output = np.asarray(outs[slot], np.int32)
            req.t_done = done_at[slot]
            ep.release(slot)
            results.append((req.output, lat))
        return results

    def serve_one(self, fn_name: str, req: Request,
                  now_s: float = 0.0) -> Tuple[np.ndarray, float]:
        """Serial single-request path (the pre-batching baseline)."""
        del now_s
        [(out, lat)] = self.serve_batch(fn_name, [(req, time.perf_counter())])
        return out, lat


class EdgeCloudContinuum:
    """The full platform: replication + policy-driven offloading across an
    N-tier topology, with a batched wave scheduler."""

    def __init__(self, edge=None, cloud=None,
                 policy: PolicySpec = "auto",
                 offload_cfg: Optional[offload.OffloadConfig] = None,
                 window: int = 64, seed: int = 0,
                 control_interval_s: float = 1.0,
                 max_waves_per_tick: Optional[int] = None,
                 topology: Optional[Topology] = None):
        if topology is None:
            if edge is None or cloud is None:
                raise ValueError(
                    "pass either topology=... or the 2-tier edge=/cloud= pair")
            topology = Topology.pair(edge, cloud)
        self.topology = topology
        self.tiers: List[Tier] = [Tier(spec.name, spec)
                                  for spec in topology.tiers]
        self.offload_cfg = offload_cfg or offload.OffloadConfig()
        self.policy = Policy.parse(policy, offload_cfg=self.offload_cfg)
        self.window = window
        self.control_interval_s = control_interval_s
        self.replicator = ReplicationController()
        self.cloud_specs: Dict[str, FunctionSpec] = {}
        self.fn_names: List[str] = []
        self.control: Optional[ControlLoop] = None
        self.key = jax.random.PRNGKey(seed)
        self.queue: Deque[_Queued] = deque()
        self._arrivals: Dict[str, int] = {}
        # Platform-level counters (hedging outcomes etc.).
        self.metrics = MetricsRegistry([])
        # None = drain the queue every tick; an int caps the batched waves
        # per tick, so overload leaves a *backlog* whose in-flight ages the
        # next scrape mixes into Eq (1) (the simulator's onset signal).
        self.max_waves_per_tick = max_waves_per_tick
        self.log: List[Dict] = []
        self._clock = 0.0          # logical control-plane time (scrapes)
        self._tick_no = 0

    # Ingress / deepest tier aliases (the historical two-tier attributes).
    @property
    def edge(self) -> Tier:
        return self.tiers[0]

    @property
    def cloud(self) -> Tier:
        return self.tiers[-1]

    # -- deployment (paper §3.3.1) ------------------------------------------
    def deploy(self, spec: FunctionSpec, model_cfg: ModelConfig, params) -> None:
        """Deploy to the deepest tier; replication mirrors the spec to
        every shallower tier of the chain."""
        self.cloud.deploy(spec.name, model_cfg, params, spec.autoscaling)
        self.cloud_specs[spec.name] = spec
        changed = self.replicator.reconcile(self.cloud_specs)
        if changed.get(spec.name, True):
            for tier in self.tiers[:-1]:
                tier.deploy(spec.name, model_cfg, params, spec.autoscaling)
        if spec.name not in self.fn_names:
            self.fn_names.append(spec.name)
            self._arrivals[spec.name] = 0
            self.control = ControlLoop(
                self.policy, len(self.fn_names), window=self.window,
                control_interval_s=self.control_interval_s,
                num_tiers=len(self.tiers))

    # -- request path (paper §3.3.2) ------------------------------------------
    def submit(self, fn_name: str, req: Request) -> None:
        req.arrival_s = time.perf_counter()
        self.queue.append(_Queued(fn_name, req, req.arrival_s,
                                  tick_no=self._tick_no))
        self._arrivals[fn_name] = self._arrivals.get(fn_name, 0) + 1

    def controller_update(self) -> np.ndarray:
        """One scrape-and-update cycle through the shared ControlLoop
        (every boundary of the chain); returns the ingress boundary's R_t
        percentages."""
        lats, valids = [], []
        for tier in self.tiers[:-1] or self.tiers[:1]:
            lat, valid = tier.metrics.latency_windows(self.window)
            lats.append(lat)
            valids.append(valid)
        now = time.perf_counter()
        ages: List[List[float]] = [[] for _ in self.fn_names]
        for item in self.queue:
            # Only true *backlog* counts as in-flight age: requests that
            # survived a previous scheduler round. Fresh arrivals have
            # waited ~0 s — mixing those into X_l(t) would drag p50 toward
            # zero and fire Eq (1) spuriously. (The simulator's queue only
            # ever holds requests the previous rounds could not place, so
            # its mixing is backlog-only by construction.)
            if item.tick_no < self._tick_no:
                ages[self.fn_names.index(item.fn)].append(now - item.t_submit)
        # The gateway backlog lives at the ingress tier; deeper boundaries
        # see completions only.
        qages = [ages] + [None] * (len(lats) - 1)
        arrivals = [self._arrivals.get(fn, 0) for fn in self.fn_names]
        R_all = self.control.step_tiers(lats, valids, queue_ages=qages,
                                        arrivals=arrivals)
        for fn in self.fn_names:
            self._arrivals[fn] = 0
        return R_all[0]

    def _latency_windows(self):
        """(F, W) ingress-tier latency windows in deployment order."""
        return self.edge.metrics.latency_windows(self.window)

    # -- scheduler ------------------------------------------------------------
    def tick(self) -> Dict[str, float]:
        """One scheduler round: controller update, tier assignment, drain
        in waves (spilling down the chain when waterfall is on)."""
        R = self.controller_update()
        self._clock += self.control_interval_s
        self._tick_no += 1
        served: Dict[str, int] = {t.name: 0 for t in self.tiers}
        hedged = waves = spilled = 0
        pairs: List[_HedgePair] = []

        n = len(self.queue)
        items = [self.queue.popleft() for _ in range(n)]
        pending: Dict[Tuple[Tier, str], List[_Queued]] = {}
        if items:
            fn_ids = np.asarray([self.fn_names.index(it.fn) for it in items],
                                np.int32)
            self.key, sub = jax.random.split(self.key)
            tier_idx = self.control.route_tiers(sub, fn_ids)
            now = time.perf_counter()
            ages = np.asarray([now - it.t_submit for it in items], np.float32)
            lat, valid = self._latency_windows()
            self.key, hk = jax.random.split(self.key)
            hedge = self.control.hedge(hk, ages, fn_ids, lat, valid)
            for it, tj, hedge_it in zip(items, tier_idx, hedge):
                primary = self.tiers[int(tj)]
                pending.setdefault((primary, it.fn), []).append(it)
                if bool(hedge_it):
                    # backup request on another tier (straggler hedge);
                    # only the winning arm's latency feeds the windows.
                    backup = (self.tiers[0] if primary is self.tiers[-1]
                              else self.tiers[-1])
                    twin = Request(rid=it.req.rid, tokens=it.req.tokens,
                                   max_new=it.req.max_new,
                                   arrival_s=it.req.arrival_s)
                    pair = _HedgePair(fn=it.fn)
                    it.pair = pair
                    pending.setdefault((backup, it.fn), []).append(
                        _Queued(it.fn, twin, it.t_submit, hedge=True,
                                pair=pair))
                    pairs.append(pair)
                    hedged += 1

        # KPA scrape: every (tier, fn) observes its assigned concurrency
        # (including zeros — that is what ages idle functions to zero).
        for tier in self.tiers:
            for fn, asc in tier.autoscalers.items():
                asc.observe(self._clock, float(len(pending.get((tier, fn), []))))
                asc.desired(self._clock)

        def dispatch(tier: Tier, fn: str, batch: List[_Queued]) -> None:
            nonlocal waves
            record = [it.pair is None for it in batch]
            results = tier.serve_batch(
                fn, [(it.req, it.t_submit) for it in batch], record=record)
            waves += 1
            for it, (_, lat) in zip(batch, results):
                if it.pair is not None:
                    it.pair.note(it, tier, lat)
                if not it.hedge:
                    served[tier.name] += 1

        def capped() -> bool:
            return (self.max_waves_per_tick is not None
                    and waves >= self.max_waves_per_tick)

        # Drain in waves: each wave packs up to the autoscaler-admitted
        # concurrency into one batched serve (shared prefill + decode_all).
        while any(pending.values()) and not capped():
            progress = False
            for (tier, fn), lst in pending.items():
                if not lst or capped():
                    continue
                budget = min(tier.free_slots(fn), tier.capacity(fn))
                if budget <= 0:
                    continue
                batch, pending[(tier, fn)] = lst[:budget], lst[budget:]
                dispatch(tier, fn, batch)
                progress = True
            if not progress and self.topology.waterfall:
                # Waterfall: a tier with no admitted capacity (e.g. scaled
                # to zero with scale-up disabled) spills its pending load
                # to the next tier down the chain.
                for (tier, fn), lst in list(pending.items()):
                    ti = self.tiers.index(tier)
                    if (lst and ti < len(self.tiers) - 1
                            and min(tier.free_slots(fn),
                                    tier.capacity(fn)) <= 0):
                        nxt = self.tiers[ti + 1]
                        pending.setdefault((nxt, fn), []).extend(lst)
                        pending[(tier, fn)] = []
                        spilled += len(lst)
                        progress = True
            if not progress:
                # Scale-from-zero floor: a queued request implies >= 1
                # desired replica next scrape; don't deadlock on degenerate
                # autoscaling bounds in the meantime.
                for (tier, fn), lst in pending.items():
                    if lst and tier.free_slots(fn) > 0:
                        dispatch(tier, fn, [lst.pop(0)])
                        progress = True
                        break
                if not progress:
                    raise RuntimeError("scheduler wedged: pending work but "
                                       "no free slot on any tier")

        # Wave budget exhausted: unserved primaries go back to the gateway
        # (keeping their submit time and tick stamp, so the next scrape
        # sees their queue age); unserved hedge twins are just dropped.
        leftovers = [it for lst in pending.values() for it in lst
                     if not it.hedge]
        for it in sorted(leftovers, key=lambda it: it.t_submit):
            it.pair = None           # a requeued primary records normally
            self.queue.append(it)

        # Resolve hedge pairs: only the winning arm's latency feeds the
        # controller windows, so a slow loser cannot bias R_t.
        won = 0
        for pair in pairs:
            if pair.primary_lat is None:
                continue             # primary requeued; pair dissolved
            if pair.twin_lat is not None and pair.twin_lat < pair.primary_lat:
                pair.twin_tier.metrics.record_latency(pair.fn, pair.twin_lat)
                won += 1
            else:
                pair.primary_tier.metrics.record_latency(pair.fn,
                                                         pair.primary_lat)
        if hedged:
            self.metrics.inc("hedges_fired", hedged)
        if won:
            self.metrics.inc("hedges_won", won)

        rec = {"R": float(R.mean()) if len(R) else 0.0,
               "edge": served[self.tiers[0].name],
               "cloud": served[self.tiers[-1].name],
               "tiers": dict(served),
               "hedged": hedged, "hedges_won": won,
               "spilled": spilled, "waves": waves,
               "replicas": {t.name: {fn: t.replicas(fn)
                                     for fn in t.autoscalers}
                            for t in self.tiers}}
        self.log.append(rec)
        return rec
