"""Arrival traces: the workload half of ``repro.workloads``.

Every benchmark and test used to drive the continuum with its own ad-hoc
arrival loop (the simulator's inlined ramp, ``serving_bench``'s request
schedule, hand-rolled Poisson bursts).  This module is the one place
arrivals come from, in two interchangeable forms:

  * :class:`ArrivalProcess` — the *inline-draw* form: a rate function
    ``rate(t)`` the consumer samples its own inter-arrival exponentials
    from, on its own RNG.  :class:`RampedPoisson` reproduces the
    historical ``SimConfig`` rate parameters **bit-identically** (same
    draw, same interleave with service-time and routing draws), so the
    committed simulator goldens are unchanged when expressed as traces;
    :class:`StationaryPoisson` is its constant-rate special case.
  * :class:`Trace` — the *materialized* form: per-request arrival time,
    function index, prompt length, decode length, and payload bytes, as
    parallel numpy columns.  Deterministic seeded generators cover the
    regimes production serverless traffic actually shows — stationary
    Poisson, bursty MMPP on/off, diurnal sinusoid — with optional
    Zipf-skewed function popularity, and CSV export/replay makes any
    trace a committable artifact.

Both the simulator (``ContinuumSimulator(..., trace=...)``) and the live
runtime (``Continuum.from_topology(..., trace=...)``) accept either form
beside their existing rate arguments.
"""

from __future__ import annotations

import dataclasses
import io
from typing import List, Optional, Sequence, Tuple

import numpy as np

_CSV_HEADER = "t,fn,prompt_len,max_new,payload_bytes"


class ArrivalProcess:
    """Inline-draw arrival form: a deterministic rate function.

    The consumer owns the RNG and draws one inter-arrival exponential per
    request (``rng.exponential(1 / proc.rate(t))``), exactly as the
    historical rate-parameter code paths did — which is what keeps the
    committed goldens bit-identical when the default arrivals are
    expressed through this interface.
    """

    def rate(self, t: float) -> float:
        raise NotImplementedError

    def __repr__(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True, repr=False)
class RampedPoisson(ArrivalProcess):
    """The paper apparatus' open-loop generator: ``low_rps`` until
    ``ramp_start_s``, linear ramp to ``high_rps`` by ``ramp_end_s`` —
    the simulator's historical default trace, consolidated here."""

    low_rps: float = 2.0
    high_rps: float = 16.0
    ramp_start_s: float = 60.0
    ramp_end_s: float = 240.0

    def rate(self, t: float) -> float:
        if t < self.ramp_start_s:
            return self.low_rps
        if t >= self.ramp_end_s:
            return self.high_rps
        frac = (t - self.ramp_start_s) / (self.ramp_end_s - self.ramp_start_s)
        return self.low_rps + frac * (self.high_rps - self.low_rps)

    def __repr__(self) -> str:
        return (f"RampedPoisson({self.low_rps}->{self.high_rps} rps over "
                f"[{self.ramp_start_s}, {self.ramp_end_s}]s)")


@dataclasses.dataclass(frozen=True, repr=False)
class StationaryPoisson(ArrivalProcess):
    """Constant-rate Poisson arrivals (the stationary special case)."""

    rps: float = 4.0

    def rate(self, t: float) -> float:
        return self.rps

    def __repr__(self) -> str:
        return f"StationaryPoisson({self.rps} rps)"


@dataclasses.dataclass
class Trace:
    """A materialized arrival trace: one row per request.

    Parallel columns (all length R): ``t`` — arrival time in seconds,
    nondecreasing; ``fn`` — index into ``fn_names``; ``prompt_len`` /
    ``max_new`` — request size in tokens; ``payload_bytes`` — the bytes a
    down-chain crossing serializes over the link.  ``duration_s`` bounds
    the trace (arrivals past it are invalid).
    """

    t: np.ndarray
    fn: np.ndarray
    prompt_len: np.ndarray
    max_new: np.ndarray
    payload_bytes: np.ndarray
    fn_names: Tuple[str, ...] = ("fn",)
    duration_s: float = 0.0

    def __post_init__(self):
        self.t = np.asarray(self.t, np.float64)
        self.fn = np.asarray(self.fn, np.int32)
        self.prompt_len = np.asarray(self.prompt_len, np.int32)
        self.max_new = np.asarray(self.max_new, np.int32)
        self.payload_bytes = np.asarray(self.payload_bytes, np.float64)
        n = len(self.t)
        for name in ("fn", "prompt_len", "max_new", "payload_bytes"):
            if len(getattr(self, name)) != n:
                raise ValueError(
                    f"trace column {name!r} has {len(getattr(self, name))} "
                    f"rows, expected {n}")
        if n and np.any(np.diff(self.t) < 0):
            raise ValueError("trace arrival times must be nondecreasing")
        if n and (self.fn.min() < 0 or self.fn.max() >= len(self.fn_names)):
            raise ValueError("trace fn index out of range of fn_names")
        if not self.duration_s:
            self.duration_s = float(self.t[-1]) if n else 0.0

    def __len__(self) -> int:
        return len(self.t)

    def __repr__(self) -> str:
        return (f"Trace({len(self)} requests over {self.duration_s:.1f}s, "
                f"fns={list(self.fn_names)})")

    # -- consumption -------------------------------------------------------
    def window(self, t0: float, t1: float) -> np.ndarray:
        """Row indices of arrivals in ``[t0, t1)`` — the per-tick form the
        live scheduler consumes."""
        return np.arange(np.searchsorted(self.t, t0, side="left"),
                         np.searchsorted(self.t, t1, side="left"))

    def per_tick(self, interval_s: float) -> np.ndarray:
        """(T, F) arrival counts per control interval per function."""
        T = max(int(np.ceil(self.duration_s / interval_s)), 1)
        out = np.zeros((T, len(self.fn_names)), np.int64)
        ticks = np.minimum((self.t / interval_s).astype(np.int64), T - 1)
        np.add.at(out, (ticks, self.fn), 1)
        return out

    def mean_rps(self) -> float:
        return len(self) / self.duration_s if self.duration_s else 0.0

    # -- CSV replay/export -------------------------------------------------
    def to_csv(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(_CSV_HEADER + "\n")
            for i in range(len(self)):
                f.write(f"{self.t[i]:.6f},{self.fn_names[self.fn[i]]},"
                        f"{self.prompt_len[i]},{self.max_new[i]},"
                        f"{self.payload_bytes[i]:.1f}\n")

    @classmethod
    def from_csv(cls, path_or_file) -> "Trace":
        f = (open(path_or_file) if isinstance(path_or_file, str)
             else path_or_file)
        try:
            header = f.readline().strip()
            if header != _CSV_HEADER:
                raise ValueError(
                    f"bad trace CSV header {header!r}, "
                    f"expected {_CSV_HEADER!r}")
            t, names, plen, mnew, pay = [], [], [], [], []
            for line in f:
                line = line.strip()
                if not line:
                    continue
                a, b, c, d, e = line.split(",")
                t.append(float(a))
                names.append(b)
                plen.append(int(c))
                mnew.append(int(d))
                pay.append(float(e))
        finally:
            if isinstance(path_or_file, str):
                f.close()
        fn_names = tuple(dict.fromkeys(names))   # first-seen order
        idx = {n: i for i, n in enumerate(fn_names)}
        return cls(t=np.asarray(t), fn=np.asarray([idx[n] for n in names]),
                   prompt_len=np.asarray(plen), max_new=np.asarray(mnew),
                   payload_bytes=np.asarray(pay),
                   fn_names=fn_names or ("fn",))

    def round_trip(self) -> "Trace":
        """CSV-roundtrip self (tests pin replay fidelity with this)."""
        buf = io.StringIO()
        buf.write(_CSV_HEADER + "\n")
        for i in range(len(self)):
            buf.write(f"{self.t[i]:.6f},{self.fn_names[self.fn[i]]},"
                      f"{self.prompt_len[i]},{self.max_new[i]},"
                      f"{self.payload_bytes[i]:.1f}\n")
        buf.seek(0)
        return Trace.from_csv(buf)

    # -- generators --------------------------------------------------------
    @staticmethod
    def _fill_requests(rng: np.random.Generator, times: np.ndarray,
                       fn_names: Sequence[str], popularity: str,
                       zipf_s: float, prompt_len: int, max_new: int,
                       payload_bytes: float, duration_s: float) -> "Trace":
        """Shared tail of every generator: draw per-request function ids
        (uniform or Zipf-skewed) and attach the size columns."""
        n, F = len(times), len(fn_names)
        if popularity == "zipf":
            w = 1.0 / np.arange(1, F + 1, dtype=np.float64) ** zipf_s
            w /= w.sum()
        elif popularity == "uniform":
            w = np.full(F, 1.0 / F)
        else:
            raise ValueError(
                f"popularity must be 'uniform' or 'zipf', got {popularity!r}")
        fn = rng.choice(F, size=n, p=w) if F > 1 else np.zeros(n, np.int32)
        return Trace(t=times, fn=fn,
                     prompt_len=np.full(n, prompt_len),
                     max_new=np.full(n, max_new),
                     payload_bytes=np.full(n, float(payload_bytes)),
                     fn_names=tuple(fn_names), duration_s=duration_s)

    @classmethod
    def poisson(cls, rps: float, duration_s: float,
                fn_names: Sequence[str] = ("fn",), seed: int = 0,
                popularity: str = "uniform", zipf_s: float = 1.1,
                prompt_len: int = 6, max_new: int = 4,
                payload_bytes: float = 2.0e5) -> "Trace":
        """Stationary Poisson arrivals at ``rps`` for ``duration_s``."""
        rng = np.random.default_rng(seed)
        # one draw per arrival, in arrival order (deterministic length)
        times, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / rps)
            if t >= duration_s:
                break
            times.append(t)
        return cls._fill_requests(rng, np.asarray(times), fn_names,
                                  popularity, zipf_s, prompt_len, max_new,
                                  payload_bytes, duration_s)

    @classmethod
    def bursty(cls, base_rps: float, burst_rps: float, duration_s: float,
               mean_on_s: float = 10.0, mean_off_s: float = 30.0,
               fn_names: Sequence[str] = ("fn",), seed: int = 0,
               popularity: str = "uniform", zipf_s: float = 1.1,
               prompt_len: int = 6, max_new: int = 4,
               payload_bytes: float = 2.0e5) -> "Trace":
        """Bursty on/off arrivals (a 2-state MMPP): ``base_rps`` in the
        off state, ``burst_rps`` during exponentially-distributed on
        periods — the flash-crowd regime."""
        rng = np.random.default_rng(seed)
        times: List[float] = []
        t, on = 0.0, False
        phase_end = rng.exponential(mean_off_s)
        while t < duration_s:
            rate = burst_rps if on else base_rps
            t_next = t + rng.exponential(1.0 / rate)
            if t_next >= phase_end:
                # no arrival this phase remainder: flip state and carry on
                t = phase_end
                on = not on
                phase_end = t + rng.exponential(
                    mean_on_s if on else mean_off_s)
                continue
            t = t_next
            if t < duration_s:
                times.append(t)
        return cls._fill_requests(rng, np.asarray(times), fn_names,
                                  popularity, zipf_s, prompt_len, max_new,
                                  payload_bytes, duration_s)

    @classmethod
    def diurnal(cls, mean_rps: float, duration_s: float,
                period_s: float = 86400.0, amplitude: float = 0.8,
                peak_at_s: float = 0.0,
                fn_names: Sequence[str] = ("fn",), seed: int = 0,
                popularity: str = "uniform", zipf_s: float = 1.1,
                prompt_len: int = 6, max_new: int = 4,
                payload_bytes: float = 2.0e5) -> "Trace":
        """Diurnal sinusoid arrivals via Poisson thinning:
        ``rate(t) = mean * (1 + amplitude * cos(2pi (t-peak)/period))``."""
        if not 0.0 <= amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0, 1], got {amplitude}")
        rng = np.random.default_rng(seed)
        peak = mean_rps * (1.0 + amplitude)
        times, t = [], 0.0
        while True:
            t += rng.exponential(1.0 / peak)
            if t >= duration_s:
                break
            rate = mean_rps * (1.0 + amplitude * np.cos(
                2.0 * np.pi * (t - peak_at_s) / period_s))
            if rng.uniform() * peak < rate:     # thinning acceptance
                times.append(t)
        return cls._fill_requests(rng, np.asarray(times), fn_names,
                                  popularity, zipf_s, prompt_len, max_new,
                                  payload_bytes, duration_s)


def request_rounds(rounds: int, seed: int, max_new: int = 6,
                   warmup_rounds: int = 3, warmup_burst: int = 2,
                   burst: int = 8, prompt_len: int = 6, vocab: int = 128
                   ) -> List[Tuple[int, np.ndarray, int]]:
    """The serving benches' shared tick-indexed request schedule:
    ``(round, tokens, max_new)`` triples — ``warmup_burst`` requests per
    round for the first ``warmup_rounds``, ``burst`` after.

    Defaults reproduce the historical ``serving_bench._workload`` draws
    bit-identically (same RNG, same order), so the committed serving
    goldens are unchanged by the consolidation.
    """
    rng = np.random.default_rng(seed)
    sched = []
    for rnd in range(rounds):
        for _ in range(warmup_burst if rnd < warmup_rounds else burst):
            sched.append((rnd, rng.integers(0, vocab, prompt_len)
                          .astype(np.int32), max_new))
    return sched


def trace_requests(trace: Trace, seed: int = 0, vocab: int = 128,
                   rng: Optional[np.random.Generator] = None
                   ) -> List[np.ndarray]:
    """Materialize per-request prompt tokens for a trace (the live
    runtime serves real tokens; the trace only carries lengths)."""
    rng = rng or np.random.default_rng(seed)
    return [rng.integers(0, vocab, int(n)).astype(np.int32)
            for n in trace.prompt_len]
