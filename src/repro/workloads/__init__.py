"""``repro.workloads`` — trace-driven workload harness + fault injection.

Two halves, consumed identically by the simulator
(:class:`repro.core.simulator.ContinuumSimulator`) and the live runtime
(:class:`repro.serving.tiers.EdgeCloudContinuum` /
:class:`repro.platform.Continuum`):

  * :mod:`repro.workloads.trace`  — arrival traces: a materialized
    :class:`~repro.workloads.trace.Trace` schema (per-request arrival
    time, function, size, payload bytes) with deterministic seeded
    generators (stationary Poisson, bursty MMPP on/off, diurnal sinusoid,
    Zipf-skewed function popularity) and CSV replay/export, plus the
    inline-draw :class:`~repro.workloads.trace.ArrivalProcess` form that
    reproduces the historical rate-parameter arrivals bit-identically.
  * :mod:`repro.workloads.faults` — a :class:`~repro.workloads.faults.\
FaultSchedule` of timed :class:`~repro.workloads.faults.FaultEvent`\\ s
    over a :class:`~repro.core.topology.Topology` (link degradation and
    partition, tier crash and recovery), applied mid-run by both
    deployments through a mutable :class:`~repro.workloads.faults.\
LinkState` overlay.
"""

from repro.workloads.faults import (FaultEvent, FaultSchedule, LinkState,
                                    cloud_partition, edge_brownout,
                                    tier_outage)
from repro.workloads.trace import (ArrivalProcess, RampedPoisson,
                                   StationaryPoisson, Trace,
                                   request_rounds)

__all__ = [
    "ArrivalProcess", "RampedPoisson", "StationaryPoisson", "Trace",
    "request_rounds",
    "FaultEvent", "FaultSchedule", "LinkState",
    "edge_brownout", "cloud_partition", "tier_outage",
]
