"""Fault injection: the chaos half of ``repro.workloads``.

A :class:`FaultSchedule` is an ordered list of timed :class:`FaultEvent`\\ s
over a :class:`~repro.core.topology.Topology` — link degradation
(bandwidth/RTT multipliers), link partition, tier crash (slots and
in-flight state lost), and recovery.  Both deployments of the platform
apply the same schedule mid-run: the simulator as ``_FAULT`` events in
its heap, the live scheduler at the top of each ``tick()`` against its
logical clock.

The frozen :class:`~repro.core.topology.LinkSpec`\\ s are never mutated;
fault state lives in a mutable :class:`LinkState` overlay per link
(``bw_mult`` / ``rtt_mult`` / ``up``) that the runtimes consult for every
crossing, and that net-aware policies are re-capped from
(:meth:`repro.core.policy.AutoOffload.set_link_capacity`) so ``auto+net``
sees a browned-out link the moment it degrades.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

from repro.core.topology import LinkSpec

#: event kinds, and which target field they address
LINK_KINDS = ("degrade_link", "partition_link", "restore_link")
TIER_KINDS = ("crash_tier", "restore_tier")
KINDS = LINK_KINDS + TIER_KINDS


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One timed fault on the deployment clock (simulator seconds /
    live logical scrape time).

    ``target`` is a link index (``degrade_link`` / ``partition_link`` /
    ``restore_link`` — link b joins tier b to tier b+1) or a tier index
    (``crash_tier`` / ``restore_tier``).  ``bw_mult`` / ``rtt_mult``
    apply to ``degrade_link`` only: effective bandwidth is
    ``spec.bandwidth_Bps * bw_mult``, effective RTT is
    ``spec.rtt_s * rtt_mult``.  ``restore_link`` clears both and any
    partition.
    """

    t: float
    kind: str
    target: int
    bw_mult: float = 1.0
    rtt_mult: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"pick from {KINDS}")
        if self.t < 0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")
        if self.bw_mult <= 0 or self.rtt_mult <= 0:
            raise ValueError("bw_mult/rtt_mult must be > 0 "
                             "(use partition_link to sever a link)")


class FaultSchedule:
    """An ordered fault script, consumed once per run.

    Consumers call :meth:`due` with their current clock and apply the
    returned events in order; :meth:`reset` rewinds for a fresh run (the
    schedule itself is immutable).
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: e.t))
        self._next = 0

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def __repr__(self) -> str:
        kinds = [f"{e.kind}@{e.t:g}s" for e in self.events]
        return f"FaultSchedule({', '.join(kinds)})"

    def reset(self) -> None:
        self._next = 0

    def due(self, now: float) -> List[FaultEvent]:
        """Pop every event with ``t <= now`` (in time order)."""
        out = []
        while (self._next < len(self.events)
               and self.events[self._next].t <= now):
            out.append(self.events[self._next])
            self._next += 1
        return out

    @property
    def exhausted(self) -> bool:
        return self._next >= len(self.events)

    def validate(self, num_tiers: int) -> "FaultSchedule":
        """Check every target index against a topology's shape."""
        for e in self.events:
            hi = num_tiers - 1 if e.kind in LINK_KINDS else num_tiers
            if not 0 <= e.target < hi:
                what = "link" if e.kind in LINK_KINDS else "tier"
                raise ValueError(
                    f"{e.kind} targets {what} {e.target}, but the "
                    f"topology has {hi} {what}s")
        return self


class LinkState:
    """Mutable runtime overlay over one frozen :class:`LinkSpec`."""

    def __init__(self, spec: LinkSpec):
        self.spec = spec
        self.bw_mult = 1.0
        self.rtt_mult = 1.0
        self.up = True

    def _effective(self) -> LinkSpec:
        """The degraded link as a real :class:`LinkSpec`, so every cost
        query goes through the ONE canonical latency formula instead of
        a re-typed copy that could drift from it."""
        if self.bw_mult == 1.0 and self.rtt_mult == 1.0:
            return self.spec
        return dataclasses.replace(
            self.spec,
            rtt_s=self.spec.rtt_s * self.rtt_mult,
            bandwidth_Bps=self.spec.bandwidth_Bps * self.bw_mult)

    @property
    def bandwidth_Bps(self) -> float:
        return self._effective().bandwidth_Bps

    @property
    def rtt_s(self) -> float:
        return self._effective().rtt_s

    def latency_s(self, nbytes: float = 0.0) -> float:
        return self._effective().latency_s(nbytes)

    def effective_capacity(self) -> float:
        """Bytes/s a net-aware controller should cap against: the
        degraded bandwidth, or ~zero when partitioned (R_t caps to 0)."""
        return self.bandwidth_Bps if self.up else 1e-6

    def apply(self, ev: FaultEvent) -> None:
        if ev.kind == "degrade_link":
            self.bw_mult, self.rtt_mult = ev.bw_mult, ev.rtt_mult
        elif ev.kind == "partition_link":
            self.up = False
        elif ev.kind == "restore_link":
            self.bw_mult = self.rtt_mult = 1.0
            self.up = True
        else:
            raise ValueError(f"{ev.kind} is not a link fault")

    def __repr__(self) -> str:
        state = ("up" if self.bw_mult == self.rtt_mult == 1.0 else
                 f"degraded(bw x{self.bw_mult:g}, rtt x{self.rtt_mult:g})"
                 ) if self.up else "PARTITIONED"
        return f"LinkState({state})"


# -- named scenarios --------------------------------------------------------

def edge_brownout(t0: float, t1: float, link: int = 0,
                  bw_mult: float = 0.05, rtt_mult: float = 5.0
                  ) -> FaultSchedule:
    """Brownout of an edge link: heavy degradation over ``[t0, t1)``."""
    return FaultSchedule([
        FaultEvent(t0, "degrade_link", link, bw_mult=bw_mult,
                   rtt_mult=rtt_mult),
        FaultEvent(t1, "restore_link", link)])


def cloud_partition(t0: float, t1: float, link: int) -> FaultSchedule:
    """Full partition of the cloud-ward link over ``[t0, t1)``:
    nothing crosses, in-transit migrations abort back to source."""
    return FaultSchedule([FaultEvent(t0, "partition_link", link),
                          FaultEvent(t1, "restore_link", link)])


def tier_outage(t0: float, t1: float, tier: int) -> FaultSchedule:
    """Crash one tier over ``[t0, t1)``: slots and in-flight state are
    lost (resident requests replay via the replication path), recovery
    re-registers the tier's functions from the cloud specs."""
    return FaultSchedule([FaultEvent(t0, "crash_tier", tier),
                          FaultEvent(t1, "restore_tier", tier)])


def merge_schedules(*schedules: Optional[FaultSchedule]) -> FaultSchedule:
    """Compose scenario helpers into one time-ordered schedule."""
    events: List[FaultEvent] = []
    for s in schedules:
        if s is not None:
            events.extend(s.events)
    return FaultSchedule(events)
