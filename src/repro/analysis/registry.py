"""Registered single-source formulas for the parity-drift rule.

The sim<->live bit-identity contract rests on a handful of arithmetic
formulas having exactly ONE home that both deployments import — the page
extent, the link-crossing cost, the Eq-(1)/(3) controller maps, the
queue-age window mixing.  Re-implementing one of them (instead of
importing it) is how parity drifts: the copies agree today and diverge at
the next edit.

This module is the one place such formulas opt in.  Adding a new
single-source formula to the platform means adding ONE :class:`Formula`
line here; the parity-drift rule then flags any function or expression
in the analyzed tree whose normalized AST matches the registered home's
— anywhere except the home itself.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Formula:
    """One registered single-source formula.

    ``home`` is the repo-relative path of the defining module; ``qualname``
    names the def (``fn`` or ``Class.method``) inside it.  ``why`` is the
    one-line rationale surfaced in findings, so the fix direction
    ("import it from <home>") is self-explanatory at the flagged line.
    """

    name: str
    home: str
    qualname: str
    why: str
    #: also match expression-level cores extracted from the home's body
    #: (return values / binop assigns).  Disable for formulas whose core
    #: is a generic idiom (e.g. a bare ceil-div) that would flag every
    #: unrelated use of the same arithmetic shape.
    expr_level: bool = True


FORMULAS: Tuple[Formula, ...] = (
    Formula(
        name="pages-needed",
        home="src/repro/cache/pages.py",
        qualname="pages_needed",
        why="the ONE page-extent formula shared by engine admission, "
            "tier budgets, and the simulator's page ledger — a clone "
            "desyncs live vs simulated capacity",
    ),
    Formula(
        name="token-extent",
        home="src/repro/cache/pages.py",
        qualname="token_extent",
        why="the KV write extent underlying both page reservation and "
            "the rolling-wrap admission test; a re-typed copy lets the "
            "two disagree about which requests wrap",
    ),
    Formula(
        name="pages-for-tokens",
        home="src/repro/cache/pages.py",
        qualname="pages_for_tokens",
        why="page count covering a token prefix; cloned ceil-div "
            "variants drift from the pool's accounting",
        expr_level=False,  # its core is a bare ceil-div — too generic
    ),
    Formula(
        name="link-latency",
        home="src/repro/core/topology.py",
        qualname="LinkSpec.latency_s",
        why="the RTT + serialization cost charged on every link "
            "crossing; both runtimes must charge the identical float "
            "expression or latency clocks diverge",
    ),
    Formula(
        name="eq1-tail-ratio",
        home="src/repro/core/offload.py",
        qualname="tail_ratio",
        why="the floored p95/p50 core both Eq-(1) front ends (latency "
            "window and histogram sketch) must share — the corners "
            "(p50=0, NaN) are where clones diverge first",
    ),
    Formula(
        name="eq1-latency-ratio",
        home="src/repro/core/offload.py",
        qualname="latency_ratio",
        why="Eq (1): the p95/p50 tail ratio driving R_t — a second "
            "implementation breaks bit-identical controller "
            "trajectories",
    ),
    Formula(
        name="eq3-target-percentage",
        home="src/repro/core/offload.py",
        qualname="target_percentage",
        why="Eq (3): the piecewise-linear ratio->percentage map; sim "
            "and live share it through offload_update",
    ),
    Formula(
        name="queue-age-mixing",
        home="src/repro/core/policy.py",
        qualname="ControlLoop.mix_queue_ages",
        why="the Eq-(1) window mixing of in-flight queue ages — the "
            "onset signal; PRs 5-7 fought to keep sim and live on this "
            "one implementation",
    ),
    Formula(
        name="tier-distribution",
        home="src/repro/core/policy.py",
        qualname="Policy.tier_distribution",
        why="per-boundary R_t -> N-tier routing distribution; the "
            "waterfall composition must be computed once, not per "
            "deployment",
    ),
    Formula(
        name="derived-slot-capacity",
        home="src/repro/launch/tier_cost.py",
        qualname="derived_slot_capacity",
        why="the HBM-derived slot count of a cost-modeled tier — the "
            "simulator's _SimTier pools and the live Endpoint both get "
            "it from the resolved TierSpec; a cloned clamp desyncs "
            "simulated capacity from live KPA admission",
    ),
    Formula(
        name="derived-service-rate",
        home="src/repro/launch/tier_cost.py",
        qualname="derived_service_rate_mult",
        why="the decode-step ratio turning hlo_cost rooflines into the "
            "simulator's service_rate_mult; a re-derived ratio breaks "
            "the shared-cost-model contract between sim and live",
        expr_level=False,  # its core is a bare division — too generic
    ),
)
