"""continuum-lint: AST-based static analysis for the sim<->live parity stack.

The repo's core guarantee is that the simulator and the live runtime
produce bit-identical R_t and token streams.  That guarantee is enforced
at runtime by parity fuzzers — but a duplicated formula, an impure jitted
function, or a recompile hazard is caught late (or never) by fuzzing.
This package is the lint-time half of the contract:

  * :mod:`repro.analysis.engine`   — file loading, suppressions
    (``# lint: ignore[rule] -- reason``), the committed JSON baseline,
    ``--json`` stats, and the rule driver.
  * :mod:`repro.analysis.rules`    — the rule passes (jit-purity,
    recompile-hazard, parity-drift, swallowed-exception, library-assert).
  * :mod:`repro.analysis.registry` — the opt-in list of single-source
    formulas whose re-implementation parity-drift hunts for.

Run it as ``python -m repro.analysis src tests benchmarks``; it exits
nonzero on any finding that is neither suppressed nor baselined.
"""

from repro.analysis.engine import (AnalysisConfig, Finding, Report,
                                   run_analysis)
from repro.analysis.registry import FORMULAS, Formula
from repro.analysis.rules import ALL_RULES

__all__ = ["AnalysisConfig", "Finding", "Report", "run_analysis",
           "FORMULAS", "Formula", "ALL_RULES"]
