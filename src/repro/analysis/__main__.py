"""continuum-lint CLI: ``python -m repro.analysis [paths...]``.

Exit status is 0 when no NEW findings exist (suppressed and baselined
findings don't fail the run), 1 otherwise.  ``--write-baseline``
grandfathers the current findings into the baseline file and exits 0.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.engine import (load_baseline, run_analysis,
                                   write_baseline)
from repro.analysis.rules import ALL_RULES

DEFAULT_PATHS = ("src", "tests", "benchmarks")
DEFAULT_BASELINE = ".analysis-baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="continuum-lint: jit purity, recompile hazards, "
                    "sim-live parity drift, swallowed exceptions, "
                    "library asserts")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories to lint "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=".",
                    help="repo root paths are relative to (default: cwd)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON of grandfathered findings "
                         f"(default: {DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file entirely")
    ap.add_argument("--write-baseline", action="store_true",
                    help="grandfather current findings into the "
                         "baseline and exit 0")
    ap.add_argument("--json", nargs="?", const="-", metavar="FILE",
                    help="emit stats JSON to FILE (or stdout with no "
                         "argument)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-finding lines (summary only)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name}: {rule.synopsis}")
        return 0

    root = Path(args.root).resolve()
    baseline_path = root / args.baseline
    baseline = {} if args.no_baseline else load_baseline(baseline_path)

    report = run_analysis(args.paths, root=root, baseline=baseline)

    if args.write_baseline:
        write_baseline(baseline_path, report)
        total = len(report.findings) + len(report.baselined)
        print(f"baseline written: {baseline_path} "
              f"({total} grandfathered finding"
              f"{'s' if total != 1 else ''})")
        return 0

    if not args.quiet:
        for f in report.findings:
            print(f.render())

    stats = report.stats()
    if args.json:
        blob = json.dumps(stats, indent=2)
        if args.json == "-":
            print(blob)
        else:
            Path(args.json).write_text(blob + "\n", encoding="utf-8")

    summary = (f"{report.files} files: {stats['new']} new, "
               f"{stats['suppressed']} suppressed, "
               f"{stats['baselined']} baselined")
    print(summary, file=sys.stderr)
    return 0 if report.clean else 1


if __name__ == "__main__":
    sys.exit(main())
