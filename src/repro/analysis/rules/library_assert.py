"""library-assert: ``assert`` used for runtime validation in shipped code.

``python -O`` strips every assert.  In ``src/repro`` an assert guarding
a capacity invariant or a shape check therefore only protects debug
runs; production (or any harness run with ``-O``) sails past it and
fails later, somewhere less diagnosable.  Library code must raise
explicit exceptions (``ValueError``/``RuntimeError``) instead.

Tests are exempt (pytest rewrites their asserts), as is anything outside
``config.library_roots``.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import AnalysisContext, Finding, Module


class LibraryAssertRule:
    name = "library-assert"
    synopsis = ("`assert` statements in shipped library code that "
                "`python -O` would strip — use explicit raises")

    def check(self, mod: Module, ctx: AnalysisContext
              ) -> Iterator[Finding]:
        if not ctx.config.in_library(mod.path):
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assert):
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    "`assert` in library code is stripped by `python "
                    "-O`: raise ValueError/RuntimeError explicitly so "
                    "the invariant holds in every run mode")
