"""swallowed-exception: broad except handlers that can hide real faults.

Two tiers, keyed by path:

  * hot paths (``config.hot_paths`` — the serving/control-plane modules
    where a swallowed error means a silently wedged request or a
    desynced controller): EVERY broad catch (bare ``except:``,
    ``except Exception``, ``except BaseException``, or a tuple
    containing one) is a finding, even when it re-raises.  A
    cleanup-and-reraise handler is legitimate — suppress it with the
    reason stating what the cleanup protects.
  * other library code: a broad catch is a finding only when the
    handler neither re-raises nor records the error (logging/warnings/
    binding the exception for use) — the classic ``except Exception:
    pass`` black hole.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.engine import AnalysisContext, Finding, Module
from repro.analysis.rules.common import dotted_name

_BROAD = {"Exception", "BaseException"}
_RECORD_CALLS = ("warnings.warn", "logging", "log", "warn", "print")


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True  # bare except:
    if isinstance(t, ast.Tuple):
        return any(_name_is_broad(e) for e in t.elts)
    return _name_is_broad(t)


def _name_is_broad(node: ast.AST) -> bool:
    d = dotted_name(node)
    return d is not None and d.split(".")[-1] in _BROAD


def _reraises(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
    return False


def _records(handler: ast.ExceptHandler) -> bool:
    """Handler logs/warns, or actually USES the bound exception."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Call):
            d = dotted_name(node.func) or ""
            if any(d == c or d.startswith(c + ".")
                   or d.split(".")[0] == c for c in _RECORD_CALLS):
                return True
        if (handler.name and isinstance(node, ast.Name)
                and node.id == handler.name
                and isinstance(node.ctx, ast.Load)):
            return True
    return False


class SwallowedExceptionRule:
    name = "swallowed-exception"
    synopsis = ("broad except handlers: any broad catch in serving/core "
                "hot paths; silent (no re-raise, no logging) broad "
                "catches elsewhere in the library")

    def check(self, mod: Module, ctx: AnalysisContext
              ) -> Iterator[Finding]:
        if not ctx.config.in_library(mod.path):
            return
        hot = ctx.config.in_hot_path(mod.path)
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _is_broad(node):
                continue
            what = ("bare `except:`" if node.type is None else
                    f"`except {ast.unparse(node.type)}`")
            if hot:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"{what} in a serving/control hot path: broad "
                    f"catches here can wedge requests or desync the "
                    f"controller — narrow the exception types, or "
                    f"suppress with the reason the breadth is required")
            elif not _reraises(node) and not _records(node):
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"{what} neither re-raises nor records the error: "
                    f"faults vanish here — narrow it, log it, or "
                    f"re-raise")
