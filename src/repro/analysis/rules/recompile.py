"""recompile-hazard: callsite patterns that defeat the jit compile cache.

``jax.jit`` caches by (function identity, static argument values,
argument shapes/dtypes).  Four patterns silently turn that cache into a
recompile-per-call treadmill, which on this serving stack means a decode
step stalling for seconds mid-tick:

  * constructing a jit wrapper inside a loop (fresh identity each
    iteration);
  * jitting a lambda/closure inside a repeatedly-called function
    (fresh identity each call — hoist to ``__init__``/module scope);
  * feeding an f-string (or any varying string) to a jitted callable —
    static args hash by value, so every distinct string recompiles;
  * feeding a loop-varying Python value at a declared static position.

Plus the plain signature bug: ``static_argnums`` out of range /
``static_argnames`` naming a parameter the target doesn't have, which
jax only reports at first call (or mis-binds entirely).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import AnalysisContext, Finding, Module
from repro.analysis.rules.common import (all_arg_names, arg_names,
                                         dotted_name, enclosing_function,
                                         walk_with_parents)
from repro.analysis.rules.jit_purity import JIT_WRAPPERS, _is_partial_jit

#: methods whose body runs once per object, where building a jit wrapper
#: is the canonical "one compiled program per instance" pattern
_INIT_METHODS = {"__init__", "__post_init__", "__new__", "__init_subclass__"}


def _static_spec(call: ast.Call) -> Tuple[Optional[List[int]],
                                          Optional[List[str]]]:
    """Literal static_argnums/static_argnames from a jit call, when
    they are statically resolvable (None entries otherwise)."""
    nums: Optional[List[int]] = None
    names: Optional[List[str]] = None
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            nums = _int_list(kw.value)
        elif kw.arg == "static_argnames":
            names = _str_list(kw.value)
    return nums, names


def _int_list(node: ast.AST) -> Optional[List[int]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return out
    return None


def _str_list(node: ast.AST) -> Optional[List[str]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return out
    return None


class RecompileHazardRule:
    name = "recompile-hazard"
    synopsis = ("jit wrappers built per loop iteration / per call, "
                "f-string or loop-varying static args, "
                "static_argnums/static_argnames signature mismatches")

    def check(self, mod: Module, ctx: AnalysisContext
              ) -> Iterator[Finding]:
        tree = mod.tree
        # local def name -> node (unambiguous names only, for signatures)
        local_defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                local_defs.setdefault(node.name, []).append(node)

        #: names bound to jitted callables -> (static nums, static names,
        #: target def or None); keys are bare names and ``self.attr``
        jitted: Dict[str, Tuple[Optional[List[int]], Optional[List[str]],
                                Optional[ast.AST]]] = {}

        # --- pass 1: decorated defs + jit-wrapper bindings --------------
        for node, parents in walk_with_parents(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    spec = self._jit_call_spec(dec)
                    if spec is None:
                        continue
                    nums, names = spec
                    jitted[node.name] = (nums, names, node)
                    yield from self._check_signature(
                        mod, dec, node, nums, names,
                        skip_first=bool(parents
                                        and isinstance(parents[-1],
                                                       ast.ClassDef)))
            elif isinstance(node, ast.Assign) and isinstance(node.value,
                                                             ast.Call):
                spec = self._jit_call_spec(node.value, require_call=True)
                if spec is None:
                    continue
                nums, names = spec
                target_def = self._resolve_target(node.value, local_defs)
                for t in node.targets:
                    key = self._bind_key(t)
                    if key:
                        jitted[key] = (nums, names, target_def)
                if target_def is not None:
                    yield from self._check_signature(
                        mod, node.value, target_def, nums, names)

        # --- pass 2: construction-site and callsite hazards -------------
        init_scope = self._init_only_helpers(tree)
        for node, parents in walk_with_parents(tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            if d in JIT_WRAPPERS or _is_partial_jit(node):
                yield from self._check_build_site(mod, node, d, parents,
                                                  local_defs, init_scope,
                                                  ctx)
                continue
            key = self._call_key(node)
            if key in jitted:
                yield from self._check_callsite(mod, node, key,
                                                jitted[key], parents)

    # ------------------------------------------------------------------
    @staticmethod
    def _jit_call_spec(node: ast.AST, require_call: bool = False
                       ) -> Optional[Tuple[Optional[List[int]],
                                           Optional[List[str]]]]:
        """(static_argnums, static_argnames) when ``node`` is a jit
        wrapper (bare decorator, call, or partial(jax.jit, ...))."""
        if isinstance(node, ast.Call):
            if dotted_name(node.func) in JIT_WRAPPERS:
                return _static_spec(node)
            if _is_partial_jit(node):
                return _static_spec(node)
            return None
        if not require_call and dotted_name(node) in JIT_WRAPPERS:
            return None, None
        return None

    @staticmethod
    def _resolve_target(call: ast.Call,
                        local_defs: Dict[str, List[ast.AST]]
                        ) -> Optional[ast.AST]:
        args = call.args
        if _is_partial_jit(call):
            args = args[1:]
        if args and isinstance(args[0], ast.Name):
            cands = local_defs.get(args[0].id, [])
            if len(cands) == 1:
                return cands[0]
        return None

    @staticmethod
    def _bind_key(target: ast.AST) -> Optional[str]:
        if isinstance(target, ast.Name):
            return target.id
        if (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"):
            return f"self.{target.attr}"
        return None

    @staticmethod
    def _call_key(call: ast.Call) -> Optional[str]:
        if isinstance(call.func, ast.Name):
            return call.func.id
        if (isinstance(call.func, ast.Attribute)
                and isinstance(call.func.value, ast.Name)
                and call.func.value.id == "self"):
            return f"self.{call.func.attr}"
        return None

    # ------------------------------------------------------------------
    def _check_signature(self, mod: Module, site: ast.AST, fn: ast.AST,
                         nums: Optional[List[int]],
                         names: Optional[List[str]],
                         skip_first: bool = False) -> Iterator[Finding]:
        """Validate literal static specs against the target def."""
        pos = arg_names(fn)
        if skip_first and pos and pos[0] in ("self", "cls"):
            pos = pos[1:]
        has_varargs = fn.args.vararg is not None
        if nums is not None and not has_varargs:
            n = len(pos)
            for i in nums:
                if i >= n or i < -n:
                    yield Finding(
                        self.name, mod.path, site.lineno, site.col_offset,
                        f"static_argnums={i} out of range for "
                        f"`{fn.name}` ({n} positional parameter"
                        f"{'s' if n != 1 else ''})")
        if names is not None and fn.args.kwarg is None:
            known = set(all_arg_names(fn))
            for s in names:
                if s not in known:
                    yield Finding(
                        self.name, mod.path, site.lineno, site.col_offset,
                        f"static_argnames={s!r} is not a parameter of "
                        f"`{fn.name}`")

    def _check_build_site(self, mod: Module, call: ast.Call,
                          wrapper: Optional[str],
                          parents: Tuple[ast.AST, ...],
                          local_defs: Dict[str, List[ast.AST]],
                          init_scope: Set[str],
                          ctx: AnalysisContext) -> Iterator[Finding]:
        label = wrapper or "partial(jax.jit, ...)"
        in_loop = any(isinstance(p, (ast.For, ast.While, ast.AsyncFor))
                      for p in parents)
        if in_loop:
            yield Finding(
                self.name, mod.path, call.lineno, call.col_offset,
                f"`{label}(...)` constructed inside a loop: a fresh "
                f"wrapper per iteration recompiles every time — hoist "
                f"the wrapper out of the loop")
            return
        if not ctx.config.in_library(mod.path):
            # a per-call wrapper in a test/benchmark body compiles once
            # per run — only the loop case above matters there
            return
        fn = enclosing_function(parents)
        if fn is None or (isinstance(fn, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
                          and (fn.name in _INIT_METHODS
                               or fn.name in init_scope)):
            return
        args = call.args[1:] if _is_partial_jit(call) else call.args
        if not args:
            return
        target = args[0]
        closure = isinstance(target, ast.Lambda)
        if isinstance(target, ast.Name):
            closure = any(
                any(p is fn for p in ps)
                for d in local_defs.get(target.id, [])
                for _, ps in [(d, self._ancestors_of(mod.tree, d))])
        if closure:
            yield Finding(
                self.name, mod.path, call.lineno, call.col_offset,
                f"`{label}` of a lambda/closure inside "
                f"`{getattr(fn, 'name', '<lambda>')}`: the wrapper gets "
                f"a fresh identity on every call, so nothing is ever "
                f"cache-hit — build it once in __init__/module scope")

    @staticmethod
    def _init_only_helpers(tree: ast.Module) -> Set[str]:
        """Method names whose only same-module call sites sit inside init
        methods (or other init-only helpers): building a jit wrapper in
        ``_build_paged_ops`` called once from ``__init__`` is the same
        one-compile-per-instance pattern as building it in ``__init__``.
        Fixpoint over call edges; a name also called from non-init code
        (or referenced without a call) never qualifies."""
        callers: Dict[str, Set[str]] = {}
        disqualified: Set[str] = set()
        for node, parents in walk_with_parents(tree):
            name: Optional[str] = None
            is_call = False
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id in ("self", "cls")):
                name = node.func.attr
                is_call = True
            elif (isinstance(node, ast.Attribute)
                  and isinstance(node.value, ast.Name)
                  and node.value.id in ("self", "cls")
                  and not (parents and isinstance(parents[-1], ast.Call)
                           and parents[-1].func is node)):
                name = node.attr  # bare reference: could be called anywhere
            if name is None:
                continue
            fn = enclosing_function(parents)
            caller = getattr(fn, "name", None)
            if not is_call or caller is None:
                disqualified.add(name)
            else:
                callers.setdefault(name, set()).add(caller)
        result: Set[str] = set()
        changed = True
        while changed:
            changed = False
            for name, froms in callers.items():
                if name in result or name in disqualified:
                    continue
                if all(c in _INIT_METHODS or c in result for c in froms):
                    result.add(name)
                    changed = True
        return result

    @staticmethod
    def _ancestors_of(tree: ast.Module, target: ast.AST
                      ) -> Tuple[ast.AST, ...]:
        for node, parents in walk_with_parents(tree):
            if node is target:
                return parents
        return ()

    def _check_callsite(self, mod: Module, call: ast.Call, key: str,
                        spec: Tuple[Optional[List[int]],
                                    Optional[List[str]],
                                    Optional[ast.AST]],
                        parents: Tuple[ast.AST, ...]) -> Iterator[Finding]:
        nums, names, target_def = spec
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.JoinedStr):
                yield Finding(
                    self.name, mod.path, arg.lineno, arg.col_offset,
                    f"f-string argument to jitted `{key}`: static args "
                    f"hash by value, so every distinct string compiles "
                    f"a fresh program")
        if not nums and not names:
            return
        loop_vars = self._loop_targets(parents)
        if not loop_vars:
            return
        pos_args = call.args
        static_pos: Set[int] = set(nums or [])
        for i, arg in enumerate(pos_args):
            if (i in static_pos and isinstance(arg, ast.Name)
                    and arg.id in loop_vars):
                yield Finding(
                    self.name, mod.path, arg.lineno, arg.col_offset,
                    f"loop variable `{arg.id}` fed to jitted `{key}` at "
                    f"static position {i}: recompiles every iteration")
        static_names = set(names or [])
        for kw in call.keywords:
            if (kw.arg in static_names and isinstance(kw.value, ast.Name)
                    and kw.value.id in loop_vars):
                yield Finding(
                    self.name, mod.path, kw.value.lineno,
                    kw.value.col_offset,
                    f"loop variable `{kw.value.id}` fed to jitted "
                    f"`{key}` at static argument {kw.arg!r}: recompiles "
                    f"every iteration")

    @staticmethod
    def _loop_targets(parents: Tuple[ast.AST, ...]) -> Set[str]:
        out: Set[str] = set()
        for p in parents:
            if isinstance(p, (ast.For, ast.AsyncFor)):
                for n in ast.walk(p.target):
                    if isinstance(n, ast.Name):
                        out.add(n.id)
        return out
