"""jit-purity: impure operations inside jit-reachable functions, plus
unseeded RNG anywhere.

A function traced by ``jax.jit``/``pl.pallas_call`` runs its Python body
ONCE; host clocks, RNG draws, prints, and global mutation silently
freeze into the compiled program (or desync it from the simulator).  The
pass seeds on every def that is jitted — decorated with ``@jax.jit`` /
``@functools.partial(jax.jit, ...)`` or passed to ``jax.jit(...)`` /
``pl.pallas_call(...)`` — and propagates reachability through same-module
calls and function-valued references (``jax.lax.scan(step, ...)``).
Cross-module reachability is intentionally out of scope: each module's
jitted surface is checked where it is defined.

The RNG sub-check runs everywhere (not just under jit): the platform's
determinism contract requires every generator to descend from an
explicit seed threaded through config, so module-global numpy/stdlib RNG
state and seedless constructors are findings in host code too.
"""

from __future__ import annotations

import ast
from typing import Iterator, List, Set

from repro.analysis.engine import AnalysisContext, Finding, Module
from repro.analysis.rules.common import (collect_defs, dotted_name,
                                         walk_with_parents)

JIT_WRAPPERS = {
    "jax.jit", "jit", "jax.pmap", "pmap", "pjit",
    "pl.pallas_call", "pallas_call",
}
PARTIAL = {"functools.partial", "partial"}

_HOST_CLOCKS = {
    "time.time", "time.perf_counter", "time.monotonic",
    "time.process_time", "time.time_ns", "time.perf_counter_ns",
    "time.monotonic_ns", "datetime.datetime.now", "datetime.now",
}
_HOST_SYNC_ATTRS = {"item", "tolist", "block_until_ready"}
_HOST_SYNC_CALLS = {"jax.device_get", "np.asarray", "np.array",
                    "numpy.asarray", "numpy.array"}

#: module-global numpy RNG functions (shared mutable state, unseedable
#: per-callsite)
_NP_GLOBAL_RNG = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "normal", "uniform", "choice", "shuffle", "permutation",
    "poisson", "exponential", "beta", "gamma", "binomial", "lognormal",
    "standard_normal", "bytes", "seed", "integers",
}
#: stdlib ``random`` module-level functions (same problem)
_STDLIB_GLOBAL_RNG = {
    "random", "randint", "uniform", "choice", "choices", "shuffle",
    "sample", "gauss", "randrange", "betavariate", "expovariate",
    "normalvariate", "seed", "getrandbits",
}
#: constructors that are fine WITH a seed argument, findings without one
_SEEDABLE_CTORS = {
    "np.random.default_rng", "numpy.random.default_rng",
    "np.random.RandomState", "numpy.random.RandomState",
    "random.Random", "jax.random.PRNGKey", "jax.random.key",
}


def _jit_target_names(call: ast.Call) -> List[str]:
    """Local def names passed to a jit-wrapper call (``jax.jit(fn)``)."""
    return [a.id for a in call.args if isinstance(a, ast.Name)]


def _is_partial_jit(call: ast.Call) -> bool:
    if dotted_name(call.func) not in PARTIAL or not call.args:
        return False
    return dotted_name(call.args[0]) in JIT_WRAPPERS


class JitPurityRule:
    name = "jit-purity"
    synopsis = ("host clocks, RNG, print, global/nonlocal mutation, and "
                "host syncs inside jit-reachable functions; unseeded RNG "
                "anywhere")

    def check(self, mod: Module, ctx: AnalysisContext
              ) -> Iterator[Finding]:
        tree = mod.tree
        defs = collect_defs(tree)

        # --- seed set: defs that are jitted at their definition or by
        # --- being passed to a jit wrapper anywhere in the module
        seeds: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    d = dotted_name(dec)
                    if d in JIT_WRAPPERS:
                        seeds.add(node.name)
                    elif isinstance(dec, ast.Call) and (
                            dotted_name(dec.func) in JIT_WRAPPERS
                            or _is_partial_jit(dec)):
                        seeds.add(node.name)
            elif isinstance(node, ast.Call):
                if dotted_name(node.func) in JIT_WRAPPERS:
                    seeds.update(n for n in _jit_target_names(node)
                                 if n in defs)
                elif _is_partial_jit(node):
                    seeds.update(n for n in _jit_target_names(node)[1:]
                                 if n in defs)

        # --- propagate reachability through calls, self.method calls,
        # --- and function-valued references (lax.scan(step, ...))
        reachable: Set[int] = set()
        work = [d for n in seeds for d in defs[n]]
        while work:
            fn = work.pop()
            if id(fn) in reachable:
                continue
            reachable.add(id(fn))
            for node in ast.walk(fn):
                names: List[str] = []
                if isinstance(node, ast.Name) and node.id in defs:
                    names.append(node.id)
                elif (isinstance(node, ast.Attribute)
                      and isinstance(node.value, ast.Name)
                      and node.value.id in ("self", "cls")
                      and node.attr in defs):
                    names.append(node.attr)
                for n in names:
                    for d in defs[n]:
                        if id(d) not in reachable:
                            work.append(d)

        # --- findings (deduped: a nested reachable def is walked both
        # --- on its own and inside its enclosing reachable def) -------
        in_jit_rng: Set[int] = set()
        seen: Set[tuple] = set()
        for fn_node in (n for n in ast.walk(tree)
                        if id(n) in reachable):
            for f in self._check_jitted(mod, fn_node, in_jit_rng):
                slot = (f.line, f.col, f.message)
                if slot not in seen:
                    seen.add(slot)
                    yield f
        yield from self._check_rng(mod, tree, in_jit_rng)

    # -- impurities inside one jit-reachable def ------------------------
    # (nested defs are excluded from the walk: a reachable nested def is
    # checked under its OWN name, an unreachable one is dead code to jit)
    @staticmethod
    def _walk_own_body(fn: ast.AST) -> Iterator[ast.AST]:
        stack = list(fn.body)
        while stack:
            node = stack.pop()
            yield node
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.extend(ast.iter_child_nodes(node))

    def _check_jitted(self, mod: Module, fn: ast.AST,
                      in_jit_rng: Set[int]) -> Iterator[Finding]:
        where = f"jit-reachable `{fn.name}`"
        for node in self._walk_own_body(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                kw = "global" if isinstance(node, ast.Global) else "nonlocal"
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"`{kw} {', '.join(node.names)}` in {where}: mutation "
                    f"under trace runs once at compile time")
                continue
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func)
            if d in _HOST_CLOCKS:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"host clock `{d}()` in {where}: traced once, "
                    f"constant in the compiled program")
            elif d and (d.startswith("np.random.")
                        or d.startswith("numpy.random.")
                        or d.startswith("random.")):
                in_jit_rng.add(id(node))
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"`{d}()` in {where}: host RNG draws freeze at trace "
                    f"time — use jax.random with a threaded key")
            elif d == "print":
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"`print` in {where}: runs at trace time only — use "
                    f"jax.debug.print if intentional")
            elif d in _HOST_SYNC_CALLS:
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"`{d}()` in {where}: host sync/materialization of a "
                    f"traced value")
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in _HOST_SYNC_ATTRS
                  and not node.args and not node.keywords):
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"`.{node.func.attr}()` in {where}: blocking host "
                    f"sync on a traced value")
            elif (d in ("float", "int", "bool") and len(node.args) == 1
                  and not node.keywords
                  and isinstance(node.args[0], (ast.Name, ast.Attribute))):
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"`{d}(...)` on a value in {where}: casting a tracer "
                    f"to a Python scalar forces a host sync "
                    f"(ConcretizationError at best)")

    # -- unseeded / module-global RNG anywhere --------------------------
    def _check_rng(self, mod: Module, tree: ast.Module,
                   in_jit_rng: Set[int]) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or id(node) in in_jit_rng:
                continue
            d = dotted_name(node.func)
            if d is None:
                continue
            if d in _SEEDABLE_CTORS:
                if not node.args and not node.keywords:
                    yield Finding(
                        self.name, mod.path, node.lineno, node.col_offset,
                        f"`{d}()` without a seed: determinism requires "
                        f"every generator to derive from an explicit "
                        f"seed threaded through config")
                continue
            parts = d.split(".")
            if (len(parts) == 3 and parts[0] in ("np", "numpy")
                    and parts[1] == "random"
                    and parts[2] in _NP_GLOBAL_RNG):
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"module-global `{d}()`: shared mutable RNG state — "
                    f"derive a Generator from an explicit seed instead")
            elif (len(parts) == 2 and parts[0] == "random"
                    and parts[1] in _STDLIB_GLOBAL_RNG):
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"module-global `{d}()`: shared mutable RNG state — "
                    f"use random.Random(seed) or np.random.default_rng")
