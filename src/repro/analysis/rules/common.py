"""Shared AST plumbing for the rule passes."""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.jit`` for ``Attribute(Name)`` chains, ``jit`` for a bare
    Name; None for anything not a plain dotted path."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def walk_with_parents(tree: ast.AST) -> Iterator[Tuple[ast.AST,
                                                       Tuple[ast.AST, ...]]]:
    """Yield ``(node, ancestors)`` depth-first; ancestors outermost-first."""
    stack: List[Tuple[ast.AST, Tuple[ast.AST, ...]]] = [(tree, ())]
    while stack:
        node, parents = stack.pop()
        yield node, parents
        child_parents = parents + (node,)
        for child in reversed(list(ast.iter_child_nodes(node))):
            stack.append((child, child_parents))


def enclosing_function(parents: Tuple[ast.AST, ...]
                       ) -> Optional[ast.AST]:
    """Innermost FunctionDef/AsyncFunctionDef/Lambda on the ancestor
    chain (None at module/class scope)."""
    for p in reversed(parents):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            return p
    return None


def collect_defs(tree: ast.Module) -> Dict[str, List[ast.AST]]:
    """Every function/method def in the module keyed by BARE name
    (methods and nested defs included — the jit reachability walk is a
    deliberate over-approximation)."""
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    return defs


def qualnames(tree: ast.Module) -> Dict[int, str]:
    """id(def node) -> dotted qualname (``Class.method``, ``fn.inner``)."""
    out: Dict[int, str] = {}

    def visit(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                q = f"{prefix}{child.name}"
                if not isinstance(child, ast.ClassDef):
                    out[id(child)] = q
                visit(child, q + ".")
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


def arg_names(fn: ast.AST) -> List[str]:
    """Positional-capable parameter names, in order (posonly + args)."""
    a = fn.args
    return [x.arg for x in list(a.posonlyargs) + list(a.args)]


def all_arg_names(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [x.arg for x in
             list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def node_count(node: ast.AST) -> int:
    return sum(1 for _ in ast.walk(node))
