"""Rule registry for continuum-lint.

Every rule is an object with ``name``, ``synopsis`` and
``check(module, ctx) -> Iterator[Finding]``; the engine runs each over
every analyzed module.  Order here is cosmetic — the engine re-sorts
findings by location.
"""

from __future__ import annotations

from repro.analysis.rules.jit_purity import JitPurityRule
from repro.analysis.rules.library_assert import LibraryAssertRule
from repro.analysis.rules.parity_drift import ParityDriftRule
from repro.analysis.rules.recompile import RecompileHazardRule
from repro.analysis.rules.swallowed_exception import SwallowedExceptionRule

ALL_RULES = (
    JitPurityRule(),
    RecompileHazardRule(),
    ParityDriftRule(),
    SwallowedExceptionRule(),
    LibraryAssertRule(),
)

__all__ = [
    "ALL_RULES",
    "JitPurityRule",
    "RecompileHazardRule",
    "ParityDriftRule",
    "SwallowedExceptionRule",
    "LibraryAssertRule",
]
