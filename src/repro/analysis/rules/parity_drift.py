"""parity-drift: re-implementations of registered single-source formulas.

The sim<->live contract (ROADMAP north star) holds because a handful of
arithmetic formulas live in exactly one module that both deployments
import.  This pass detects the failure mode that broke parity twice
before PR 5: someone re-types the arithmetic instead of importing it.

Detection is normalized-AST fingerprinting:

  * every registered :class:`~repro.analysis.registry.Formula` home def
    is parsed and fingerprinted — once whole-def (argument names mapped
    to positional placeholders in signature order) and once per
    "expression core" (return values and binop-shaped assignments,
    fresh placeholder mapping each);
  * every def and expression core in an analyzed library module is
    fingerprinted the same way and compared.

Normalization maps variable names to first-occurrence placeholders, so
``rtt + n / bw`` matches ``self.rtt_s + nbytes / self.bandwidth_Bps``
structurally, and keeps attribute/call names literal so ``np.maximum``
still matches ``jnp.maximum`` (sim-vs-live spellings) without matching
unrelated arithmetic.  Docstrings, annotations, and type comments are
stripped.  Expression cores below ``min_expr_nodes`` nodes are ignored —
tiny arithmetic is idiom, not a formula.

Scope: library code only (``config.in_library``).  Tests legitimately
recompute oracles by hand; re-deriving a formula in a test is the point
of the test.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.engine import AnalysisContext, Finding, Module
from repro.analysis.registry import Formula
from repro.analysis.rules.common import arg_names, node_count, qualnames


class _Normalizer(ast.NodeTransformer):
    """Rewrite Name ids to stable positional placeholders."""

    def __init__(self, pre: Optional[Dict[str, str]] = None):
        self.mapping: Dict[str, str] = dict(pre or {})

    def visit_Name(self, node: ast.Name) -> ast.Name:
        if node.id not in self.mapping:
            self.mapping[node.id] = f"_v{len(self.mapping)}"
        return ast.copy_location(
            ast.Name(id=self.mapping[node.id], ctx=ast.Load()), node)

    def visit_Attribute(self, node: ast.Attribute) -> ast.Attribute:
        # Keep the attribute NAME literal but normalize the value chain:
        # ``self.rtt_s`` and ``spec.rtt_s`` both become ``_v0.rtt_s``.
        return ast.copy_location(
            ast.Attribute(value=self.visit(node.value), attr=node.attr,
                          ctx=ast.Load()), node)

    def visit_arg(self, node: ast.arg) -> ast.arg:
        if node.arg not in self.mapping:
            self.mapping[node.arg] = f"_a{len(self.mapping)}"
        return ast.arg(arg=self.mapping[node.arg], annotation=None)


def _strip(node: ast.AST) -> ast.AST:
    """Drop docstrings/annotations so formatting never affects the print."""
    class Cleaner(ast.NodeTransformer):
        def visit_FunctionDef(self, n):
            self.generic_visit(n)
            n.returns = None
            n.decorator_list = []
            if (n.body and isinstance(n.body[0], ast.Expr)
                    and isinstance(n.body[0].value, ast.Constant)
                    and isinstance(n.body[0].value.value, str)):
                n.body = n.body[1:] or [ast.Pass()]
            return n
        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_AnnAssign(self, n):
            self.generic_visit(n)
            if n.value is None:
                return None
            return ast.copy_location(
                ast.Assign(targets=[n.target], value=n.value), n)
    return Cleaner().visit(node)


def fingerprint_def(fn: ast.AST) -> str:
    """Whole-def fingerprint; argument names pre-seed the mapping in
    signature order so renamed-but-same-order clones still match."""
    import copy
    fn = _strip(copy.deepcopy(fn))
    pre = {a: f"_a{i}" for i, a in enumerate(arg_names(fn))}
    norm = _Normalizer(pre)
    body = [norm.visit(stmt) for stmt in fn.body]
    return ";".join(ast.dump(ast.fix_missing_locations(s),
                             include_attributes=False) for s in body)


def fingerprint_expr(expr: ast.AST) -> str:
    import copy
    norm = _Normalizer()
    e = norm.visit(copy.deepcopy(expr))
    return ast.dump(ast.fix_missing_locations(e),
                    include_attributes=False)


_CORE_TYPES = (ast.BinOp, ast.BoolOp, ast.IfExp, ast.Compare)


def expr_cores(fn: ast.AST) -> List[ast.AST]:
    """Expressions inside a def that look like formula arithmetic:
    return values, and assignment RHSs with arithmetic shape."""
    out: List[ast.AST] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            out.append(node.value)
        elif isinstance(node, ast.Assign) and isinstance(node.value,
                                                         _CORE_TYPES):
            out.append(node.value)
    return out


def _find_def(tree: ast.Module, qualname: str) -> Optional[ast.AST]:
    for node_id, q in qualnames(tree).items():
        if q == qualname:
            for node in ast.walk(tree):
                if id(node) == node_id:
                    return node
    return None


class ParityDriftRule:
    name = "parity-drift"
    synopsis = ("normalized-AST clones of registered single-source "
                "formulas (pages_needed, LinkSpec.latency_s, "
                "Eq-(1)/(3) controller maps, queue-age mixing)")

    def check(self, mod: Module, ctx: AnalysisContext
              ) -> Iterator[Finding]:
        if not ctx.config.in_library(mod.path):
            return
        index = self._formula_index(ctx)
        if not index:
            return
        def_prints, expr_prints = index
        quals = qualnames(mod.tree)

        #: defs that ARE a canonical home — skip their whole subtree
        canonical: Set[int] = set()
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            q = quals.get(id(node), node.name)
            homes = def_prints.get(fingerprint_def(node))
            if homes:
                fm = homes[0]
                if mod.path == fm.home and q == fm.qualname:
                    canonical.add(id(node))

        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if id(node) in canonical:
                continue
            q = quals.get(id(node), node.name)
            homes = def_prints.get(fingerprint_def(node))
            if homes:
                fm = homes[0]
                yield Finding(
                    self.name, mod.path, node.lineno, node.col_offset,
                    f"`{q}` re-implements registered formula "
                    f"[{fm.name}] {fm.home}::{fm.qualname} — import it "
                    f"instead ({fm.why})")
                continue  # don't also flag its interior expressions
            yield from self._check_exprs(mod, node, q, expr_prints,
                                         canonical, ctx)

    def _check_exprs(self, mod: Module, fn: ast.AST, q: str,
                     expr_prints: Dict[str, List[Formula]],
                     canonical: Set[int], ctx: AnalysisContext
                     ) -> Iterator[Finding]:
        if any(id(sub) in canonical for sub in ast.walk(fn)
               if sub is not fn):
            # a canonical home nested inside — handled at its own level
            return
        matched: Set[int] = set()
        for core in expr_cores(fn):
            if node_count(core) < ctx.config.min_expr_nodes:
                continue
            if any(id(a) in matched for a in ast.walk(core)):
                continue  # inside an already-matched expression
            homes = expr_prints.get(fingerprint_expr(core))
            if not homes:
                continue
            fm = homes[0]
            matched.update(id(n) for n in ast.walk(core))
            yield Finding(
                self.name, mod.path, core.lineno, core.col_offset,
                f"expression in `{q}` clones registered formula "
                f"[{fm.name}] {fm.home}::{fm.qualname} — call the "
                f"canonical implementation instead ({fm.why})")

    # ------------------------------------------------------------------
    def _formula_index(self, ctx: AnalysisContext
                       ) -> Optional[Tuple[Dict[str, List[Formula]],
                                           Dict[str, List[Formula]]]]:
        cached = getattr(ctx, "_parity_index", None)
        if cached is not None:
            return cached
        def_prints: Dict[str, List[Formula]] = {}
        expr_prints: Dict[str, List[Formula]] = {}
        for fm in ctx.config.formulas:
            home = ctx.load(fm.home)
            if home is None or home.tree is None:
                continue
            fn = _find_def(home.tree, fm.qualname)
            if fn is None:
                continue
            def_prints.setdefault(fingerprint_def(fn), []).append(fm)
            if not fm.expr_level:
                continue
            for core in expr_cores(fn):
                if node_count(core) < ctx.config.min_expr_nodes:
                    continue
                expr_prints.setdefault(fingerprint_expr(core),
                                       []).append(fm)
        result = (def_prints, expr_prints)
        ctx._parity_index = result
        return result
