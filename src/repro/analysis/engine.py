"""continuum-lint engine: files, suppressions, baseline, rule driver.

The engine is pure AST analysis — analyzed files are never imported, so
linting ``src tests benchmarks`` cannot execute repo code or require its
runtime dependencies.

Suppression syntax (a reason is mandatory — a suppression that does not
say why is itself a finding):

    x = risky()           # lint: ignore[rule-id] -- why this is fine
    # lint: ignore[rule-a,rule-b] -- a comment-only directive covers the
    # first code line after its comment block
    y = risky()

File-level (first 15 lines of the module):

    # lint: ignore-file[rule-id] -- why the whole file opts out

The baseline is a committed JSON file of grandfathered finding keys: a
key hashes (rule, path, source line text, occurrence index), so findings
survive unrelated line-number churn but die when the offending line is
edited.  ``--write-baseline`` refreshes it; new findings (not suppressed,
not baselined) are what fail CI.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

from repro.analysis.registry import FORMULAS, Formula

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*(ignore-file|ignore)"
    r"(?:\[([^\]]*)\])?"
    r"(?:\s*--\s*(\S.*?))?\s*$")

#: lines from the top of a file within which ``ignore-file`` is honored
_FILE_SUPPRESS_SPAN = 15


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str            # posix path relative to the analysis root
    line: int            # 1-based
    col: int             # 0-based
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: " \
               f"[{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class AnalysisConfig:
    """Knobs the rule passes consult (tests override these to point at
    fixture trees instead of the real repo layout)."""

    formulas: Tuple[Formula, ...] = FORMULAS
    #: path prefixes where swallowed-exception treats ANY broad catch as
    #: a finding (the serving/control hot paths)
    hot_paths: Tuple[str, ...] = ("src/repro/serving", "src/repro/core",
                                  "src/repro/cache")
    #: path prefixes that count as shipped library code (library-assert,
    #: swallowed-exception outside hot paths)
    library_roots: Tuple[str, ...] = ("src/repro",)
    #: minimum normalized-AST node count for an expression-level
    #: parity-drift match (whole-def matches have no floor)
    min_expr_nodes: int = 8

    def in_hot_path(self, path: str) -> bool:
        return any(path.startswith(p) for p in self.hot_paths)

    def in_library(self, path: str) -> bool:
        return any(path.startswith(p) for p in self.library_roots)


class Module:
    """One parsed source file plus its suppression directives."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree: Optional[ast.Module] = None
        self.syntax_error: Optional[str] = None
        #: line -> {rule: reason}
        self.line_suppressions: Dict[int, Dict[str, str]] = {}
        #: rule -> reason (whole file)
        self.file_suppressions: Dict[str, str] = {}
        self.bad_suppressions: List[Finding] = []
        try:
            self.tree = ast.parse(source)
        except SyntaxError as e:
            self.syntax_error = f"line {e.lineno}: {e.msg}"
        self._parse_suppressions()

    def _parse_suppressions(self) -> None:
        # Only genuine COMMENT tokens count — a suppression example quoted
        # inside a docstring must not suppress (or mis-parse as) anything.
        for i, text, col in self._comments():
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            kind, rules, reason = m.group(1), m.group(2), m.group(3)
            if not rules or not rules.strip() or not reason:
                self.bad_suppressions.append(Finding(
                    "bad-suppression", self.path, i, col,
                    "suppression needs an explicit rule list and a "
                    "reason: `# lint: ignore[rule] -- reason`"))
                continue
            names = [r.strip() for r in rules.split(",") if r.strip()]
            if kind == "ignore-file":
                if i > _FILE_SUPPRESS_SPAN:
                    self.bad_suppressions.append(Finding(
                        "bad-suppression", self.path, i, col,
                        f"ignore-file must appear in the first "
                        f"{_FILE_SUPPRESS_SPAN} lines"))
                    continue
                for r in names:
                    self.file_suppressions[r] = reason
                continue
            # A directive on a comment-only line covers the first CODE
            # line after the comment block (the reason may span several
            # comment lines); the directive's own line is covered too.
            targets = [i]
            if self.line_text(i)[:col].strip() == "":
                j = i + 1
                while (j <= len(self.lines)
                       and self.line_text(j).strip().startswith("#")):
                    j += 1
                targets.append(j)
            for t in targets:
                slot = self.line_suppressions.setdefault(t, {})
                for r in names:
                    slot[r] = reason

    def _comments(self):
        """Yield ``(line, comment_text, col)`` for every real comment
        token (tolerant of tokenize errors on partial sources)."""
        try:
            toks = tokenize.generate_tokens(
                io.StringIO(self.source).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    yield tok.start[0], tok.string, tok.start[1]
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return

    def suppression_for(self, finding: Finding) -> Optional[str]:
        if finding.rule in self.file_suppressions:
            return self.file_suppressions[finding.rule]
        per_line = self.line_suppressions.get(finding.line, {})
        return per_line.get(finding.rule)

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1]
        return ""


class AnalysisContext:
    """Shared state handed to every rule pass."""

    def __init__(self, root: Path, config: AnalysisConfig):
        self.root = root
        self.config = config
        self._cache: Dict[str, Optional[Module]] = {}

    def load(self, relpath: str) -> Optional[Module]:
        """Parse a module by repo-relative path (cached); None when the
        file does not exist.  Used by parity-drift to read a formula's
        canonical home even when it is outside the analyzed paths."""
        if relpath not in self._cache:
            p = self.root / relpath
            if not p.is_file():
                self._cache[relpath] = None
            else:
                self._cache[relpath] = Module(
                    relpath, p.read_text(encoding="utf-8"))
        return self._cache[relpath]


@dataclasses.dataclass
class Report:
    """Outcome of one analysis run, split by disposition."""

    findings: List[Finding]                    # new -> nonzero exit
    suppressed: List[Tuple[Finding, str]]      # (finding, reason)
    baselined: List[Finding]
    files: int
    keys: Dict[int, str] = dataclasses.field(default_factory=dict)

    @property
    def clean(self) -> bool:
        return not self.findings

    def stats(self) -> Dict:
        per_rule: Dict[str, int] = {}
        for f in self.findings:
            per_rule[f.rule] = per_rule.get(f.rule, 0) + 1
        return {
            "files": self.files,
            "new": len(self.findings),
            "suppressed": len(self.suppressed),
            "baselined": len(self.baselined),
            "per_rule": dict(sorted(per_rule.items())),
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "message": f.message, "key": self.keys.get(id(f), "")}
                for f in self.findings],
            "suppressions": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "reason": reason}
                for f, reason in self.suppressed],
        }


def finding_key(finding: Finding, line_text: str, occurrence: int) -> str:
    """Stable identity for baselining: survives line-number churn,
    invalidates when the offending line's text changes."""
    blob = f"{finding.rule}|{finding.path}|{line_text.strip()}|{occurrence}"
    return hashlib.sha1(blob.encode("utf-8")).hexdigest()[:16]


def load_baseline(path: Path) -> Dict[str, Dict]:
    """Baseline file -> {key: entry}; a missing file is an empty baseline."""
    if not path.is_file():
        return {}
    data = json.loads(path.read_text(encoding="utf-8"))
    return {e["key"]: e for e in data.get("findings", [])}


def write_baseline(path: Path, report: Report) -> None:
    """Grandfather every currently-live finding (new + already baselined)."""
    entries = []
    for f in report.findings + report.baselined:
        entries.append({
            "key": report.keys[id(f)],
            "rule": f.rule, "path": f.path, "line": f.line,
            "message": f.message,
        })
    entries.sort(key=lambda e: (e["path"], e["rule"], e["line"]))
    path.write_text(
        json.dumps({"version": 1, "findings": entries}, indent=2) + "\n",
        encoding="utf-8")


def iter_py_files(paths: Sequence[str], root: Path) -> Iterator[Path]:
    for raw in paths:
        p = (root / raw) if not Path(raw).is_absolute() else Path(raw)
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                parts = f.relative_to(p).parts
                if any(s.startswith(".") or s == "__pycache__"
                       for s in parts):
                    continue
                yield f


def run_analysis(paths: Sequence[str], root: Optional[Path] = None,
                 config: Optional[AnalysisConfig] = None,
                 baseline: Optional[Dict[str, Dict]] = None,
                 rules: Optional[Sequence] = None) -> Report:
    """Lint every ``*.py`` under ``paths`` (relative to ``root``)."""
    from repro.analysis.rules import ALL_RULES
    root = (root or Path.cwd()).resolve()
    config = config or AnalysisConfig()
    baseline = baseline or {}
    rules = list(rules) if rules is not None else list(ALL_RULES)
    ctx = AnalysisContext(root, config)

    modules: List[Module] = []
    seen = set()
    for f in iter_py_files(paths, root):
        f = f.resolve()
        if f in seen:
            continue
        seen.add(f)
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        mod = ctx.load(rel)
        if mod is not None:
            modules.append(mod)

    raw: List[Tuple[Module, Finding]] = []
    for mod in modules:
        if mod.syntax_error is not None:
            raw.append((mod, Finding("syntax-error", mod.path, 1, 0,
                                     mod.syntax_error)))
            continue
        for bad in mod.bad_suppressions:
            raw.append((mod, bad))
        for rule in rules:
            for finding in rule.check(mod, ctx):
                raw.append((mod, finding))

    report = Report(findings=[], suppressed=[], baselined=[],
                    files=len(modules))
    occ: Dict[Tuple[str, str, str], int] = {}
    for mod, finding in raw:
        reason = mod.suppression_for(finding)
        if reason is not None and finding.rule != "bad-suppression":
            report.suppressed.append((finding, reason))
            continue
        text = mod.line_text(finding.line)
        slot = (finding.rule, finding.path, text.strip())
        n = occ.get(slot, 0)
        occ[slot] = n + 1
        key = finding_key(finding, text, n)
        report.keys[id(finding)] = key
        if key in baseline:
            report.baselined.append(finding)
        else:
            report.findings.append(finding)
    report.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return report
