"""Model zoo: dense GQA transformers, MoE, RWKV6, Hymba hybrid + stubs."""
