"""Decoder-only transformer: dense GQA LM + the generic layer-stack driver.

The layer stack is the shared chassis for every family: ``forward`` runs a
layer function over stacked per-layer params either as one ``lax.scan``
step (O(1) HLO in depth — required for the 126-layer dry-run) or as a
python-unrolled loop (hymba: per-layer cache shapes differ). Caches are
pytrees; in scan mode their leaves carry a leading layer axis, in unrolled
mode the cache is a list of per-layer pytrees.

Modes:
  * ``train``   — full sequence, no cache, remat per layer.
  * ``prefill`` — full sequence; emits a filled KV cache.
  * ``decode``  — one token per sequence against the cache.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention
from repro.models.common import (ModelConfig, ParamSpec, Params, activate,
                                 apply_norm, apply_rope, chunked_softmax_xent,
                                 embed_tokens, layer_slice, norm_specs,
                                 stack_layers)
from repro.sharding import shd

Cache = Any  # pytree: dict of arrays (scan mode) or list of dicts (unrolled)


# --------------------------------------------------------------------------
# Parameter tables
# --------------------------------------------------------------------------


def _prefixed(prefix: str, table: Dict[str, ParamSpec]) -> Dict[str, ParamSpec]:
    return {prefix + k: v for k, v in table.items()}


def attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, Hq, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    t = {
        "wq": ParamSpec((d, Hq, Dh), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, Hkv, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, Hkv, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((Hq, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((Hq, Dh), ("heads", "head_dim"), "zeros")
        t["bk"] = ParamSpec((Hkv, Dh), ("kv_heads", "head_dim"), "zeros")
        t["bv"] = ParamSpec((Hkv, Dh), ("kv_heads", "head_dim"), "zeros")
    t.update(_prefixed("norm/", norm_specs(cfg)))
    return t


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    F = d_ff or cfg.d_ff
    t = {"wi": ParamSpec((d, F), ("embed", "ffn")),
         "wo": ParamSpec((F, d), ("ffn", "embed"))}
    if cfg.activation == "swiglu":
        t["wg"] = ParamSpec((d, F), ("embed", "ffn"))
    t.update(_prefixed("norm/", norm_specs(cfg)))
    return t


def dense_layer_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {**_prefixed("attn/", attn_specs(cfg)),
            **_prefixed("mlp/", mlp_specs(cfg))}


def head_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """Embedding + final norm + output head."""
    t = {
        # input table: rows gathered locally (embed dim sharded over model)
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab_in", "embed_table")),
        **_prefixed("final_norm/", norm_specs(cfg)),
    }
    if not cfg.tie_embeddings:
        # output head: vocab sharded over model (parallel logsumexp in CE)
        t["lm_head"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"))
    return t


def param_table(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {**head_specs(cfg),
            **stack_layers(dense_layer_specs(cfg), cfg.num_layers)}


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------


def _window_for_layer(cfg: ModelConfig, layer_idx: Optional[int]) -> Optional[int]:
    """Static per-layer sliding window (hymba: some layers are global)."""
    if cfg.sliding_window is None:
        return None
    if layer_idx is not None and layer_idx in cfg.global_layers:
        return None
    return cfg.sliding_window


def qkv_project(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array,
                prefix: str = "attn/"):
    """x (B,S,d) -> q (B,S,Hq,Dh), k/v (B,S,Hkv,Dh), rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p[prefix + "wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p[prefix + "bq"].astype(x.dtype)
        k = k + p[prefix + "bk"].astype(x.dtype)
        v = v + p[prefix + "bv"].astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shd(q, "batch", "seq", "heads", "head_dim")
    k = shd(k, "batch", "seq", "kv_heads", "head_dim")
    v = shd(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _cache_write(cache: Dict[str, jax.Array], k: jax.Array, v: jax.Array,
                 positions: jax.Array) -> Dict[str, jax.Array]:
    """Scatter new k/v (B,S,Hkv,Dh) at slots pos % W (rolling or full).

    For rolling caches only the last W tokens are written (earlier ones
    would be overwritten anyway; slicing keeps scatter slots unique).
    """
    W = cache["k"].shape[1]
    B, S = positions.shape
    if S > W:
        k, v, positions = k[:, -W:], v[:, -W:], positions[:, -W:]
        S = W
    slots = positions % W                                    # (B,S)
    b = jnp.arange(B)[:, None]
    new_k = cache["k"].at[b, slots].set(k.astype(cache["k"].dtype))
    new_v = cache["v"].at[b, slots].set(v.astype(cache["v"].dtype))
    new_pos = cache["pos"].at[b, slots].set(positions)
    return {"k": new_k, "v": new_v, "pos": new_pos}


def attention_block(cfg: ModelConfig, p: Params, x: jax.Array,
                    positions: jax.Array, cache: Optional[Dict[str, jax.Array]],
                    mode: str, layer_idx: Optional[int] = None,
                    prefix: str = "attn/", window_override=None):
    """Pre-norm attention residual branch. Returns (out, new_cache).

    ``window_override`` may be a *traced* per-layer width (scan-mode hymba:
    SWA layers vs global layers differ only in this predicate) — the lax
    mask path handles dynamic windows; the Pallas kernel needs it static.
    """
    window = (window_override if window_override is not None
              else _window_for_layer(cfg, layer_idx))
    h = apply_norm(cfg, p, prefix + "norm", x)
    if mode == "decode":
        # x: (B,1,d); cache holds the history INCLUDING this token after write.
        q, k, v = qkv_project(cfg, p, h, positions, prefix)
        cache = _cache_write(cache, k, v, positions)
        q1 = q[:, 0]                                          # (B,Hq,Dh)
        ck = shd(cache["k"], "batch", "cache_seq", "kv_heads", "head_dim")
        cv = shd(cache["v"], "batch", "cache_seq", "kv_heads", "head_dim")
        o = attention.decode_attention(cfg, q1, ck, cv, positions[:, 0],
                                       cache["pos"], window=window)
        o = o[:, None]                                        # (B,1,Hq,Dh)
    else:
        q, k, v = qkv_project(cfg, p, h, positions, prefix)
        o = attention.flash_attention(cfg, q, k, v, positions, positions,
                                      causal=True, window=window)
        if mode == "prefill":
            cache = _cache_write(cache, k, v, positions)
    o = shd(o, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bshk,hkd->bsd", o, p[prefix + "wo"].astype(x.dtype))
    return out, cache


def mlp_block(cfg: ModelConfig, p: Params, x: jax.Array,
              prefix: str = "mlp/", d_ff: Optional[int] = None) -> jax.Array:
    h = apply_norm(cfg, p, prefix + "norm", x)
    gate = jnp.einsum("bsd,df->bsf", h, p[prefix + "wi"].astype(x.dtype))
    gate = shd(gate, "batch", "seq", "ffn")
    up = None
    if cfg.activation == "swiglu":
        up = jnp.einsum("bsd,df->bsf", h, p[prefix + "wg"].astype(x.dtype))
        up = shd(up, "batch", "seq", "ffn")
    act = activate(cfg, gate, up)
    return jnp.einsum("bsf,fd->bsd", act, p[prefix + "wo"].astype(x.dtype))


def dense_layer(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array,
                cache, mode: str, layer_idx: Optional[int] = None,
                meta=None):
    a, cache = attention_block(cfg, p, x, positions, cache, mode, layer_idx)
    x = x + a
    x = x + mlp_block(cfg, p, x)
    x = shd(x, "batch", "seq", "embed")
    return x, cache, {}


# --------------------------------------------------------------------------
# Layer-stack driver (scan or unrolled), shared by all families
# --------------------------------------------------------------------------

LayerFn = Callable[..., Tuple[jax.Array, Any, Dict[str, jax.Array]]]


def layer_metadata(cfg: ModelConfig) -> Optional[Dict[str, jax.Array]]:
    """Per-layer static metadata as stacked arrays (scan-mode xs).

    Families whose layers differ only by *predicate* (hymba: SWA vs global
    attention) expose that difference here so the stack can still be one
    ``lax.scan`` step — O(1) HLO in depth — instead of a python unroll.
    """
    if cfg.family == "hymba" and cfg.sliding_window is not None:
        flags = jnp.asarray([i in cfg.global_layers
                             for i in range(cfg.num_layers)])
        return {"is_global": flags}
    return None


def _use_scan(cfg: ModelConfig, mode: str) -> bool:
    if mode == "train" and cfg.scan_layers_train is not None:
        return cfg.scan_layers_train
    return cfg.scan_layers


def _constrain_layer_params(cfg: ModelConfig, layer_params: Params) -> Params:
    """§Perf cell B: pin each weight slice's sharding at its use site.

    ``with_sharding_constraint`` transposes to the same constraint on the
    cotangent, so the per-layer weight grads materialize directly in the
    FSDP shard layout *inside* the backward scan — GSPMD then emits a
    reduce-scatter instead of a full all-reduce + slice per layer.
    """
    if not cfg.opt_weight_constraints:
        return layer_params
    from repro.sharding import get_param_rules
    rules = get_param_rules()
    if rules is None:
        return layer_params
    from repro.models import model_zoo
    table = model_zoo.param_table(cfg)
    out = {}
    for k, v in layer_params.items():
        spec = table.get("layers/" + k)
        if spec is None or len(spec.axes) - 1 != v.ndim:
            out[k] = v
            continue
        axes = spec.axes[1:]                    # drop the "layers" dim
        out[k] = jax.lax.with_sharding_constraint(
            v, rules.sharding(axes, v.shape))
    return out


def forward(cfg: ModelConfig, params: Params, embeds: jax.Array,
            positions: jax.Array, cache: Optional[Cache], mode: str,
            layer_fn: LayerFn = dense_layer):
    """Run the layer stack. Returns (hidden, new_cache, aux_sums).

    ``aux_sums`` accumulates per-layer scalars (MoE aux losses).
    """
    stacked, _ = layer_slice(params)
    x = embeds
    meta = layer_metadata(cfg)

    def one_layer(x, layer_params, layer_cache, layer_idx, layer_meta):
        layer_params = _constrain_layer_params(cfg, layer_params)
        return layer_fn(cfg, layer_params, x, positions, layer_cache, mode,
                        layer_idx, meta=layer_meta)

    if _use_scan(cfg, mode):
        def body(carry, xs):
            x, aux = carry
            layer_params, layer_cache, layer_meta = xs
            x, new_cache, a = one_layer(x, layer_params, layer_cache, None,
                                        layer_meta)
            aux = {k: aux.get(k, 0.0) + v for k, v in a.items()} if a else aux
            return (x, aux), new_cache

        aux0: Dict[str, jax.Array] = (
            {"moe_aux": jnp.float32(0.0), "router_z": jnp.float32(0.0)}
            if cfg.family == "moe" else {})
        G = cfg.remat_group if (cfg.remat and mode == "train") else 1
        if G > 1 and cfg.num_layers % G == 0:
            # two-level remat, scan-of-scans: HBM keeps only GROUP
            # boundaries (activations / G); the group's backward replays
            # the group forward, and each layer inside is itself
            # checkpointed so layer internals stay transient. Costs one
            # extra forward pass — the classic sqrt-ish remat trade.
            nG = cfg.num_layers // G
            grp = lambda v: v.reshape((nG, G) + v.shape[1:])
            xs2 = jax.tree.map(grp, (stacked, cache, meta))
            inner = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)

            def group_body(carry, gxs):
                return jax.lax.scan(inner, carry, gxs)

            group_body = jax.checkpoint(
                group_body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), new_cache = jax.lax.scan(group_body, (x, aux0), xs2)
            if new_cache is not None:
                new_cache = jax.tree.map(
                    lambda v: v.reshape((cfg.num_layers,) + v.shape[2:]),
                    new_cache)
        else:
            if cfg.remat and mode == "train":
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            (x, aux), new_cache = jax.lax.scan(body, (x, aux0),
                                               (stacked, cache, meta))
    else:
        aux: Dict[str, jax.Array] = {}
        new_cache = []
        for i in range(cfg.num_layers):
            layer_params = {k: v[i] for k, v in stacked.items()}
            layer_cache = cache[i] if cache is not None else None
            layer_meta = (jax.tree.map(lambda m: m[i], meta)
                          if meta is not None else None)
            fn = one_layer
            if cfg.remat and mode == "train":
                fn = jax.checkpoint(one_layer, static_argnums=(3,))
            x, c, a = fn(x, layer_params, layer_cache, i, layer_meta)
            new_cache.append(c)
            for k, v in (a or {}).items():
                aux[k] = aux.get(k, 0.0) + v
        if cache is None:
            new_cache = None
    return x, new_cache, aux


# --------------------------------------------------------------------------
# Top-level model functions (dense; other families override layer_fn)
# --------------------------------------------------------------------------


def assemble_embeds(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array]):
    """Token/frontend embeddings + positions.

    ``batch`` carries "tokens" (B,S) and, for vision frontends, "patches"
    (B,P,d) — precomputed patch embeddings prepended to the token stream
    (the assignment stubs the modality encoder). Audio frontends pass
    token ids over the EnCodec codebook (vocab_size=2048), i.e. plain LM.
    """
    emb = None
    if "tokens" in batch:
        emb = embed_tokens(params["embed"], batch["tokens"], cfg.compute_dtype)
    if "embeds" in batch:                      # fully precomputed stream
        e = batch["embeds"].astype(cfg.compute_dtype)
        emb = e if emb is None else jnp.concatenate([emb, e], axis=1)
    if "patches" in batch:                     # vision prefix
        p = batch["patches"].astype(cfg.compute_dtype)
        emb = p if emb is None else jnp.concatenate([p, emb], axis=1)
    B, S = emb.shape[0], emb.shape[1]
    offset = batch.get("offset")
    positions = jnp.arange(S, dtype=jnp.int32)[None, :] + (
        offset[:, None].astype(jnp.int32) if offset is not None else 0)
    positions = jnp.broadcast_to(positions, (B, S))
    emb = shd(emb, "batch", "seq", "embed")
    return emb, positions


def output_head(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    """Final norm + logits for the given hidden states."""
    x = apply_norm(cfg, params, "final_norm", x)
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if cfg.opt_bf16_dots:
        logits = jnp.einsum("bsd,vd->bsv", x, w.astype(x.dtype),
                            preferred_element_type=jnp.float32)
    else:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                            w.astype(jnp.float32))
    return shd(logits, "batch", "seq", "vocab")


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            layer_fn: LayerFn = dense_layer):
    """Mean-token CE over the batch (labels: next tokens; -1 = masked)."""
    emb, positions = assemble_embeds(cfg, params, batch)
    x, _, aux = forward(cfg, params, emb, positions, None, "train", layer_fn)
    x = apply_norm(cfg, params, "final_norm", x)
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    labels = batch["labels"]
    if x.shape[1] != labels.shape[1]:          # vision prefix: no labels there
        x = x[:, x.shape[1] - labels.shape[1]:]
    loss, count = chunked_softmax_xent(x, w, labels, cfg.ce_chunk,
                                       bf16_dots=cfg.opt_bf16_dots)
    metrics = {"loss": loss, "tokens": count}
    if aux:
        for k, v in aux.items():
            metrics[k] = v / cfg.num_layers
        loss = loss + cfg.router_aux_coef * metrics.get("moe_aux", 0.0)
    return loss, metrics


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               abstract: bool = False) -> Cache:
    """Allocate (or shape-spec) the KV cache.

    Layers with a sliding window get a rolling buffer of that width;
    global-attention layers get the full ``max_len``.
    """
    Hkv, Dh, L = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    dt = cfg.compute_dtype

    def one(width: int):
        kv = (batch_size, width, Hkv, Dh)
        ps = (batch_size, width)
        if abstract:
            return {"k": jax.ShapeDtypeStruct(kv, dt),
                    "v": jax.ShapeDtypeStruct(kv, dt),
                    "pos": jax.ShapeDtypeStruct(ps, jnp.int32)}
        return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
                "pos": jnp.full(ps, -1, jnp.int32)}

    def width_for(i: int) -> int:
        w = _window_for_layer(cfg, i)
        return max_len if w is None else min(w, max_len)

    if cfg.scan_layers:
        w = width_for(0)          # uniform by construction in scan mode
        per = one(w)
        if abstract:
            return {k: jax.ShapeDtypeStruct((L,) + v.shape, v.dtype)
                    for k, v in per.items()}
        return {k: jnp.broadcast_to(v, (L,) + v.shape).copy() if k != "pos"
                else jnp.broadcast_to(v, (L,) + v.shape).copy()
                for k, v in per.items()}
    return [one(width_for(i)) for i in range(L)]


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            cache: Cache, layer_fn: LayerFn = dense_layer,
            lengths: Optional[jax.Array] = None):
    """Full-sequence forward; fills the cache. Returns (last_logits, cache).

    ``lengths`` (B,) selects each row's true last prompt position when the
    batch is right-padded to a shared bucket length (causal masking keeps
    positions < length unaffected by the padding; padded cache positions
    carry pos > t and are masked until decode overwrites them).
    """
    emb, positions = assemble_embeds(cfg, params, batch)
    x, cache, _ = forward(cfg, params, emb, positions, cache, "prefill", layer_fn)
    if lengths is None:
        xl = x[:, -1:]
    else:
        idx = jnp.clip(jnp.asarray(lengths, jnp.int32) - 1, 0, x.shape[1] - 1)
        xl = jnp.take_along_axis(x, idx[:, None, None], axis=1)
    logits = output_head(cfg, params, xl)
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                tokens: jax.Array, t: jax.Array,
                layer_fn: LayerFn = dense_layer):
    """One decode step. tokens: (B,), t: (B,) current positions.

    Returns (logits (B,V), new_cache).
    """
    batch = {"tokens": tokens[:, None], "offset": t}
    emb, positions = assemble_embeds(cfg, params, batch)
    x, cache, _ = forward(cfg, params, emb, positions, cache, "decode", layer_fn)
    logits = output_head(cfg, params, x)
    return logits[:, 0], cache
