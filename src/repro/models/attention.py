"""Attention: GQA flash (chunked online softmax) + single-token decode.

Two execution paths per call site:

* **XLA path** (default; `cfg.use_pallas=False`) — the same blocked
  online-softmax algorithm as the Pallas kernel, expressed with
  ``lax.scan`` over KV chunks (and over Q chunks for long prefill). XLA
  fuses each chunk step; peak memory is O(q_chunk × kv_chunk) instead of
  O(S²). This is what the multi-pod dry-run lowers, so HLO cost analysis
  reflects the flash-style memory behaviour.
* **Pallas path** (`cfg.use_pallas=True`) — ``repro.kernels`` TPU kernels
  (validated on CPU in interpret mode), same math, MXU-aligned tiles.

Masking is positional: every query/key carries an absolute position;
causality, sliding windows (mixtral/hymba) and cache-slot validity
(position < 0 = empty slot) are all expressed as position predicates, so
prefill, decode and rolling caches share one mask rule.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

NEG_INF = -1e30


def _mask(q_pos: jax.Array, kv_pos: jax.Array, window: Optional[int],
          causal: bool) -> jax.Array:
    """(..., S_q, S_k) bool — True where attention is allowed.

    q_pos: (..., S_q), kv_pos: (..., S_k). Slots with kv_pos < 0 are invalid.
    """
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = kv_pos[..., None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return ok


def _layer_window(cfg: ModelConfig, layer_idx: Optional[jax.Array]) -> Optional[int]:
    """Static sliding-window width for this layer (None = full)."""
    del layer_idx
    return cfg.sliding_window


# --------------------------------------------------------------------------
# Flash attention over full sequences (training / prefill)
# --------------------------------------------------------------------------


def flash_attention(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos: jax.Array, kv_pos: jax.Array, *,
                    causal: bool = True,
                    window: Optional[int] = None) -> jax.Array:
    """Blocked online-softmax attention with GQA.

    Args:
      q: (B, S, Hq, D); k, v: (B, T, Hkv, D).
      q_pos: (B, S) absolute positions; kv_pos: (B, T).
      window: sliding-window width (None = dense causal).
    Returns:
      (B, S, Hq, D) in q.dtype.
    """
    if cfg.use_pallas:
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, q_pos, kv_pos, causal=causal,
                                   window=window, softcap=cfg.attn_logit_softcap)
    return _flash_lax(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                      kv_chunk=cfg.attn_chunk, q_chunk=cfg.q_chunk,
                      softcap=cfg.attn_logit_softcap,
                      bf16_dots=cfg.opt_bf16_dots)


def _flash_lax(q, k, v, q_pos, kv_pos, *, causal, window, kv_chunk, q_chunk,
               softcap=None, bf16_dots=False):
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    kv_chunk = min(kv_chunk, T)
    q_chunk = min(q_chunk, S)
    # Pad T to a multiple of kv_chunk with invalid slots (pos = -1).
    pad_t = (-T) % kv_chunk
    if pad_t:
        k = jnp.pad(k, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_t)), constant_values=-1)
    Tp = T + pad_t
    nk = Tp // kv_chunk
    pad_s = (-S) % q_chunk
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_s)), constant_values=-1)
    Sp = S + pad_s
    nq = Sp // q_chunk

    # bf16_dots (§Perf): operands stay in their storage dtype; the MXU
    # accumulates in fp32 via preferred_element_type — no materialized
    # fp32 copies of q/k/v chunks.
    in_dt = q.dtype if bf16_dots else jnp.float32
    qg = q.reshape(B, nq, q_chunk, Hkv, G, D).astype(in_dt)
    kc = k.reshape(B, nk, kv_chunk, Hkv, D).astype(in_dt)
    vc = v.reshape(B, nk, kv_chunk, Hkv, D).astype(in_dt)
    qp = q_pos.reshape(B, nq, q_chunk)
    kp = kv_pos.reshape(B, nk, kv_chunk)

    def q_step(_, qi):
        qblk = qg[:, qi]                       # (B,c,Hkv,G,D)
        qpb = qp[:, qi]                        # (B,c)

        def kv_step(carry, inp):
            num, den, m = carry
            kblk, vblk, kpb = inp              # (B,kc,Hkv,D), (B,kc)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            ok = _mask(qpb, kpb, window, causal)              # (B,c,kc)
            s = jnp.where(ok[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))       # (B,Hkv,G,c)
            # Fully-masked-so-far rows keep m_new = NEG_INF; guard the
            # exp(NEG_INF - NEG_INF) = nan corner.
            alive = m_new > NEG_INF / 2
            p = jnp.where(alive[..., None], jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.where(alive, jnp.exp(m - m_new), 1.0)
            num = num * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(in_dt), vblk,
                preferred_element_type=jnp.float32)
            den = den * corr + jnp.sum(p, axis=-1)
            return (num, den, m_new), None

        num0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        den0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        (num, den, _), _ = jax.lax.scan(
            kv_step, (num0, den0, m0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), kp.swapaxes(0, 1)))
        out = num / jnp.maximum(den[..., None], 1e-30)        # (B,Hkv,G,c,D)
        return None, out.transpose(0, 3, 1, 2, 4)             # (B,c,Hkv,G,D)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))      # (nq,B,c,Hkv,G,D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, Hq, D)
    return out[:, :S].astype(q.dtype)


# --------------------------------------------------------------------------
# Decode attention (one new token vs a filled KV cache)
# --------------------------------------------------------------------------


def decode_attention(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                     q_pos: jax.Array, kv_pos: jax.Array, *,
                     window: Optional[int] = None) -> jax.Array:
    """Single-position attention: q (B, Hq, D) vs cache k/v (B, T, Hkv, D).

    q_pos: (B,) absolute position of the new token; kv_pos: (B, T) absolute
    positions of cache slots (-1 = empty; rolling caches leave these
    unordered — the mask doesn't care).
    Returns (B, Hq, D).
    """
    if cfg.use_pallas:
        from repro.kernels import ops
        return ops.decode_attention(q, k, v, q_pos, kv_pos, window=window,
                                    softcap=cfg.attn_logit_softcap)
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = D ** -0.5
    # bf16_dots (§Perf): the cache is the dominant memory stream in decode;
    # reading it through a bf16 dot (fp32 accumulation) instead of a
    # materialized .astype(f32) copy removes ~3x of the per-token traffic.
    in_dt = k.dtype if cfg.opt_bf16_dots else jnp.float32
    qf = q.reshape(B, Hkv, G, D).astype(in_dt)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(in_dt),
                   preferred_element_type=jnp.float32) * scale
    if cfg.attn_logit_softcap is not None:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    ok = _mask(q_pos[:, None], kv_pos, window, causal=True)[:, 0]   # (B,T)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(in_dt), v.astype(in_dt),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Reference (naive) attention — oracle for tests
# --------------------------------------------------------------------------


def reference_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                        softcap=None) -> jax.Array:
    """O(S²) materialized-scores oracle, fp32."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bskgd,btkd->bkgst", qf, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    ok = _mask(q_pos, kv_pos, window, causal)          # (B,S,T)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key: softmax of all -inf -> uniform; zero them.
    any_ok = jnp.any(ok, axis=-1)[:, None, None, :, None]
    p = jnp.where(any_ok, p, 0.0)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)
