"""Hymba — hybrid-head LM: attention and SSM heads run *in parallel* in
every layer (arXiv:2411.13676), outputs mean-fused after per-branch norm.

Assignment config: 32L, d=1600, 25 attention heads (kv=5), ssm_state=16,
d_ff=5504. Most layers use sliding-window attention; ``global_layers``
(first / middle / last, per the paper) keep full attention. Meta tokens are
out of scope (noted in DESIGN.md) — the backbone is what the assignment
specifies.

Because SWA layers carry a rolling KV cache and global layers a full-length
cache, per-layer cache shapes differ -> this family sets
``scan_layers=False`` (python-unrolled stack; 32 small layers keep the HLO
manageable).
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import ssm, transformer
from repro.models.common import (ModelConfig, ParamSpec, Params, apply_norm,
                                 norm_specs, stack_layers)
from repro.sharding import shd


def layer_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    t = {**{f"attn/{k}": v for k, v in transformer.attn_specs(cfg).items()},
         **{f"ssm/{k}": v for k, v in ssm.ssm_specs(cfg, d).items()},
         **{f"mlp/{k}": v for k, v in transformer.mlp_specs(cfg).items()}}
    # per-branch output norms (the paper normalizes before averaging)
    t["attn_out_norm/scale"] = ParamSpec((d,), ("embed",), "ones")
    t["ssm_out_norm/scale"] = ParamSpec((d,), ("embed",), "ones")
    return t


def param_table(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {**transformer.head_specs(cfg),
            **stack_layers(layer_specs(cfg), cfg.num_layers)}


def hymba_layer(cfg: ModelConfig, p: Params, x: jax.Array,
                positions: jax.Array, cache, mode: str,
                layer_idx: Optional[int] = None, meta=None):
    """cache = {"k","v","pos" (attention), "h","conv" (ssm)} or None."""
    attn_cache = None
    ssm_state = None
    if cache is not None:
        attn_cache = {k: cache[k] for k in ("k", "v", "pos")}
        ssm_state = {"h": cache["h"], "conv": cache["conv"]}
    else:
        ssm_state = ssm.init_state(cfg, x.shape[0])

    # scan-mode (layer_idx unknown statically): the SWA-vs-global split is
    # a traced per-layer predicate from layer_metadata — global layers get
    # an effectively-unbounded window
    window_override = None
    if layer_idx is None and meta is not None and cfg.sliding_window is not None:
        window_override = jnp.where(meta["is_global"], jnp.int32(2 ** 30),
                                    jnp.int32(cfg.sliding_window))

    # --- parallel heads: attention + SSM on the same normalized input ----
    a, attn_cache = transformer.attention_block(
        cfg, p, x, positions, attn_cache, mode, layer_idx,
        window_override=window_override)
    s, ssm_new = ssm.ssm_block(cfg, p, x, ssm_state, mode)
    from repro.models.common import rms_norm
    fused = 0.5 * (rms_norm(a, p["attn_out_norm/scale"], cfg.norm_eps)
                   + rms_norm(s, p["ssm_out_norm/scale"], cfg.norm_eps))
    x = x + fused
    x = x + transformer.mlp_block(cfg, p, x)
    x = shd(x, "batch", "seq", "embed")

    new_cache = None
    if cache is not None:
        new_cache = {**attn_cache, "h": ssm_new["h"], "conv": ssm_new["conv"]}
    elif mode == "prefill":
        new_cache = None
    return x, new_cache, {}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False):
    """Per-layer list (unrolled stack): rolling KV for SWA layers, full KV
    for global layers, plus the SSM state."""
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim
    out = []
    for i in range(cfg.num_layers):
        w = cfg.sliding_window if (cfg.sliding_window is not None
                                   and i not in cfg.global_layers) else None
        width = max_len if w is None else min(w, max_len)
        kv = (batch, width, Hkv, Dh)
        ps = (batch, width)
        st = ssm.init_state(cfg, batch, abstract=abstract)
        if abstract:
            out.append({"k": jax.ShapeDtypeStruct(kv, cfg.compute_dtype),
                        "v": jax.ShapeDtypeStruct(kv, cfg.compute_dtype),
                        "pos": jax.ShapeDtypeStruct(ps, jnp.int32), **st})
        else:
            out.append({"k": jnp.zeros(kv, cfg.compute_dtype),
                        "v": jnp.zeros(kv, cfg.compute_dtype),
                        "pos": jnp.full(ps, -1, jnp.int32), **st})
    return out
