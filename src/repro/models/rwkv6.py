"""RWKV6 "Finch" — attention-free LM with data-dependent per-channel decay.

Core recurrence, per head (key dim i, value dim j):

    y_t[j] = sum_i r_t[i] * ( S_{t-1}[i,j] + u[i] * k_t[i] * v_t[j] )
    S_t[i,j] = w_t[i] * S_{t-1}[i,j] + k_t[i] * v_t[j],   w_t = exp(-exp(x_w))

Execution paths:

* **Chunked (train/prefill, the MXU path).** Sequences are processed in
  chunks; within a chunk the recurrence is re-expressed as three matmuls
  using *log-space decay differences* (every exponent is a sum of log-decays
  over a non-empty suffix, hence <= 0 — no overflow, no 1/P underflow that
  plagues the textbook "divide by cumulative decay" form):

      L_t   = sum_{tau<t} log w_tau                      (exclusive cumsum)
      y_t   = (r_t . e^{L_t}) @ S_0                       inter-chunk
            + sum_{s<t} [sum_i r_t[i] k_s[i] e^{L_t[i]-L_{s+1}[i]}] v_s
            + (sum_i r_t[i] u[i] k_t[i]) v_t              bonus diagonal
      S_c   = e^{L_c} . S_0 + (k . e^{L_c - L_{s+1}})^T @ v

  ``lax.scan`` carries S across chunks, so the saved residuals are one
  (B,H,D,D) state per chunk instead of per token.
* **Recurrent (decode / oracle).** The literal per-token recurrence:
  O(1) state, which is why this arch runs the 500k-token decode cell.
* **Pallas kernel** (``kernels/rwkv6_scan.py``): same chunked math with the
  state held in VMEM scratch across the sequential grid dimension.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import (ModelConfig, ParamSpec, Params, layer_norm,
                                 norm_specs, stack_layers)
from repro.sharding import shd

LORA_MIX = 32      # rank of the token-shift mixing LoRA
LORA_DECAY = 64    # rank of the decay LoRA


# --------------------------------------------------------------------------
# Parameter table
# --------------------------------------------------------------------------


def layer_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, H, D, F = cfg.d_model, cfg.num_rwkv_heads, cfg.rwkv_head_dim, cfg.d_ff
    n = lambda: {f"norm/{k}": v for k, v in norm_specs(cfg).items()}
    t = {
        # --- time mix -------------------------------------------------
        "tm/mu_x": ParamSpec((d,), ("embed",), "uniform_pm", 0.5),
        "tm/mu5": ParamSpec((5, d), (None, "embed"), "uniform_pm", 0.5),
        "tm/lora_w1": ParamSpec((d, 5 * LORA_MIX), ("embed", None), scale=0.1),
        "tm/lora_w2": ParamSpec((5, LORA_MIX, d), (None, None, "embed"), scale=0.1),
        "tm/w0": ParamSpec((H, D), ("heads", "head_dim"), "const", -5.0),
        "tm/decay_a": ParamSpec((d, LORA_DECAY), ("embed", None), scale=0.1),
        "tm/decay_b": ParamSpec((LORA_DECAY, H, D), (None, "heads", "head_dim"),
                                scale=0.1),
        "tm/u": ParamSpec((H, D), ("heads", "head_dim"), "uniform_pm", 0.5),
        "tm/wr": ParamSpec((d, H, D), ("embed", "heads", "head_dim")),
        "tm/wk": ParamSpec((d, H, D), ("embed", "heads", "head_dim")),
        "tm/wv": ParamSpec((d, H, D), ("embed", "heads", "head_dim")),
        "tm/wg": ParamSpec((d, H, D), ("embed", "heads", "head_dim")),
        "tm/wo": ParamSpec((H, D, d), ("heads", "head_dim", "embed")),
        "tm/ln_scale": ParamSpec((H, D), ("heads", "head_dim"), "ones"),
        "tm/ln_bias": ParamSpec((H, D), ("heads", "head_dim"), "zeros"),
        **{f"tm/{k}": v for k, v in n().items()},
        # --- channel mix ------------------------------------------------
        "cm/mu_k": ParamSpec((d,), ("embed",), "uniform_pm", 0.5),
        "cm/mu_r": ParamSpec((d,), ("embed",), "uniform_pm", 0.5),
        "cm/wk": ParamSpec((d, F), ("embed", "ffn")),
        "cm/wv": ParamSpec((F, d), ("ffn", "embed")),
        "cm/wr": ParamSpec((d, d), ("embed", None)),
        **{f"cm/{k}": v for k, v in n().items()},
    }
    return t


def param_table(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {**transformer.head_specs(cfg),
            **stack_layers(layer_specs(cfg), cfg.num_layers)}


# --------------------------------------------------------------------------
# WKV core
# --------------------------------------------------------------------------


def wkv_chunked(r, k, v, lw, u, s0, chunk: int):
    """Chunked WKV. r,k,v,lw: (B,S,H,D) fp32 (lw = log decay <= 0);
    u: (H,D); s0: (B,H,D,D). Returns (y (B,S,H,D), s_final)."""
    B, S, H, D = r.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        raise ValueError(f"seq len {S} is not divisible by chunk {chunk}")
    n = S // chunk
    rc = r.reshape(B, n, chunk, H, D).transpose(1, 0, 3, 2, 4)  # (n,B,H,c,D)
    kc = k.reshape(B, n, chunk, H, D).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(B, n, chunk, H, D).transpose(1, 0, 3, 2, 4)
    wc = lw.reshape(B, n, chunk, H, D).transpose(1, 0, 3, 2, 4)

    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # s<t

    def step(s, inp):
        rb, kb, vb, wb = inp                         # (B,H,c,D)
        Lincl = jnp.cumsum(wb, axis=2)               # L_{t+1} = sum_{tau<=t}
        L = Lincl - wb                               # exclusive: L_t
        Lend = Lincl[:, :, -1:, :]                   # (B,H,1,D)
        # inter-chunk
        y_inter = jnp.einsum("bhtd,bhde->bhte", rb * jnp.exp(L), s)
        # intra-chunk pairwise: exponent L_t - L_{s+1} (<=0 where s<t)
        diff = L[:, :, :, None, :] - Lincl[:, :, None, :, :]   # (B,H,t,s,D)
        A = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rb, kb,
                       jnp.exp(jnp.minimum(diff, 0.0)))
        A = A * causal
        y_intra = jnp.einsum("bhts,bhse->bhte", A, vb)
        # bonus diagonal
        du = jnp.einsum("bhtd,hd,bhtd->bht", rb, u, kb)
        y_diag = du[..., None] * vb
        y = y_inter + y_intra + y_diag
        # state to next chunk
        kd = kb * jnp.exp(jnp.minimum(Lend - Lincl, 0.0))      # (B,H,c,D)
        s_new = jnp.exp(Lend)[:, :, 0, :, None] * s + \
            jnp.einsum("bhtd,bhte->bhde", kd, vb)
        return s_new, y

    s_fin, ys = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)
    return y, s_fin


def wkv_recurrent_step(r, k, v, lw, u, s):
    """One token. r,k,v,lw: (B,H,D); s: (B,H,D,D). Returns (y, s')."""
    kv = k[..., :, None] * v[..., None, :]                     # (B,H,D,D)
    y = jnp.einsum("bhd,bhde->bhe", r, s + u[..., :, None] * kv)
    s_new = jnp.exp(lw)[..., :, None] * s + kv
    return y, s_new


def wkv_recurrent(r, k, v, lw, u, s0):
    """Oracle: literal per-token scan. Same signature as wkv_chunked."""
    def step(s, inp):
        rt, kt, vt, wt = inp
        y, s = wkv_recurrent_step(rt, kt, vt, wt, u, s)
        return s, y
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, lw))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), s_fin


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------


def _token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """(B,S,d), (B,d) -> previous-token stream (B,S,d)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def time_mix(cfg: ModelConfig, p: Params, x: jax.Array, state, mode: str):
    """RWKV6 attention analogue. state = {"x": (B,d), "s": (B,H,D,D)}."""
    B, S, d = x.shape
    H, D = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    from repro.models.common import apply_norm
    h = apply_norm(cfg, p, "tm/norm", x)
    xprev = _token_shift(h, state["x"]) if mode != "decode" else \
        state["x"][:, None].astype(h.dtype)
    if mode == "decode":
        xprev = jnp.broadcast_to(xprev, h.shape)
    dx = xprev - h
    xxx = h + dx * p["tm/mu_x"].astype(h.dtype)
    mix = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["tm/lora_w1"].astype(h.dtype)))
    mix = mix.reshape(B, S, 5, LORA_MIX)
    off = jnp.einsum("bsmr,mrd->mbsd", mix, p["tm/lora_w2"].astype(h.dtype))
    mu5 = p["tm/mu5"].astype(h.dtype)                          # (5,d)
    xr, xk, xv, xw, xg = [h + dx * (mu5[i] + off[i]) for i in range(5)]

    proj = lambda t, w: jnp.einsum("bsd,dhk->bshk", t, p[w].astype(h.dtype))
    r = shd(proj(xr, "tm/wr"), "batch", "seq", "heads", "head_dim")
    k = shd(proj(xk, "tm/wk"), "batch", "seq", "heads", "head_dim")
    v = shd(proj(xv, "tm/wv"), "batch", "seq", "heads", "head_dim")
    g = shd(proj(xg, "tm/wg"), "batch", "seq", "heads", "head_dim")
    # data-dependent log-decay, guaranteed < 0: lw = -exp(w0 + lora)
    dlo = jnp.einsum("bsd,dr->bsr", xw, p["tm/decay_a"].astype(h.dtype))
    dexp = p["tm/w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rhk->bshk", jnp.tanh(dlo), p["tm/decay_b"]).astype(jnp.float32)
    lw = -jnp.exp(jnp.clip(dexp, -20.0, 10.0))
    u = p["tm/u"].astype(jnp.float32)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    s0 = state["s"].astype(jnp.float32)
    if mode == "decode":
        y1, s_new = wkv_recurrent_step(rf[:, 0], kf[:, 0], vf[:, 0],
                                       lw[:, 0], u, s0)
        y = y1[:, None]
    elif cfg.use_pallas:
        from repro.kernels import ops
        y, s_new = ops.rwkv6_scan(rf, kf, vf, lw, u, s0)
    else:
        y, s_new = wkv_chunked(rf, kf, vf, lw, u, s0, chunk=32)

    # per-head group norm
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yn * p["tm/ln_scale"].astype(jnp.float32) + \
        p["tm/ln_bias"].astype(jnp.float32)
    yn = (yn * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", yn, p["tm/wo"].astype(x.dtype))
    new_state = {"x": h[:, -1].astype(state["x"].dtype), "s": s_new}
    return out, new_state


def _rms(cfg, p, prefix, x):
    from repro.models.common import rms_norm
    return rms_norm(x, p[prefix + "/scale"], cfg.norm_eps)


def channel_mix(cfg: ModelConfig, p: Params, x: jax.Array, state, mode: str):
    """RWKV6 FFN analogue. state = {"x": (B,d)}."""
    from repro.models.common import apply_norm
    h = apply_norm(cfg, p, "cm/norm", x)
    xprev = _token_shift(h, state["x"]) if mode != "decode" else \
        jnp.broadcast_to(state["x"][:, None].astype(h.dtype), h.shape)
    dx = xprev - h
    xk = h + dx * p["cm/mu_k"].astype(h.dtype)
    xr = h + dx * p["cm/mu_r"].astype(h.dtype)
    kh = jnp.einsum("bsd,df->bsf", xk, p["cm/wk"].astype(h.dtype))
    kh = shd(kh, "batch", "seq", "ffn")
    kh = jnp.square(jax.nn.relu(kh))
    kv = jnp.einsum("bsf,fd->bsd", kh, p["cm/wv"].astype(h.dtype))
    rgate = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                      p["cm/wr"].astype(h.dtype)))
    out = rgate * kv
    return out, {"x": h[:, -1].astype(state["x"].dtype)}


def rwkv_layer(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array,
               cache, mode: str, layer_idx: Optional[int] = None, meta=None):
    """cache = {"tm_x": (B,d), "tm_s": (B,H,D,D), "cm_x": (B,d)} or None."""
    del positions, layer_idx
    B = x.shape[0]
    H, D = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    if cache is None:
        st = init_layer_state(cfg, B)
    else:
        st = cache
    tm_state = {"x": st["tm_x"], "s": st["tm_s"]}
    a, tm_new = time_mix(cfg, p, x, tm_state, mode)
    x = x + a
    cm_state = {"x": st["cm_x"]}
    m, cm_new = channel_mix(cfg, p, x, cm_state, mode)
    x = x + m
    x = shd(x, "batch", "seq", "embed")
    new_cache = None if cache is None else {
        "tm_x": tm_new["x"], "tm_s": tm_new["s"].astype(st["tm_s"].dtype),
        "cm_x": cm_new["x"]}
    return x, new_cache, {}


def init_layer_state(cfg: ModelConfig, batch: int):
    H, D = cfg.num_rwkv_heads, cfg.rwkv_head_dim
    return {"tm_x": jnp.zeros((batch, cfg.d_model), cfg.compute_dtype),
            "tm_s": jnp.zeros((batch, H, D, D), jnp.float32),
            "cm_x": jnp.zeros((batch, cfg.d_model), cfg.compute_dtype)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False):
    """State cache (constant size — no growth with context length)."""
    del max_len
    H, D, L, d = cfg.num_rwkv_heads, cfg.rwkv_head_dim, cfg.num_layers, cfg.d_model
    shapes = {"tm_x": ((L, batch, d), cfg.compute_dtype),
              "tm_s": ((L, batch, H, D, D), jnp.float32),
              "cm_x": ((L, batch, d), cfg.compute_dtype)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, t) for k, (s, t) in shapes.items()}
    return {k: jnp.zeros(s, t) for k, (s, t) in shapes.items()}
