"""Mixture-of-Experts FFN (qwen2-moe, mixtral) — TPU-native dispatch.

Adaptation notes (GPU MoE -> TPU, recorded per the brief):

* Dispatch is **shard-local**: tokens are grouped by sequence (the group
  axis is the batch axis, which is data-sharded), each group does its own
  capacity accounting, and every expert processes its group-local slice.
  No token ever crosses a data shard, so the only collectives are the
  existing tensor-parallel psums on the expert FFN — the TPU-idiomatic
  replacement for GPU all-to-all dispatch.
* The (tokens, experts, capacity) one-hot dispatch tensor of GShard is
  never materialized; dispatch/combine are segment-sum scatters and row
  gathers bounded by O(tokens x d_model).
* Capacity: per group, ``C = ceil(S * top_k * capacity_factor / E)``;
  overflow tokens drop that expert's contribution (keep their other
  experts), standard capacity semantics. The router aux loss (GShard)
  keeps assignment balanced so drops are rare; tests cover both regimes.
* Shared experts (qwen2-moe) are a fused dense FFN applied to every token.

Expert-parallel (experts sharded over "model") is a config option in
``launch/sharding.py`` when ``num_experts % model_axis == 0``; the default
keeps experts replicated and TP-shards each expert's ``d_ff``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import (ModelConfig, ParamSpec, Params, activate,
                                 apply_norm, norm_specs)
from repro.sharding import shd


def moe_ffn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    t = {
        "router": ParamSpec((d, E), ("embed", None)),
        "experts/wi": ParamSpec((E, d, F), ("experts", "embed", "ffn")),
        "experts/wo": ParamSpec((E, F, d), ("experts", "ffn", "embed")),
    }
    if cfg.activation == "swiglu":
        t["experts/wg"] = ParamSpec((E, d, F), ("experts", "embed", "ffn"))
    if cfg.num_shared_experts > 0:
        Fs = cfg.shared_d_ff or cfg.num_shared_experts * F
        t["shared/wi"] = ParamSpec((d, Fs), ("embed", "ffn"))
        t["shared/wo"] = ParamSpec((Fs, d), ("ffn", "embed"))
        if cfg.activation == "swiglu":
            t["shared/wg"] = ParamSpec((d, Fs), ("embed", "ffn"))
        t["shared/gate"] = ParamSpec((d, 1), ("embed", None), "zeros")
    t.update({f"norm/{k}": v for k, v in norm_specs(cfg).items()})
    return t


def moe_layer_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {**{f"attn/{k}": v for k, v in transformer.attn_specs(cfg).items()},
            **{f"moe/{k}": v for k, v in moe_ffn_specs(cfg).items()}}


def param_table(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    from repro.models.common import stack_layers
    return {**transformer.head_specs(cfg),
            **stack_layers(moe_layer_specs(cfg), cfg.num_layers)}


def _capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = math.ceil(group_tokens * cfg.top_k * cfg.capacity_factor
                  / max(cfg.num_experts, 1))
    return max(int(c), 1)


def moe_ffn(cfg: ModelConfig, p: Params, x: jax.Array,
            prefix: str = "moe/") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Routed FFN. x: (B, S, d) -> (B, S, d), aux losses.

    Groups = batch rows (data-sharded); all dispatch is group-local.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    F = cfg.moe_d_ff or cfg.d_ff
    C = _capacity(cfg, S)

    h = apply_norm(cfg, p, prefix + "norm", x)

    # ---- routing (fp32) ------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32),
                        p[prefix + "router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalize

    # ---- aux losses (GShard load-balance + router z) -------------------
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    assign = jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(assign, axis=(0, 1))                         # top-1 fraction
    aux = jnp.sum(me * ce) * E
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- capacity accounting, per group --------------------------------
    # position of each (token, k) slot within its expert's group-local queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)      # (B,S,K,E)
    flat = onehot.reshape(B, S * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                 # (B,S*K,E)
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(B, S, K)   # (B,S,K)
    keep = pos < C
    dest = jnp.where(keep, gate_idx * C + pos, E * C)          # overflow slot

    # ---- dispatch: segment-sum into (B, E*C+1, d) -----------------------
    hk = jnp.broadcast_to(h[:, :, None, :], (B, S, K, d)).reshape(B, S * K, d)
    destf = dest.reshape(B, S * K)

    def scatter_one(rows, idx):
        return jax.ops.segment_sum(rows, idx, num_segments=E * C + 1)

    expert_in = jax.vmap(scatter_one)(hk, destf)               # (B,E*C+1,d)
    expert_in = expert_in[:, :E * C].reshape(B, E, C, d)
    expert_in = shd(expert_in, "batch", "experts", None, "embed")

    # ---- expert FFN (batched einsum; F is TP-sharded) --------------------
    wi = p[prefix + "experts/wi"].astype(x.dtype)
    wo = p[prefix + "experts/wo"].astype(x.dtype)
    gate_h = jnp.einsum("becd,edf->becf", expert_in.astype(x.dtype), wi)
    gate_h = shd(gate_h, "batch", "experts", None, "ffn")
    up_h = None
    if cfg.activation == "swiglu":
        wg = p[prefix + "experts/wg"].astype(x.dtype)
        up_h = jnp.einsum("becd,edf->becf", expert_in.astype(x.dtype), wg)
        up_h = shd(up_h, "batch", "experts", None, "ffn")
    act = activate(cfg, gate_h, up_h)
    expert_out = jnp.einsum("becf,efd->becd", act, wo)          # (B,E,C,d)
    expert_out = expert_out.reshape(B, E * C, d)
    expert_out = jnp.pad(expert_out, ((0, 0), (0, 1), (0, 0)))  # overflow row=0

    # ---- combine: gather rows back, weight by gate ----------------------
    def gather_one(rows, idx):
        return rows[idx]                                        # (S*K, d)

    back = jax.vmap(gather_one)(expert_out, destf).reshape(B, S, K, d)
    y = jnp.sum(back.astype(jnp.float32)
                * gate_vals[..., None].astype(jnp.float32), axis=2)
    y = y.astype(x.dtype)

    # ---- shared experts (qwen2-moe) -------------------------------------
    if cfg.num_shared_experts > 0:
        gate_s = jnp.einsum("bsd,df->bsf", h, p[prefix + "shared/wi"].astype(x.dtype))
        up_s = None
        if cfg.activation == "swiglu":
            up_s = jnp.einsum("bsd,df->bsf", h,
                              p[prefix + "shared/wg"].astype(x.dtype))
        act_s = activate(cfg, gate_s, up_s)
        shared = jnp.einsum("bsf,fd->bsd", act_s,
                            p[prefix + "shared/wo"].astype(x.dtype))
        sg = jax.nn.sigmoid(jnp.einsum("bsd,do->bso", h.astype(jnp.float32),
                                       p[prefix + "shared/gate"].astype(jnp.float32)))
        y = y + (shared.astype(jnp.float32) * sg).astype(x.dtype)

    return y, {"moe_aux": aux, "router_z": z}


def moe_layer(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array,
              cache, mode: str, layer_idx: Optional[int] = None, meta=None):
    a, cache = transformer.attention_block(cfg, p, x, positions, cache, mode,
                                           layer_idx)
    x = x + a
    m, aux = moe_ffn(cfg, p, x)
    x = x + m
    x = shd(x, "batch", "seq", "embed")
    return x, cache, aux
