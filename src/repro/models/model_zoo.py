"""Uniform model API over all families.

Every family exposes the same six entry points, keyed by
``cfg.family``:

    param_table(cfg)                  -> {path: ParamSpec}
    init(key, cfg)                    -> params
    loss(cfg, params, batch)          -> (loss, metrics)        # train step body
    prefill(cfg, params, batch, cache)-> (last_logits, cache)
    decode(cfg, params, cache, tok, t)-> (logits, cache)
    init_cache(cfg, batch, max_len)   -> cache pytree (abstract= for dry-run)

The serving engine, trainer, dry-run and tests all go through this table —
adding an architecture is one config module + (optionally) one layer fn.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax

from repro.models import common, hymba, moe, rwkv6, transformer
from repro.models.common import ModelConfig, Params


class Family:
    def __init__(self, layer_fn, table_fn, cache_fn):
        self.layer_fn = layer_fn
        self.table_fn = table_fn
        self.cache_fn = cache_fn


_FAMILIES: Dict[str, Family] = {
    "dense": Family(transformer.dense_layer, transformer.param_table,
                    transformer.init_cache),
    "moe": Family(moe.moe_layer, moe.param_table, transformer.init_cache),
    "rwkv6": Family(rwkv6.rwkv_layer, rwkv6.param_table, rwkv6.init_cache),
    "hymba": Family(hymba.hymba_layer, hymba.param_table, hymba.init_cache),
}


def family(cfg: ModelConfig) -> Family:
    return _FAMILIES[cfg.family]


def param_table(cfg: ModelConfig):
    return family(cfg).table_fn(cfg)


def init(key: jax.Array, cfg: ModelConfig) -> Params:
    return common.init_params(key, param_table(cfg), cfg.param_dtype)


def abstract_params(cfg: ModelConfig) -> Params:
    return common.abstract_params(param_table(cfg), cfg.param_dtype)


def loss(cfg: ModelConfig, params: Params, batch) :
    return transformer.loss_fn(cfg, params, batch, family(cfg).layer_fn)


def prefill(cfg: ModelConfig, params: Params, batch, cache, lengths=None):
    return transformer.prefill(cfg, params, batch, cache, family(cfg).layer_fn,
                               lengths=lengths)


def decode(cfg: ModelConfig, params: Params, cache, tokens, t):
    return transformer.decode_step(cfg, params, cache, tokens, t,
                                   family(cfg).layer_fn)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False):
    return family(cfg).cache_fn(cfg, batch, max_len, abstract=abstract)
