"""Selective state-space (Mamba-style S6) head — used by Hymba.

Diagonal selective SSM:

    dt_t = softplus(x_t @ W_dt + b_dt)            (B,S,I)   per-channel step
    a_t  = exp(dt_t * A)                          (B,S,I,N) A < 0 (learned log)
    h_t  = a_t . h_{t-1} + dt_t * x_t * B_t       (B,I,N)   B_t: (B,S,N)
    y_t  = sum_N h_t * C_t + D . x_t              (B,S,I)

Execution: chunked ``associative_scan`` — within a chunk the linear
recurrence is solved in O(log c) parallel steps (TPU-friendly), states are
carried across chunks with ``lax.scan`` so peak memory is O(chunk) not
O(S). Decode is the O(1) recurrence (this is what makes hymba a long_500k
arch). Oracle and Pallas kernel in ``kernels/ssd_scan.py``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamSpec, Params
from repro.sharding import shd


def ssm_specs(cfg: ModelConfig, d_in: int) -> Dict[str, ParamSpec]:
    I, N, Kc = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "in_proj": ParamSpec((d_in, 2 * I), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((Kc, I), (None, "ssm_inner"), "normal", 0.5),
        "conv_b": ParamSpec((I,), ("ssm_inner",), "zeros"),
        "wB": ParamSpec((I, N), ("ssm_inner", None), scale=0.5),
        "wC": ParamSpec((I, N), ("ssm_inner", None), scale=0.5),
        "wdt": ParamSpec((I, I), ("ssm_inner", "ssm_inner"), scale=0.1),
        "dt_bias": ParamSpec((I,), ("ssm_inner",), "const", -2.0),
        "A_log": ParamSpec((I, N), ("ssm_inner", None), "const", 0.0),
        "Dskip": ParamSpec((I,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((I, d_in), ("ssm_inner", "embed")),
    }


def _scan_chunked_fused(a: jax.Array, b: jax.Array, C: jax.Array,
                        h0: jax.Array, chunk: int):
    """Like ``_scan_chunked`` but contracts each chunk's hidden states with
    C on the spot: y_t = sum_N h_t * C_t.

    The full (B,S,I,N) hidden-state tensor is never materialized -- per-
    layer peak memory drops from O(S*I*N) to O(chunk*I*N), which is the
    difference between ~6.7 GB and ~0.4 GB per hymba layer at train_4k
    (EXPERIMENTS.md SPerf, cell C).

    a, b: (B,S,I,N); C: (B,S,N); h0: (B,I,N).
    Returns (y (B,S,I) fp32, h_final (B,I,N)).
    """
    B, S, I, N = a.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        raise ValueError(f"seq len {S} is not divisible by chunk {chunk}")
    n = S // chunk
    ac = a.reshape(B, n, chunk, I, N).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(B, n, chunk, I, N).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(B, n, chunk, N).transpose(1, 0, 2, 3)

    def combine(x, y):
        (ax, bx), (ay, by) = x, y
        return ax * ay, bx * ay + by

    def step(h, inp):
        ab, bb, Cb = inp                                  # (B,c,I,N), (B,c,N)
        aa, bb2 = jax.lax.associative_scan(combine, (ab, bb), axis=1)
        hs = aa * h[:, None] + bb2                        # (B,c,I,N)
        y = jnp.einsum("bcin,bcn->bci", hs, Cb)           # contract now
        return hs[:, -1], y

    h_fin, ys = jax.lax.scan(step, h0, (ac, bc, Cc))
    return ys.transpose(1, 0, 2, 3).reshape(B, S, I), h_fin


def _scan_chunked(a: jax.Array, b: jax.Array, h0: jax.Array, chunk: int):
    """h_t = a_t * h_{t-1} + b_t over axis 1. a,b: (B,S,I,N); h0: (B,I,N)."""
    B, S, I, N = a.shape
    chunk = min(chunk, S)
    if S % chunk != 0:
        raise ValueError(f"seq len {S} is not divisible by chunk {chunk}")
    n = S // chunk
    ac = a.reshape(B, n, chunk, I, N).transpose(1, 0, 2, 3, 4)
    bc = b.reshape(B, n, chunk, I, N).transpose(1, 0, 2, 3, 4)

    def combine(x, y):
        (ax, bx), (ay, by) = x, y
        return ax * ay, bx * ay + by

    def step(h, inp):
        ab, bb = inp                                      # (B,c,I,N)
        aa, bb2 = jax.lax.associative_scan(combine, (ab, bb), axis=1)
        hs = aa * h[:, None] + bb2                        # (B,c,I,N)
        return hs[:, -1], hs

    h_fin, hs = jax.lax.scan(step, h0, (ac, bc))
    return hs.transpose(1, 0, 2, 3, 4).reshape(B, S, I, N), h_fin


def ssm_recurrent_step(a_t, b_t, h):
    return a_t * h + b_t


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: jax.Array):
    """Depthwise causal conv. x: (B,S,I); w: (K,I); conv_state: (B,K-1,I).

    Returns (y (B,S,I), new_state (B,K-1,I))."""
    K = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (B,S+K-1,I)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(K - 1):] if K > 1 else conv_state
    return y, new_state


def ssm_block(cfg: ModelConfig, p: Params, x: jax.Array, state, mode: str,
              prefix: str = "ssm/") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B,S,d) -> (B,S,d). state = {"h": (B,I,N) fp32, "conv": (B,K-1,I)}."""
    B, S, _ = x.shape
    I, N = cfg.ssm_d_inner, cfg.ssm_state
    g = lambda k: p[prefix + k]
    zx = jnp.einsum("bsd,di->bsi", x, g("in_proj").astype(x.dtype))
    z, xin = jnp.split(zx, 2, axis=-1)                    # (B,S,I) each
    xin = shd(xin, "batch", "seq", "ssm_inner")
    xc, conv_new = _causal_conv(xin, g("conv_w"), g("conv_b"), state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32))              # (B,S,I) fp32

    dt = jax.nn.softplus(jnp.einsum("bsi,ij->bsj", xc,
                                    g("wdt").astype(jnp.float32))
                         + g("dt_bias").astype(jnp.float32))       # (B,S,I)
    Bmat = jnp.einsum("bsi,in->bsn", xc, g("wB").astype(jnp.float32))
    Cmat = jnp.einsum("bsi,in->bsn", xc, g("wC").astype(jnp.float32))
    A = -jnp.exp(g("A_log").astype(jnp.float32))                   # (I,N) < 0
    a = jnp.exp(dt[..., None] * A)                                 # (B,S,I,N)
    b = (dt * xc)[..., None] * Bmat[:, :, None, :]                 # (B,S,I,N)

    if mode == "decode":
        h = ssm_recurrent_step(a[:, 0], b[:, 0], state["h"])
        y_core = jnp.einsum("bsin,bsn->bsi", h[:, None], Cmat)
    elif cfg.opt_fused_ssm_y:
        y_core, h = _scan_chunked_fused(a, b, Cmat, state["h"], chunk=256)
    elif cfg.use_pallas:
        from repro.kernels import ops
        hs, h = ops.ssd_scan(a, b, state["h"])
        y_core = jnp.einsum("bsin,bsn->bsi", hs, Cmat)
    else:
        hs, h = _scan_chunked(a, b, state["h"], chunk=256)
        y_core = jnp.einsum("bsin,bsn->bsi", hs, Cmat)

    y = y_core + g("Dskip").astype(jnp.float32) * xc
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, g("out_proj").astype(x.dtype))
    return out, {"h": h, "conv": conv_new.astype(state["conv"].dtype)}


def init_state(cfg: ModelConfig, batch: int, abstract: bool = False):
    I, N, K = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_conv
    shapes = {"h": ((batch, I, N), jnp.float32),
              "conv": ((batch, K - 1, I), cfg.compute_dtype)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, t) for k, (s, t) in shapes.items()}
    return {k: jnp.zeros(s, t) for k, (s, t) in shapes.items()}
