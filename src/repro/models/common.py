"""Shared model substrate: config, param tables with logical sharding axes,
norms, rotary embeddings, activations, and memory-safe losses.

Design notes
------------
* **Functional, flax-free.** Parameters live in a *flat dict* ``{path: array}``.
  Every parameter is declared once in a :class:`ParamSpec` table; the same
  table drives initialization (``init_params``), abstract shapes for the
  dry-run (``abstract_params``), and mesh partitioning
  (``launch/sharding.py`` maps each spec's *logical axes* to mesh axes).
* **Scan-over-layers.** Per-layer parameters are stacked along a leading
  ``"layers"`` axis so the transformer body is a single ``lax.scan`` step —
  this keeps the HLO O(1) in depth (essential for the 126-layer dry-run
  compiles) and gives remat a natural per-layer boundary.
* **Mixed precision.** Params are stored in ``cfg.param_dtype`` (bf16 for
  the big configs), matmuls run in ``cfg.compute_dtype``, reductions
  (norms, softmax, CE, router) accumulate in fp32.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]
PyTree = Any

# --------------------------------------------------------------------------
# Model configuration (one dataclass covers every assigned family)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters + runtime policy knobs."""

    name: str = "model"
    family: str = "dense"            # dense | moe | rwkv6 | hymba
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    vocab_size: int = 1024

    # attention flavour
    qkv_bias: bool = False           # qwen2.5
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None     # SWA width (mixtral, hymba)
    global_layers: Tuple[int, ...] = ()      # hymba: layers w/ full attention
    attn_logit_softcap: Optional[float] = None

    # MLP flavour
    activation: str = "swiglu"       # swiglu | relu2 (nemotron) | gelu
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # routed-expert hidden size (qwen2-moe: 1408)
    shared_d_ff: int = 0             # shared-expert hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM / RWKV
    ssm_state: int = 0               # mamba N (hymba: 16)
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv: int = 4                # depthwise conv width
    rwkv_head_dim: int = 64

    # modality frontend (assignment: stub — precomputed embeddings arrive
    # as inputs; the backbone is what we build)
    frontend: Optional[str] = None   # None | "vision" | "audio"
    num_patches: int = 256           # vision prefix length in prefill/train

    # norms / misc
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    tie_embeddings: bool = False

    # runtime policy
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    scan_layers: bool = True         # False => python-unrolled (hymba: mixed caches)
    # train-mode override for scan_layers (hymba: unrolled for serving's
    # mixed cache widths, scanned for training where there is no cache)
    scan_layers_train: Optional[bool] = None
    remat: bool = True               # checkpoint each layer in training

    # ---- beyond-baseline performance toggles (EXPERIMENTS.md §Perf) ----
    # keep dot operands in bf16 with fp32 MXU accumulation instead of
    # materializing fp32 copies of activations/caches/weights
    opt_bf16_dots: bool = False
    # fuse the SSM y-projection into the chunked scan (never materialize
    # the full (B,S,I,N) hidden-state tensor)
    opt_fused_ssm_y: bool = False
    # constrain per-layer weight slices at their use site (forces the AD
    # cotangent — the layer grads — onto the FSDP shard layout inside the
    # backward loop: reduce-scatter instead of full all-reduce)
    opt_weight_constraints: bool = False
    # remat granularity: checkpoint every G layers instead of every layer
    # (boundary activations / G; enables lower grad-accumulation, which is
    # the dominant FSDP re-gather multiplier at 405B scale)
    remat_group: int = 1
    attn_chunk: int = 1024           # KV chunk for the lax flash path
    q_chunk: int = 2048              # query chunk for long prefill
    ce_chunk: int = 512              # sequence chunk for the CE loss
    use_pallas: bool = False         # True => Pallas kernels (TPU / interpret)

    # distribution hints (read by launch/sharding.py)
    fsdp: bool = True                # shard params over "data" in training

    @property
    def q_per_kv(self) -> int:
        return max(self.num_heads // max(self.num_kv_heads, 1), 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def num_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def param_count(self) -> int:
        """Total parameters (exact, from the spec table)."""
        from repro.models import model_zoo  # local import to avoid cycle
        table = model_zoo.param_table(self)
        return sum(int(math.prod(s.shape)) for s in table.values())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        from repro.models import model_zoo
        table = model_zoo.param_table(self)
        total = 0
        for path, spec in table.items():
            n = int(math.prod(spec.shape))
            if "experts/" in path and self.num_experts > 0:
                n = n * self.top_k // self.num_experts
            total += n
        return total


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declared parameter: shape + logical axis names + initializer.

    ``axes`` entries name *logical* dimensions ("vocab", "embed", "heads",
    "kv_heads", "head_dim", "ffn", "experts", "ssm", "layers", or None);
    ``launch/sharding.py`` maps them to mesh axes per run mode.
    """

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"             # normal | zeros | ones | uniform_pm
    scale: float = 1.0               # stddev multiplier on top of fan-in rule

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} and axes {self.axes} "
                             f"must have the same rank")


def stack_layers(table: Mapping[str, ParamSpec], num_layers: int,
                 prefix: str = "layers/") -> Dict[str, ParamSpec]:
    """Stack a single-layer table along a leading 'layers' axis."""
    out = {}
    for k, s in table.items():
        out[prefix + k] = ParamSpec((num_layers,) + s.shape, ("layers",) + s.axes,
                                    s.init, s.scale)
    return out


def _init_leaf(key: jax.Array, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "uniform_pm":   # uniform in [-scale, scale]
        return jax.random.uniform(key, spec.shape, dtype, -spec.scale, spec.scale)
    if spec.init == "const":        # constant fill with value = scale
        return jnp.full(spec.shape, spec.scale, dtype)
    # fan-in scaled normal: std = scale / sqrt(fan_in); fan_in = prod of all
    # dims except the last (works for stacked (L, ...) specs too since the
    # per-layer fan-in is what matters — strip a leading "layers" axis).
    shape = spec.shape[1:] if spec.axes and spec.axes[0] == "layers" else spec.shape
    fan_in = max(int(math.prod(shape[:-1])), 1)
    std = spec.scale / math.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def init_params(key: jax.Array, table: Mapping[str, ParamSpec], dtype) -> Params:
    """Materialize a parameter dict from a spec table (deterministic)."""
    keys = jax.random.split(key, len(table))
    return {path: _init_leaf(k, spec, dtype)
            for k, (path, spec) in zip(keys, sorted(table.items()))}


def abstract_params(table: Mapping[str, ParamSpec], dtype) -> Params:
    """ShapeDtypeStruct stand-ins (dry-run: no allocation)."""
    return {p: jax.ShapeDtypeStruct(s.shape, dtype) for p, s in table.items()}


def layer_slice(params: Params, prefix: str = "layers/") -> Tuple[Params, Params]:
    """Split params into (stacked per-layer, rest)."""
    stacked = {k[len(prefix):]: v for k, v in params.items() if k.startswith(prefix)}
    rest = {k: v for k, v in params.items() if not k.startswith(prefix)}
    return stacked, rest


# --------------------------------------------------------------------------
# Norms / activations / rotary
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, params: Params, prefix: str, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, params[prefix + "/scale"], params[prefix + "/bias"],
                          cfg.norm_eps)
    return rms_norm(x, params[prefix + "/scale"], cfg.norm_eps)


def norm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    """Specs for one norm under a caller-supplied prefix."""
    d = cfg.d_model
    specs = {"scale": ParamSpec((d,), ("embed",), "ones")}
    if cfg.norm_type == "layernorm":
        specs["bias"] = ParamSpec((d,), ("embed",), "zeros")
    return specs


def activate(cfg: ModelConfig, gate: jax.Array, up: Optional[jax.Array]) -> jax.Array:
    """MLP nonlinearity. swiglu: silu(gate)*up; relu2: relu(gate)^2 (nemotron)."""
    if cfg.activation == "swiglu":
        if up is None:
            raise ValueError("swiglu activation requires the `up` "
                             "projection")
        return jax.nn.silu(gate) * up
    if cfg.activation == "relu2":
        r = jax.nn.relu(gate)
        return r * r
    if cfg.activation == "gelu":
        return jax.nn.gelu(gate)
    raise ValueError(cfg.activation)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, D); positions: broadcastable to (..., S).
    """
    freqs = rope_frequencies(x.shape[-1], theta)             # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                       # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Memory-safe cross-entropy (sequence-chunked; never materializes (B,S,V))
# --------------------------------------------------------------------------


def chunked_softmax_xent(x: jax.Array, w_out: jax.Array, labels: jax.Array,
                         chunk: int, logit_dtype=jnp.float32,
                         bf16_dots: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Mean token cross-entropy of ``x @ w_out.T`` against labels.

    Args:
      x: (B, S, d) final hidden states.
      w_out: (V, d) output head (vocab may be sharded over "model").
      labels: (B, S) int32; negative labels are masked out.
      chunk: sequence chunk length.

    Returns:
      (mean_loss, token_count) — both fp32 scalars.

    The scan over sequence chunks keeps live logits at (B, chunk, V); under
    remat the backward pass recomputes each chunk's logits instead of saving
    them — the standard trick that makes 256k-row vocabularies trainable.
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:                     # pad with masked labels (loss-neutral)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    n_chunks = S // chunk
    xs = x.reshape(B, n_chunks, chunk, d).swapaxes(0, 1)          # (n,B,c,d)
    ls = labels.reshape(B, n_chunks, chunk).swapaxes(0, 1)        # (n,B,c)

    V = w_out.shape[0]

    def one_chunk(carry, inp):
        loss_sum, count = carry
        xc, lc = inp
        if bf16_dots:
            # keep the (sharded, FSDP-gathered) head in bf16 on the wire;
            # the MXU accumulates logits in fp32
            logits = jnp.einsum("bcd,vd->bcv", xc, w_out,
                                preferred_element_type=logit_dtype)
        else:
            logits = jnp.einsum("bcd,vd->bcv", xc.astype(logit_dtype),
                                w_out.astype(logit_dtype))
        lse = jax.nn.logsumexp(logits, axis=-1)                   # (B,c)
        # One-hot contraction instead of take_along_axis: partitions cleanly
        # when V is sharded over the model axis.
        onehot = jax.nn.one_hot(jnp.maximum(lc, 0), V, dtype=logit_dtype)
        correct = jnp.einsum("bcv,bcv->bc", logits, onehot)
        mask = (lc >= 0).astype(logit_dtype)
        loss_sum = loss_sum + jnp.sum((lse - correct) * mask)
        count = count + jnp.sum(mask)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        one_chunk, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ls))
    return loss_sum / jnp.maximum(count, 1.0), count


def embed_tokens(embed: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    """Input embedding lookup (table sharded over the *embed* dim, so the
    row gather is collective-free; activations all-gather afterwards)."""
    return jnp.take(embed, tokens, axis=0).astype(compute_dtype)
