"""qwen2.5-14b [dense] — GQA with QKV bias [hf:Qwen/Qwen2.5-14B]."""
from repro.models.common import ModelConfig

ARCH = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
        head_dim=128, d_ff=13824, vocab_size=152064,
        qkv_bias=True, rope_theta=1_000_000.0, activation="swiglu",
        norm_type="rmsnorm")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, qkv_bias=True, activation="swiglu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
