"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088]. SWA rolling cache -> runs the long_500k cell."""
from repro.models.common import ModelConfig

ARCH = "mixtral-8x7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, moe_d_ff=14336, vocab_size=32000,
        num_experts=8, num_shared_experts=0, top_k=2,
        sliding_window=4096, rope_theta=1_000_000.0, activation="swiglu",
        norm_type="rmsnorm")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, moe_d_ff=96, vocab_size=256, num_experts=4, top_k=2,
        sliding_window=16,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
