"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.common import ModelConfig

ARCH = "qwen2-moe-a2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe",
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        head_dim=128, d_ff=1408, moe_d_ff=1408, vocab_size=151936,
        num_experts=60, num_shared_experts=4, top_k=4, shared_d_ff=5632,
        qkv_bias=True, rope_theta=1_000_000.0, activation="swiglu",
        norm_type="rmsnorm")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=96, moe_d_ff=96, vocab_size=256, num_experts=8,
        num_shared_experts=2, top_k=2, shared_d_ff=192, qkv_bias=True,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
