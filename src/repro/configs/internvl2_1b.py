"""internvl2-1b [vlm] — Qwen2-0.5B LM backbone + InternViT stub
[arXiv:2404.16821]. Per the assignment the vision tower is a stub:
``input_specs()`` supplies precomputed patch embeddings (B, P, d) that are
prepended to the token stream."""
from repro.models.common import ModelConfig

ARCH = "internvl2-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        head_dim=64, d_ff=4864, vocab_size=151655,
        qkv_bias=True, rope_theta=1_000_000.0, activation="swiglu",
        norm_type="rmsnorm", frontend="vision", num_patches=256)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, qkv_bias=True, frontend="vision",
        num_patches=8,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
