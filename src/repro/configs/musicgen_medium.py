"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284]. The EnCodec frontend is a stub per the assignment: the
interface is token ids over the 2048-entry codebook (plain LM backbone)."""
from repro.models.common import ModelConfig

ARCH = "musicgen-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
        head_dim=64, d_ff=6144, vocab_size=2048,
        rope_theta=10_000.0, activation="gelu", norm_type="layernorm",
        frontend="audio")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, activation="gelu", norm_type="layernorm",
        frontend="audio",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
