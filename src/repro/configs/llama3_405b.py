"""llama3-405b [dense] — GQA, 128k vocab [arXiv:2407.21783]."""
from repro.models.common import ModelConfig

ARCH = "llama3-405b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
        head_dim=128, d_ff=53248, vocab_size=128256,
        rope_theta=500_000.0, activation="swiglu", norm_type="rmsnorm")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=256, activation="swiglu",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
