"""Architecture registry + assigned input shapes + dry-run input specs.

Every assigned architecture is a selectable config (``--arch <id>``); each
arch pairs with the four LM shapes. ``input_specs`` returns weak-type-
correct ShapeDtypeStruct stand-ins for every model input of a given
(arch, shape) cell — the dry-run lowers against these, so no host memory is
ever allocated for the full configs.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

_MODULES = {
    "qwen2.5-14b": "qwen2_5_14b",
    "llama3-405b": "llama3_405b",
    "stablelm-1.6b": "stablelm_1_6b",
    "nemotron-4-340b": "nemotron_4_340b",
    "hymba-1.5b": "hymba_1_5b",
    "musicgen-medium": "musicgen_medium",
    "internvl2-1b": "internvl2_1b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
}

ARCHS: Tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").config()


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}").smoke_config()


# --------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch; decode shapes lower serve_step
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# long_500k needs sub-quadratic attention: run for SSM / hybrid / SWA archs,
# skip for pure full-attention archs (recorded in DESIGN.md §8).
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "hymba-1.5b", "mixtral-8x7b")


def cell_is_valid(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def valid_cells():
    return [(a, s) for a in ARCHS for s in SHAPES if cell_is_valid(a, s)]


# --------------------------------------------------------------------------
# Input specs for the dry-run (ShapeDtypeStruct; no allocation)
# --------------------------------------------------------------------------


def _tok(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell, as abstract values.

    train:   {tokens, labels}           (+patches for vision frontends)
    prefill: {tokens}                   (+patches)
    decode:  {tokens (B,), t (B,)}      — cache specs come from the engine
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs: Dict[str, jax.ShapeDtypeStruct] = {}
        if cfg.frontend == "vision":
            P = cfg.num_patches
            specs["patches"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                    cfg.compute_dtype)
            specs["tokens"] = _tok((B, S - P))
            specs["labels"] = _tok((B, S - P))
        else:
            specs["tokens"] = _tok((B, S))
            specs["labels"] = _tok((B, S))
        return specs
    if shape.kind == "prefill":
        specs = {}
        if cfg.frontend == "vision":
            P = cfg.num_patches
            specs["patches"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                    cfg.compute_dtype)
            specs["tokens"] = _tok((B, S - P))
        else:
            specs["tokens"] = _tok((B, S))
        return specs
    if shape.kind == "decode":
        return {"tokens": _tok((B,)), "t": _tok((B,))}
    raise ValueError(shape.kind)
