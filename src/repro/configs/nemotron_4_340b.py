"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819]."""
from repro.models.common import ModelConfig

ARCH = "nemotron-4-340b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
        head_dim=192, d_ff=73728, vocab_size=256_000,
        rope_theta=10_000.0, activation="relu2", norm_type="layernorm")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256, activation="relu2", norm_type="layernorm",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
