"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892]. O(1) decode state -> runs the long_500k cell."""
from repro.models.common import ModelConfig

ARCH = "rwkv6-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="rwkv6",
        num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
        head_dim=64, rwkv_head_dim=64, d_ff=14336, vocab_size=65536,
        activation="swiglu", norm_type="rmsnorm")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="rwkv6",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, rwkv_head_dim=16, d_ff=128, vocab_size=256,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
