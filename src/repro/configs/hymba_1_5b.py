"""hymba-1.5b [hybrid] — parallel attention + SSM heads [arXiv:2411.13676].

SWA everywhere except first/middle/last layers (paper layout). Meta tokens
out of scope (DESIGN.md). Mixed per-layer cache shapes -> unrolled stack.
"""
from repro.models.common import ModelConfig

ARCH = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hymba",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        head_dim=64, d_ff=5504, vocab_size=32001,
        ssm_state=16, ssm_expand=2, ssm_conv=4,
        sliding_window=1024, global_layers=(0, 15, 31),
        rope_theta=10_000.0, activation="swiglu", norm_type="rmsnorm",
        scan_layers=False)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="hymba",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, ssm_state=4, ssm_expand=2, ssm_conv=4,
        sliding_window=16, global_layers=(1,), scan_layers=False,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
