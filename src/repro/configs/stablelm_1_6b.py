"""stablelm-1.6b [dense] — MHA (kv=heads), LayerNorm [hf:stabilityai/stablelm-2-1_6b].

Adaptation note: StableLM-2 applies rotary to 25% of head dims; we apply
full rotary (recorded in DESIGN.md — no effect on systems behaviour).
"""
from repro.models.common import ModelConfig

ARCH = "stablelm-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=5632, vocab_size=100352,
        rope_theta=10_000.0, activation="swiglu", norm_type="layernorm")


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return ModelConfig(
        name=ARCH + "-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, activation="swiglu", norm_type="layernorm",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=32, q_chunk=32, ce_chunk=16)
