"""Sharded, mesh-agnostic checkpoint/restore with elastic resharding.

Layout (no orbax dependency; the format is the fault-tolerance contract):

    <dir>/step_000123/
        manifest.json            # step, tree structure, shard table, status
        <leaf-path>.npy          # one file per leaf *shard* (or full leaf)

Properties required at 1000-node scale and how they are met:

* **Atomicity** — writes go to ``step_N.tmp/`` and the directory is
  renamed into place only after the manifest is fsync'd; a crash mid-write
  leaves no valid ``step_N``, and ``latest_step`` skips partial dirs —
  restart resumes from the last complete checkpoint.
* **Elastic resharding** — leaves are stored as *full logical arrays*
  (assembled from addressable shards on save, one writer per shard when
  the process owns it). Restore reads the logical array and reshards to
  *whatever mesh/sharding the new run uses* via ``jax.device_put``; the
  source and destination meshes never need to match (elastic up/downscale).
* **Self-describing** — the manifest carries the flat key list + dtypes +
  shapes; ``restore`` validates against the param table and fails loudly
  on architecture mismatch.

On a multi-host pod each host writes only the shards it owns (guarded by
``process_index``); this container is single-process so the guard is
trivially true, but the code path is the production one.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"


def _flatten(tree: PyTree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out


def _leaf_filename(key: str) -> str:
    return key.replace("/", "__") + ".npy"


def save(ckpt_dir: str, step: int, tree: PyTree, *, extra: Optional[Dict] = None) -> str:
    """Write one atomic checkpoint. Returns the final directory path."""
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, _leaf_filename(key)), arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *complete* checkpoint (ignores .tmp partials)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _MANIFEST)):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    pass
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template: PyTree,
            shardings: Optional[PyTree] = None) -> Tuple[PyTree, Dict]:
    """Load a checkpoint and reshard onto the current mesh.

    Args:
      template: pytree of arrays or ShapeDtypeStructs defining the expected
        structure (validated against the manifest).
      shardings: optional matching pytree of NamedSharding — the *new*
        run's layout; leaves are device_put to it (elastic resharding).

    Returns (tree, extra_metadata).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)

    flat_t = _flatten(template)
    missing = set(flat_t) - set(manifest["leaves"])
    extra_keys = set(manifest["leaves"]) - set(flat_t)
    if missing or extra_keys:
        raise ValueError(f"checkpoint/model mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra_keys)[:5]}")

    flat_s = _flatten(shardings) if shardings is not None else {}
    loaded: Dict[str, Any] = {}
    for key, spec in flat_t.items():
        arr = np.load(os.path.join(d, _leaf_filename(key)))
        want = manifest["leaves"][key]
        if list(arr.shape) != want["shape"]:
            raise ValueError(f"{key}: manifest/file shape mismatch")
        exp_shape = tuple(spec.shape)
        if arr.shape != exp_shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} vs model {exp_shape}")
        arr = arr.astype(spec.dtype)
        if key in flat_s and flat_s[key] is not None:
            loaded[key] = jax.device_put(arr, flat_s[key])
        else:
            loaded[key] = jax.device_put(arr)

    # Rebuild the original structure.
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path) for path, _ in paths]
    tree = jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in keys])
    return tree, manifest.get("extra", {})


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` complete checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n[5:]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, n, _MANIFEST)))
    for s in steps[:-keep] if keep else steps:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
