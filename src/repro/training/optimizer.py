"""AdamW + cosine schedule, as pure pytree transforms.

No optax dependency: the optimizer is ~80 lines and owning it keeps the
checkpoint layout and the dry-run's optimizer-state sharding fully under
our control (optimizer moments inherit each parameter's PartitionSpec, so
FSDP shards them identically to the weights).

Moments are stored in fp32 regardless of param dtype (bf16 Adam moments
lose the small-update tail); the update is computed in fp32 and cast back.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # moment storage dtype. fp32 is the default; bf16 halves optimizer HBM
    # for the >=100B archs (update math stays fp32 either way).
    moment_dtype: Any = jnp.float32


class OptState(NamedTuple):
    step: jax.Array          # () int32
    mu: PyTree               # first moment, fp32, same tree as params
    nu: PyTree               # second moment, fp32


def init(params: PyTree, cfg: OptimizerConfig = OptimizerConfig()) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.moment_dtype), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def abstract_state(params: PyTree,
                   cfg: OptimizerConfig = OptimizerConfig()) -> OptState:
    """ShapeDtypeStruct stand-ins (dry-run path)."""
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype),
                     params)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z, nu=z)


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to end_lr_frac * peak."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    total = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((s - cfg.warmup_steps) / total, 0.0, 1.0)
    cos = cfg.end_lr_frac + (1 - cfg.end_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def _decay_mask(path: str) -> bool:
    """Weight decay applies to matrices, not norms/biases (standard rule)."""
    leaf = path.split("/")[-1]
    return not (leaf in ("scale", "bias") or leaf.startswith("b"))


def apply_updates(cfg: OptimizerConfig, params: Dict[str, jax.Array],
                  grads: Dict[str, jax.Array], state: OptState,
                  ) -> Tuple[Dict[str, jax.Array], OptState, Dict[str, jax.Array]]:
    """One AdamW step on the flat param dict. Returns (params', state', info)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_params, new_mu, new_nu = {}, {}, {}
    for path in params:
        g = grads[path].astype(jnp.float32) * clip
        mu = cfg.b1 * state.mu[path].astype(jnp.float32) + (1 - cfg.b1) * g
        nu = (cfg.b2 * state.nu[path].astype(jnp.float32)
              + (1 - cfg.b2) * jnp.square(g))
        upd = (mu / b1t) / (jnp.sqrt(nu / b2t) + cfg.eps)
        p32 = params[path].astype(jnp.float32)
        if _decay_mask(path):
            upd = upd + cfg.weight_decay * p32
        new_params[path] = (p32 - lr * upd).astype(params[path].dtype)
        new_mu[path] = mu.astype(cfg.moment_dtype)
        new_nu[path] = nu.astype(cfg.moment_dtype)
    info = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), info
