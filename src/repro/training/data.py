"""Deterministic synthetic data pipeline.

A stateless, seekable token stream: batch ``i`` is a pure function of
``(seed, i)`` via threefry, so restart-after-preemption reproduces the
exact same stream without data-loader state in the checkpoint (only the
step index is stored). Shapes follow the (arch x shape) cell.

The generator models a Zipf-ish unigram LM over the vocab — cheap, but with
enough structure that loss actually decreases (so examples/train_small.py
shows real learning curves, not noise).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    zipf_a: float = 1.2          # unigram skew
    span: int = 16               # repeated-span structure (gives learnable signal)


def _zipf_logits(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks ** a
    return np.log(p / p.sum()).astype(np.float32)


def make_batch(cfg: ModelConfig, dcfg: DataConfig, index: int) -> Dict[str, jnp.ndarray]:
    """Batch ``index`` of the stream (pure function; jit-free host path).

    Tokens have copy structure: each span of ``dcfg.span`` tokens is
    sampled once and repeated, so a model that learns to copy gets a big
    loss drop — a useful smoke signal.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), index)
    B, S = dcfg.batch, dcfg.seq_len
    n_span = (S + 2 * dcfg.span - 1) // (2 * dcfg.span)
    logits = jnp.asarray(_zipf_logits(cfg.vocab_size, dcfg.zipf_a))
    spans = jax.random.categorical(key, logits, shape=(B, n_span, dcfg.span))
    doubled = jnp.concatenate([spans, spans], axis=-1).reshape(B, -1)[:, :S + 1]
    tokens = doubled[:, :S].astype(jnp.int32)
    labels = doubled[:, 1:S + 1].astype(jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "vision":
        P = cfg.num_patches
        patches = jax.random.normal(jax.random.fold_in(key, 7),
                                    (B, P, cfg.d_model), cfg.compute_dtype)
        batch["patches"] = patches
    return batch


def stream(cfg: ModelConfig, dcfg: DataConfig, start: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    """Seekable infinite stream; ``start`` resumes mid-run after restart."""
    i = start
    while True:
        yield make_batch(cfg, dcfg, i)
        i += 1
