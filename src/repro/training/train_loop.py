"""Training step + fault-tolerant loop.

``make_train_step`` builds the pure function the dry-run lowers:

    (train_state, batch) -> (train_state, metrics)

with optional gradient accumulation (``lax.scan`` over microbatches; the
batch's leading dim is split ``(accum, B/accum)``) and optional int8
error-feedback gradient compression on the cross-data-parallel mean.

``Trainer`` is the driver used by ``launch/train.py`` and the examples:
auto-resume from the newest complete checkpoint, periodic atomic saves,
simulated-preemption hooks for the fault-tolerance tests, straggler-aware
step timing (logs p95/p50 step-time ratio — the same Eq-(1) statistic the
paper applies to requests, reused as the training-loop health signal).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo
from repro.models.common import ModelConfig, Params
from repro.training import checkpoint as ckpt_lib
from repro.training import compression, optimizer
from repro.training.optimizer import OptimizerConfig, OptState

PyTree = Any


class TrainState(NamedTuple):
    params: Params
    opt: OptState
    err: Optional[PyTree]        # compression error feedback (None if off)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptimizerConfig = OptimizerConfig()
    accum_steps: int = 1
    compression: compression.CompressionConfig = compression.CompressionConfig()
    # data-parallel axes for the compressed-mean path (shard_map mode)
    dp_axes: Tuple[str, ...] = ("data",)


def init_state(key: jax.Array, cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    params = model_zoo.init(key, cfg)
    err = compression.init_error(params) if tcfg.compression.enabled else None
    return TrainState(params, optimizer.init(params, tcfg.opt), err)


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    params = model_zoo.abstract_params(cfg)
    err = (jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
           if tcfg.compression.enabled else None)
    return TrainState(params, optimizer.abstract_state(params, tcfg.opt), err)


def _split_microbatches(batch: Dict[str, jax.Array], accum: int):
    def r(x):
        B = x.shape[0]
        if B % accum != 0:
            raise ValueError(f"batch size {B} is not divisible by "
                             f"grad-accum factor {accum}")
        return x.reshape(accum, B // accum, *x.shape[1:])
    return {k: r(v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    grad_shardings: Optional[PyTree] = None) -> Callable:
    """Build the jittable train step for one (arch, shape) cell.

    ``grad_shardings`` (a pytree of NamedSharding matching params) pins the
    gradient / accumulation buffers to the parameter layout — without it
    GSPMD is free to keep the fp32 accumulators partially replicated, which
    at 405B scale is tens of GiB of temp and an all-reduce instead of a
    reduce-scatter on every microbatch.
    """

    def loss_fn(params, mb):
        loss, metrics = model_zoo.loss(cfg, params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _pin(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if tcfg.accum_steps > 1:
            mbs = _split_microbatches(batch, tcfg.accum_steps)

            def micro(carry, mb):
                gsum, lsum = carry
                (loss, metrics), grads = grad_fn(state.params, mb)
                grads = _pin(grads)
                gsum = _pin(jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads))
                return (gsum, lsum + loss), metrics

            g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   state.params))
            (gsum, lsum), metrics = jax.lax.scan(micro, (g0, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.accum_steps, gsum)
            loss = lsum / tcfg.accum_steps
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)
            grads = _pin(grads)

        err = state.err
        if tcfg.compression.enabled and err is not None:
            # Quantize + dequantize with error feedback. Under pjit the
            # subsequent psum (inserted by XLA for the sharded batch dim)
            # reduces the *dequantized* grads; the explicit int8-wire ring
            # lives in the shard_map path (compression.allreduce_compressed)
            # and is benchmarked separately.
            q, s, err = compression.compress(grads, err, tcfg.compression)
            grads = compression.decompress(q, s)

        params, opt, info = optimizer.apply_updates(
            tcfg.opt, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss, **info)
        return TrainState(params, opt, err), metrics

    return train_step


# ---------------------------------------------------------------------------
# Fault-tolerant driver
# ---------------------------------------------------------------------------


class PreemptionError(RuntimeError):
    """Raised by fault-injection hooks to simulate a node loss."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10


class Trainer:
    """Checkpoint/restart training driver.

    ``fault_hook(step)`` (tests only) may raise :class:`PreemptionError`;
    callers re-instantiate the Trainer to model a restarted job, and
    ``run`` resumes from the newest complete checkpoint — the data stream
    is seekable so the token sequence is bit-identical to an uninterrupted
    run (verified in tests/test_fault_tolerance.py).
    """

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, lcfg: LoopConfig,
                 make_batches: Callable[[int], Iterator[Dict[str, jnp.ndarray]]],
                 seed: int = 0,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.cfg, self.tcfg, self.lcfg = cfg, tcfg, lcfg
        self.make_batches = make_batches
        self.fault_hook = fault_hook
        self.step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
        self.state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)
        self.start_step = 0
        self.step_times: list = []
        if lcfg.ckpt_dir:
            latest = ckpt_lib.latest_step(lcfg.ckpt_dir)
            if latest is not None:
                self.state, extra = ckpt_lib.restore(
                    lcfg.ckpt_dir, latest, self.state)
                self.start_step = latest
        self.history: list = []

    def _save(self, step: int) -> None:
        if self.lcfg.ckpt_dir:
            ckpt_lib.save(self.lcfg.ckpt_dir, step, self.state)
            ckpt_lib.gc_old(self.lcfg.ckpt_dir, self.lcfg.keep)

    def straggler_ratio(self) -> float:
        """p95/p50 of recent step wall-times — Eq (1) applied to steps."""
        if len(self.step_times) < 4:
            return 1.0
        t = np.asarray(self.step_times[-64:])
        return float(np.percentile(t, 95) / max(np.percentile(t, 50), 1e-9))

    def run(self) -> Dict[str, list]:
        batches = self.make_batches(self.start_step)
        for step in range(self.start_step, self.lcfg.total_steps):
            if self.fault_hook:
                self.fault_hook(step)
            batch = next(batches)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])   # sync point = step boundary
            self.step_times.append(time.perf_counter() - t0)
            self.history.append({"step": step + 1, "loss": loss})
            nxt = step + 1
            if self.lcfg.ckpt_dir and nxt % self.lcfg.ckpt_every == 0:
                self._save(nxt)
        if self.lcfg.ckpt_dir and self.lcfg.total_steps % self.lcfg.ckpt_every:
            self._save(self.lcfg.total_steps)
        return {"history": self.history,
                "straggler_ratio": self.straggler_ratio()}
