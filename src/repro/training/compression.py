"""Int8 gradient compression with error feedback.

Distributed-optimization trick for bandwidth-bound data-parallel training:
before the cross-replica mean, each gradient leaf is quantized to int8
with a per-leaf fp32 scale; the quantization residual is carried to the
next step (error feedback), which keeps SGD/Adam convergence unbiased in
expectation (Karimireddy et al., 2019 — "EF-SGD").

Two modes:

* ``compress/decompress`` — pure pytree transforms used inside a standard
  ``psum``-based step: quantize -> all-reduce int8* -> dequantize.
  (*XLA all-reduces int8 by widening; the wire format win is modeled in
  the roofline term — see EXPERIMENTS.md. On real ICI the win comes from
  the ``shard_map`` ring below.)
* ``ring_allreduce_int8`` — an explicit reduce-scatter + all-gather ring
  written with ``shard_map`` + ``lax.ppermute`` over a named axis, moving
  int8 on every hop. This is the collective whose bytes the roofline
  counts at 1/4 of the fp32 ring.

Error feedback state is one fp32 residual per leaf, sharded like the leaf.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

PyTree = Any


def _pvary(x, axis_name):
    """Mark ``x`` device-varying over ``axis_name``.

    ``lax.pvary`` exists only on jax versions with varying-manual-axes
    tracking (check_vma); older releases have no such annotation (their
    ``check_rep=False`` shard_map accepts untyped collectives), so the
    identity is the correct fallback.
    """
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, (axis_name,))
    return x


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    dtype: Any = jnp.int8
    # quantile used for the scale (max is noise-sensitive; 0 = use absmax)
    clip_quantile: float = 0.0


def init_error(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _scale_for(leaf: jax.Array, cfg: CompressionConfig) -> jax.Array:
    a = jnp.abs(leaf.astype(jnp.float32))
    if cfg.clip_quantile > 0:
        s = jnp.quantile(a.reshape(-1), cfg.clip_quantile)
    else:
        s = jnp.max(a)
    return jnp.maximum(s, 1e-12) / 127.0


def compress(grads: PyTree, error: PyTree, cfg: CompressionConfig
             ) -> Tuple[PyTree, PyTree, PyTree]:
    """Quantize (grad + carried error) to int8. Returns (q, scales, new_error)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        s = _scale_for(g32, cfg)
        q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(cfg.dtype)
        deq = q.astype(jnp.float32) * s
        return q, s, g32 - deq       # residual -> error feedback
    qs, ss, es = {}, {}, {}
    for k in grads:
        qs[k], ss[k], es[k] = one(grads[k], error[k])
    return qs, ss, es


def decompress(qs: PyTree, scales: PyTree) -> PyTree:
    return {k: qs[k].astype(jnp.float32) * scales[k] for k in qs}


# ---------------------------------------------------------------------------
# Explicit int8 ring all-reduce (reduce-scatter + all-gather) over one axis
# ---------------------------------------------------------------------------


def ring_allreduce_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` moving int8+scale on every hop.

    Must be called *inside* ``shard_map``. x: any int8 array whose leading
    dim is divisible by the axis size. Accumulates in int32 (no overflow
    for axis sizes < 2^23), rescales to int8 between hops.

    Wire bytes per device: 2 * (n-1)/n * |x| * 1 byte — 4x less than fp32.
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    chunks = x.shape[0] // n
    acc = x.reshape(n, chunks, *x.shape[1:]).astype(jnp.int32)
    # mark device-varying up front: ppermute outputs are varying over the
    # axis, and a lax loop carry must keep a consistent varying type
    acc = _pvary(acc, axis_name)

    def rs_step(i, acc_blk):
        acc, blk = acc_blk
        # step i: send chunk (idx - i), fold the received chunk (idx - i - 1)
        src_chunk = (idx - i) % n
        send = jax.lax.dynamic_index_in_dim(acc, src_chunk, 0, keepdims=False)
        recv = jax.lax.ppermute(send, axis_name,
                                [(j, (j + 1) % n) for j in range(n)])
        tgt_chunk = (idx - i - 1) % n
        acc = acc.at[tgt_chunk].add(recv)
        return acc, blk

    acc, _ = jax.lax.fori_loop(0, n - 1, rs_step, (acc, 0))
    # Each device now owns the fully-reduced chunk at position idx+1 mod n.
    own = jax.lax.dynamic_index_in_dim(acc, (idx + 1) % n, 0, keepdims=False)

    # all-gather ring: n-1 hops of the owned chunk.
    def ag_step(i, state):
        out, cur = state
        recv = jax.lax.ppermute(cur, axis_name,
                                [(j, (j + 1) % n) for j in range(n)])
        pos = (idx - i) % n
        out = out.at[pos].set(recv)
        return out, recv

    out0 = _pvary(jnp.zeros((n, chunks) + x.shape[1:], jnp.int32),
                  axis_name).at[(idx + 1) % n].set(own)
    out, _ = jax.lax.fori_loop(0, n - 1, ag_step, (out0, own))
    return out.reshape(x.shape).astype(jnp.int32)


def allreduce_compressed(grads: PyTree, error: PyTree, cfg: CompressionConfig,
                         axis_name: str) -> Tuple[PyTree, PyTree]:
    """Mean-reduce gradients across ``axis_name`` in int8 (inside shard_map).

    Scales are psum-maxed first so every replica quantizes on the same grid
    (required for exact int-domain summation). Returns (mean_grads, error').
    """
    n = jax.lax.psum(1, axis_name)
    out, new_err = {}, {}
    for k, g in grads.items():
        g32 = g.astype(jnp.float32) + error[k]
        s = jax.lax.pmax(_scale_for(g32, cfg), axis_name)
        q = jnp.clip(jnp.round(g32 / s), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int wire fmt
        mean = summed.astype(jnp.float32) * s / n
        new_err[k] = g32 - q.astype(jnp.float32) * s
        out[k] = mean
    return out, new_err
