"""``repro.cache`` — paged KV-cache management.

The serving engine's cache pool is either *dense* (one ``max_len`` row
per slot — the historical layout) or *paged*: a fixed pool of
``page_size``-token pages, a per-request page table, refcounted pages
with copy-on-write forking, and a prefix registry that lets requests
sharing a system/function prompt reference the same resident pages.

  * :class:`~repro.cache.pages.PagePool` — free-list allocator +
    refcounts over a fixed page pool (host-side bookkeeping; the page
    *contents* live in the endpoint's device arrays).
  * :func:`~repro.cache.pages.pages_needed` — the one formula both the
    live engine and the simulator's bytes-based tier-capacity model use
    to size a request's page reservation.
  * :class:`~repro.cache.prefix.PrefixRegistry` — prompt-hash ->
    resident prefix pages (+ the cached first token), LRU-bounded.
"""

from repro.cache.pages import (PagePool, pages_needed, pages_for_tokens,
                               token_extent)
from repro.cache.prefix import PrefixEntry, PrefixRegistry

__all__ = ["PagePool", "pages_needed", "pages_for_tokens", "token_extent",
           "PrefixEntry", "PrefixRegistry"]
