"""Prefix-hash registry: shared system/function-prompt pages.

Serverless LLM traffic is dominated by a few hot functions whose
invocations share the same system/function prompt — the KV cache of that
prompt is identical across invocations, yet a dense pool re-prefills it
from token 0 every time (the LLM analogue of the cold-start cost the
edge-serverless measurements call the dominant latency term).  The
registry keys the *pages* holding an already-computed prompt prefix by a
hash of its token ids; a new request whose prompt matches simply
references those pages (refcount++, copy-on-write past the fork point)
and skips prefill compute entirely — the cached ``first_token`` (the
argmax the registering prefill produced) seeds its decode stream, so the
token stream is bit-identical to having prefilled from scratch.

The registry holds one reference on every page of every entry; LRU
eviction (bounded ``capacity``) drops those references, and the pool
frees a page once no table references it either.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from repro.cache.pages import PagePool


def prefix_key(tokens: np.ndarray) -> bytes:
    """Stable identity of a token prefix (exact content, not a digest —
    collisions would silently cross-wire two requests' caches)."""
    return np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes()


@dataclasses.dataclass
class PrefixEntry:
    """One registered prompt prefix resident in the pool."""
    page_ids: Tuple[int, ...]          # pages covering positions [0, length)
    length: int                        # prompt tokens covered
    first_token: int                   # argmax at the last prompt position


class PrefixRegistry:
    """LRU-bounded map: prompt hash -> resident prefix pages."""

    def __init__(self, pool: PagePool, capacity: int = 64):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.pool = pool
        self.capacity = capacity
        self._entries: "OrderedDict[bytes, PrefixEntry]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, tokens: np.ndarray) -> Optional[PrefixEntry]:
        """Exact-prompt hit or None; hits refresh LRU order."""
        entry = self._entries.get(prefix_key(tokens))
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(prefix_key(tokens))
        self.hits += 1
        return entry

    def register(self, tokens: np.ndarray, page_ids, first_token: int
                 ) -> Optional[PrefixEntry]:
        """Pin ``page_ids`` as the resident cache of ``tokens`` (the
        registry takes one reference per page).  Registering an
        already-known prompt is a no-op; a zero-capacity registry
        registers nothing.  May evict the LRU entry."""
        if self.capacity == 0:
            return None
        key = prefix_key(tokens)
        if key in self._entries:
            self._entries.move_to_end(key)
            return self._entries[key]
        entry = PrefixEntry(tuple(int(p) for p in page_ids),
                            int(len(tokens)), int(first_token))
        self.pool.retain(entry.page_ids)
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            _, old = self._entries.popitem(last=False)
            self.pool.release(old.page_ids)
        return entry

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry (frees its references).
        Returns False when the registry is empty."""
        if not self._entries:
            return False
        _, old = self._entries.popitem(last=False)
        self.pool.release(old.page_ids)
        return True

    def flush(self) -> None:
        """Drop every entry (e.g. before endpoint teardown)."""
        while self.evict_lru():
            pass
