"""Fixed-size page-pool allocator for the paged KV cache.

The pool is pure host-side bookkeeping: which pages are free, how many
references each allocated page carries, and how many pages a request of
a given size must reserve.  Page *contents* are device arrays owned by
the serving endpoint (``serving/engine.py``); the simulator reuses only
the arithmetic (:func:`pages_needed`) for its bytes-based tier-capacity
model, so both deployments agree on what fits.

Sharing model (vLLM-style, at page granularity):

  * a page referenced by exactly one page table is *private* — its owner
    may write new KV positions into it;
  * a page referenced by several tables (or by the
    :class:`~repro.cache.prefix.PrefixRegistry`) is *shared* and
    immutable — a request about to write into a shared page must first
    **copy-on-write fork** it: allocate a fresh page, copy the contents,
    swap its table entry, and drop one reference on the original.

The pool enforces the refcount side of that contract; the engine does
the device-side copying.
"""

from __future__ import annotations

from typing import Iterable, List, Optional


def token_extent(prompt_len: int, max_new: int) -> int:
    """KV positions ``[0, extent)`` a request writes over its lifetime.

    Prefill writes ``[0, prompt_len)``; decode writes
    ``prompt_len .. prompt_len + max_new - 2`` (the last generated token
    is never written back).  Both the page-extent formula below and the
    engine's rolling-wrap admission test derive from this one number.
    """
    return prompt_len + max(max_new, 1) - 1


def pages_needed(prompt_len: int, max_new: int, page_size: int,
                 max_len: int) -> int:
    """Pages a request must reserve to decode without mid-stream allocation.

    A request writes KV at positions ``[0, prompt_len)`` during prefill
    and at ``prompt_len .. prompt_len + max_new - 2`` during decode (the
    last generated token is never written back), so its page extent is
    ``prompt_len + max_new - 1`` positions.  A request whose extent
    exceeds ``max_len`` wraps the rolling cache and touches every page of
    the row, so it reserves the full row.
    """
    if page_size <= 0:
        raise ValueError(f"page_size must be > 0, got {page_size}")
    ppr = -(-max_len // page_size)              # pages per full row
    extent = token_extent(prompt_len, max_new)
    if extent > max_len:
        return ppr
    return min(ppr, max(1, -(-extent // page_size)))


def pages_for_tokens(n_tokens: int, page_size: int) -> int:
    """Pages covering positions ``[0, n_tokens)`` (0 tokens -> 0 pages)."""
    return -(-max(n_tokens, 0) // page_size)


class PagePool:
    """Free-list page allocator with per-page reference counts.

    ``num_pages`` usable pages, ids ``0..num_pages-1``.  Allocation pops
    from the free list (LIFO — recently freed pages are reused first,
    keeping the working set compact); every allocated page carries a
    refcount, and :meth:`release` returns a page to the free list only
    when its last reference drops.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0:
            raise ValueError(f"num_pages must be > 0, got {num_pages}")
        if page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self._ref: List[int] = [0] * num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))

    # -- queries -----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, pid: int) -> int:
        return self._ref[pid]

    def is_shared(self, pid: int) -> bool:
        return self._ref[pid] > 1

    # -- allocation --------------------------------------------------------
    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` free pages (refcount 1 each), or None if the pool
        cannot satisfy the request — nothing is allocated partially."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for pid in out:
            self._ref[pid] = 1
        return out

    def retain(self, pids: Iterable[int]) -> None:
        """Add one reference to each (already-allocated) page."""
        for pid in pids:
            if self._ref[pid] <= 0:
                raise ValueError(f"retain of free page {pid}")
            self._ref[pid] += 1

    def release(self, pids: Iterable[int]) -> None:
        """Drop one reference per page; a page whose last reference drops
        returns to the free list."""
        for pid in pids:
            if self._ref[pid] <= 0:
                raise ValueError(f"release of free page {pid}")
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                self._free.append(pid)

    def check_balanced(self) -> bool:
        """True when refcounts and the free list agree (debug/tests)."""
        live = sum(1 for r in self._ref if r > 0)
        return live + len(self._free) == self.num_pages
