"""Static analysis of compiled HLO: collective bytes + roofline terms.

``cost_analysis()`` gives HLO_FLOPs and HLO_bytes but not collective
traffic, so collective bytes are parsed from the compiled HLO text: for
every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all``
/ ``collective-permute`` op we sum the *operand* sizes (the bytes that hit
the interconnect, per participating device).

Hardware model (TPU v5e, the assignment's target):
    peak 197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

Roofline terms, per device:
    compute    = HLO_FLOPs_per_device / peak
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw
"""

from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Dict, Iterable, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 MXU / chip
VPU_FLOPS = 3.9e12           # elementwise f32 / chip (8x128 VPU @ ~950MHz)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
# Post-optimization HLO prints operands by %name (no inline types), so we
# parse the RESULT type (left of the op name) and the replica group size,
# and derive operand/wire bytes per collective kind from those.
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(text: str) -> float:
    """Sum bytes of every dtype[shape] group in a type string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
    return 1


def _iter_collectives(hlo_text: str):
    """Yield (kind, result_bytes, group_size) per collective instruction."""
    for line in hlo_text.splitlines():
        s = line.strip()
        if not any(k in s for k in _COLLECTIVE_KINDS):
            continue
        if "-done" in s:          # async completion: counted at -start
            continue
        m = _OP_RE.search(s)
        if not m:
            continue
        result_t, kind = m.group(1), m.group(2)
        yield kind, _shape_bytes(result_t), _group_size(s), s


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind *operand* bytes (the assignment's metric) and a ring-model
    ``wire`` estimate of per-device link traffic.

    operand bytes per kind (result_bytes R, group size n):
        all-gather: R/n   all-reduce: R   reduce-scatter: R*n
        all-to-all: R     collective-permute: R
    wire bytes per device (bidirectional ring model):
        all-gather: R*(n-1)/n          all-reduce: 2*R*(n-1)/n
        reduce-scatter: R*(n-1)        all-to-all: R*(n-1)/n
        collective-permute: R
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_KINDS}
    wire = 0.0
    for kind, R, n, _ in _iter_collectives(hlo_text):
        if kind == "all-gather":
            out[kind] += R / n
            wire += R * (n - 1) / n
        elif kind == "all-reduce":
            out[kind] += R
            wire += 2.0 * R * (n - 1) / n
        elif kind == "reduce-scatter":
            out[kind] += R * n
            wire += R * (n - 1)
        elif kind == "all-to-all":
            out[kind] += R
            wire += R * (n - 1) / n
        else:                      # collective-permute
            out[kind] += R
            wire += R
    out["total"] = sum(out[k] for k in _COLLECTIVE_KINDS)
    out["wire"] = wire
    return out


def collective_ops_count(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for kind, _, _, _ in _iter_collectives(hlo_text):
        out[kind] += 1
    return out


def top_collectives(hlo_text: str, n: int = 10):
    """The n largest collectives by wire bytes — the §Perf shortlist."""
    rows = []
    for kind, R, g, line in _iter_collectives(hlo_text):
        meta = ""
        m = re.search(r'op_name="([^"]*)"', line)
        if m:
            meta = m.group(1)[-90:]
        rows.append((R, kind, g, meta))
    rows.sort(reverse=True)
    return rows[:n]


@dataclasses.dataclass
class Roofline:
    """Per-device roofline terms (seconds) for one compiled step."""
    flops_per_device: float              # total (MXU + VPU)
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int
    mxu_flops_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        """MXU time + VPU time (elementwise work runs on the vector unit)."""
        mxu = self.mxu_flops_per_device or self.flops_per_device
        vpu = max(self.flops_per_device - mxu, 0.0)
        return mxu / PEAK_FLOPS + vpu / VPU_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Lower-bound step time = max of the three overlapped terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self, useful_flops_per_device: float) -> float:
        """useful-FLOPs MFU bound implied by the dominant term."""
        if self.step_s <= 0:
            return 0.0
        return useful_flops_per_device / PEAK_FLOPS / self.step_s

    def to_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops_per_device,
            "mxu_flops_per_device": self.mxu_flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_s": self.step_s,
        }


def roofline_from_compiled(compiled, chips: int,
                           hlo_text: Optional[str] = None) -> Tuple[Roofline, Dict]:
    """Build roofline terms from a jax ``Compiled`` object.

    The compiled module is the per-device SPMD program, so every number is
    the per-device view. FLOPs/bytes/collectives come from the
    trip-count-aware ``hlo_cost`` walk (XLA's own ``cost_analysis`` counts
    loop bodies once — useless for scanned layer stacks; it is recorded in
    the detail dict for reference).
    """
    from repro.launch import hlo_cost
    fallback = None
    try:
        text = hlo_text if hlo_text is not None else compiled.as_text()
        hc = hlo_cost.analyze_hlo(text)
    except Exception as e:
        # A backend that cannot render HLO text (or renders a dialect the
        # walk cannot parse) must still hand callers a *usable* result:
        # a well-formed zero-cost Roofline plus an explicit fallback
        # marker, never a partial dict they have to defensively probe.
        warnings.warn(
            f"hlo cost walk unavailable, returning zero-cost fallback "
            f"roofline: {e!r}")
        fallback = repr(e)
        text = ""
        hc = {"flops": 0.0, "mxu_flops": 0.0, "vpu_flops": 0.0,
              "bytes": 0.0, "transcendentals": 0.0,
              "collective_operand_bytes": {},
              "collective_operand_total": 0.0,
              "collective_wire_bytes": 0.0, "num_collectives": 0}
    coll = dict(hc["collective_operand_bytes"])
    coll["total"] = hc["collective_operand_total"]
    coll["wire"] = hc["collective_wire_bytes"]
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        xla_ca = {k: float(v) for k, v in ca.items()
                  if isinstance(v, (int, float))}
        xla_ok = True
    except Exception as e:
        # cost_analysis() is advisory (recorded for reference only) and
        # its API/availability varies across jax versions and backends —
        # degrade to empty, but say so rather than vanish the error.
        warnings.warn(f"xla cost_analysis unavailable: {e!r}")
        xla_ca = {}
        xla_ok = False
    return (Roofline(hc["flops"], hc["bytes"], coll["wire"], chips,
                     mxu_flops_per_device=hc["mxu_flops"]),
            {"collectives": coll, "counts": collective_ops_count(text),
             "num_collectives": hc["num_collectives"],
             "transcendentals": hc["transcendentals"],
             "xla_cost_analysis_unscaled": xla_ca,
             "xla_cost_analysis_ok": xla_ok,
             "fallback": fallback})


def model_flops(cfg, shape_kind: str, tokens: int, *, seq_len: int = 0,
                batch: int = 0) -> float:
    """Useful model FLOPs for the cell (the MODEL_FLOPS of §Roofline).

    train:   6 * N_active * tokens  (fwd 2ND + bwd 4ND)
    prefill: 2 * N_active * tokens
    decode:  2 * N_active * batch  + attention KV read term
    """
    n = cfg.active_param_count()
    if shape_kind == "train":
        base = 6.0 * n * tokens
    elif shape_kind == "prefill":
        base = 2.0 * n * tokens
    else:  # decode: one token per sequence
        base = 2.0 * n * batch
    # attention score/value FLOPs: 2 * 2 * B * S_q * S_kv * H * D (approx,
    # causal halves it for train/prefill)
    H, D, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    if shape_kind in ("train", "prefill") and H:
        S = seq_len
        attn = 2 * 2 * batch * S * S * H * D * L / 2
        if cfg.sliding_window:
            w = min(cfg.sliding_window, S)
            attn = 2 * 2 * batch * S * w * H * D * L
        base += attn * (3 if shape_kind == "train" else 1)
    elif shape_kind == "decode" and H:
        w = seq_len if not cfg.sliding_window else min(cfg.sliding_window, seq_len)
        base += 2 * 2 * batch * w * H * D * L
    return base
