"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — required because only the
dry-run process forces 512 host devices; tests and benches run on 1.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's grading meshes.

    single-pod: (16, 16)   ("data", "model")    — 256 chips (one v5e pod)
    multi-pod:  (2, 16, 16) ("pod", "data", "model") — 512 chips (2 pods)
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small ones, e.g. (2, 2) on 4 host devices)."""
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-device mesh with the production axis names — lets every
    sharded code path run unchanged on one CPU device."""
    return jax.make_mesh((1, 1), ("data", "model"))
