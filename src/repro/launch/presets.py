"""Per-cell runtime presets: gradient accumulation + state dtypes.

The assigned shapes pin global batch and sequence length; what's free is
how a cell spends HBM. These presets are the baseline memory plan derived
in EXPERIMENTS.md §Dry-run (napkin math per arch, then validated against
``memory_analysis()``):

* accum_steps: keeps the microbatch's activation footprint (remat layer
  boundaries, seq-sharded) plus CE logits inside HBM.
* moment_dtype: bf16 Adam moments for the >=100B archs (fp32 moments alone
  would be 4 bytes/param -> 6.3 GB/chip at 512-way sharding for 405B).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig


def train_preset(cfg: ModelConfig, global_batch: int) -> TrainConfig:
    n = cfg.param_count()
    if n >= 100e9:
        accum, moment_dtype = 16, jnp.bfloat16
    elif n >= 30e9:
        accum, moment_dtype = 8, jnp.float32
    elif n >= 5e9:
        accum, moment_dtype = 4, jnp.float32
    else:
        accum, moment_dtype = 2, jnp.float32
    accum = min(accum, global_batch)
    while global_batch % accum:
        accum //= 2
    return TrainConfig(
        opt=OptimizerConfig(moment_dtype=moment_dtype),
        accum_steps=max(accum, 1))
