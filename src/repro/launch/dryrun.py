import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# persistent compile cache: re-analysis sweeps skip recompilation
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR", "/tmp/jax_dryrun_cache")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "1")
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, and record memory/cost/collective analysis.

This is deliverable (e): the proof that the distribution config is
coherent — sharding mismatches, compile-time OOM and unsupported
collectives all surface here as hard failures.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch qwen2.5-14b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --all
    PYTHONPATH=src python -m repro.launch.dryrun --all            # both meshes

Results land in benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json;
benchmarks/roofline.py and EXPERIMENTS.md read from there.

NOTE the first two lines of this file: the placeholder-device flag must be
set before jax initializes. Only the dry-run sets it — tests and benches
see the single real CPU device.
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs
from repro import sharding as shlib
from repro.launch import hlo_analysis, presets
from repro.launch import sharding as rules_lib
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo
from repro.training import train_loop

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _variant_overrides(cfg, variant: str):
    """Named config variants used by the §Perf hillclimb iterations."""
    if variant == "baseline":
        return cfg
    raise ValueError(f"unknown variant {variant!r} (hillclimbs register "
                     f"theirs via --set key=value)")


def _apply_sets(cfg, sets):
    """--set key=value config overrides (ints/floats/bools auto-coerced)."""
    if not sets:
        return cfg
    kv = {}
    for s in sets:
        k, v = s.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        kv[k] = v
    return dataclasses.replace(cfg, **kv)


def build_lowered(arch: str, shape_name: str, mesh, *,
                  serve_mode: str = "serve", sets=None,
                  accum: Optional[int] = None):
    """Lower one cell on ``mesh``. Returns (lowered, meta)."""
    cfg = _apply_sets(configs.get_config(arch), sets)
    shape = configs.SHAPES[shape_name]
    batch_abs = configs.input_specs(cfg, shape)
    chips = mesh.devices.size

    arules = rules_lib.act_rules(mesh, "train" if shape.kind == "train" else "serve")

    if shape.kind == "train":
        tcfg = presets.train_preset(cfg, shape.global_batch)
        if accum is not None:
            tcfg = dataclasses.replace(tcfg, accum_steps=accum)
        state_abs = train_loop.abstract_state(cfg, tcfg)
        state_sh = rules_lib.train_state_shardings(
            cfg, mesh, compression=tcfg.compression.enabled)
        batch_sh = rules_lib.batch_shardings(batch_abs, mesh)
        step = train_loop.make_train_step(cfg, tcfg,
                                          grad_shardings=state_sh.params)
        prules = rules_lib.param_rules(mesh, "train")

        def wrapped(state, batch):
            with shlib.use_rules(arules), shlib.use_param_rules(prules):
                return step(state, batch)

        rep = rules_lib.replicated(mesh)
        # lint: ignore[recompile-hazard] -- dryrun lowers each preset
        # exactly once per invocation; the closure carries the mesh rules
        jitted = jax.jit(
            wrapped,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,))
        with mesh:
            lowered = jitted.lower(state_abs, batch_abs)
        meta = {"accum_steps": tcfg.accum_steps,
                "moment_dtype": str(tcfg.opt.moment_dtype.__name__
                                    if hasattr(tcfg.opt.moment_dtype, "__name__")
                                    else tcfg.opt.moment_dtype)}
        return lowered, cfg, meta

    # ---- serving cells ----
    if serve_mode == "auto":
        # replicate weights over "data" when they fit beside the cache
        # (TP keeps 1/16th per device); FSDP-gather serving otherwise
        serve_mode = ("serve_replicated"
                      if cfg.param_count() * 2 / 16 < 8e9 else "serve")
    params_abs = model_zoo.abstract_params(cfg)
    params_sh = rules_lib.param_shardings(cfg, mesh, serve_mode)
    cache_abs = model_zoo.init_cache(cfg, shape.global_batch, shape.seq_len,
                                     abstract=True)
    cache_sh = rules_lib.cache_shardings(cfg, cache_abs, mesh, "serve")

    if shape.kind == "prefill":
        batch_sh = rules_lib.batch_shardings(batch_abs, mesh)

        def serve_step(params, batch, cache):
            with shlib.use_rules(arules):
                return model_zoo.prefill(cfg, params, batch, cache)

        # lint: ignore[recompile-hazard] -- dryrun lowers each preset
        # exactly once per invocation; the closure carries the mesh rules
        jitted = jax.jit(serve_step,
                         in_shardings=(params_sh, batch_sh, cache_sh),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs, cache_abs)
        return lowered, cfg, {}

    # decode: one new token against a full cache
    B = shape.global_batch
    tok_abs = batch_abs["tokens"]
    t_abs = batch_abs["t"]
    brules = rules_lib.batch_shardings({"tokens": tok_abs}, mesh)
    tok_sh = brules["tokens"]

    def serve_step(params, cache, tokens, t):
        with shlib.use_rules(arules):
            return model_zoo.decode(cfg, params, cache, tokens, t)

    # lint: ignore[recompile-hazard] -- dryrun lowers each preset
    # exactly once per invocation; the closure carries the mesh rules
    jitted = jax.jit(serve_step,
                     in_shardings=(params_sh, cache_sh, tok_sh, tok_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(params_abs, cache_abs, tok_abs, t_abs)
    return lowered, cfg, {}


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             serve_mode: str = "serve", sets=None, accum=None,
             out_dir: Optional[str] = None, tag: str = "") -> Dict[str, Any]:
    """Lower + compile one cell; returns (and persists) the analysis dict."""
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    shape = configs.SHAPES[shape_name]
    t0 = time.time()
    lowered, cfg, meta = build_lowered(arch, shape_name, mesh,
                                       serve_mode=serve_mode, sets=sets,
                                       accum=accum)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # -- memory ------------------------------------------------------------
    mem: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = float(v)
        # bytes resident per device during the step (args are sharded;
        # aliased/donated outputs don't double-count)
        mem["per_device_total"] = (mem.get("argument_size_in_bytes", 0.0)
                                   + mem.get("output_size_in_bytes", 0.0)
                                   - mem.get("alias_size_in_bytes", 0.0)
                                   + mem.get("temp_size_in_bytes", 0.0))
    except Exception as e:   # CPU backend may not implement it
        mem["error"] = str(e)

    # -- roofline ----------------------------------------------------------
    hlo = compiled.as_text()
    roof, detail = hlo_analysis.roofline_from_compiled(compiled, chips,
                                                       hlo_text=hlo)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = hlo_analysis.model_flops(cfg, shape.kind, tokens,
                                  seq_len=shape.seq_len,
                                  batch=shape.global_batch)
    mf_per_dev = mf / chips
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "kind": shape.kind,
        "lower_s": t_lower, "compile_s": t_compile,
        "memory": mem,
        "roofline": roof.to_dict(),
        "model_flops_per_device": mf_per_dev,
        "useful_ratio": (mf_per_dev / roof.flops_per_device
                         if roof.flops_per_device else 0.0),
        "roofline_fraction": roof.fraction_of_roofline(mf_per_dev),
        "collectives": detail["collectives"],
        "collective_counts": detail["counts"],
        "meta": meta,
    }
    if shape.kind == "decode":
        # The handoff number to the serving layer: a cost-modeled
        # TierSpec serving this arch adopts exactly this step time
        # (repro.launch.tier_cost derives it from the same Roofline).
        result["decode_step_ms"] = roof.step_s * 1e3
    if out_dir is None:
        out_dir = os.path.join(RESULTS_DIR, mesh_kind)
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list(configs.ARCHS))
    ap.add_argument("--shape", default=None, choices=list(configs.SHAPES))
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true", help="every valid cell")
    ap.add_argument("--serve-mode", default="serve",
                    choices=("serve", "serve_replicated", "auto"))
    ap.add_argument("--set", action="append", default=None,
                    help="config override key=value (repeatable)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--tag", default="", help="result filename suffix")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        cells = configs.valid_cells()
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all is given")
        cells = [(args.arch, args.shape)]

    failures = []
    for mesh_kind in meshes:
        for arch, shape in cells:
            if not configs.cell_is_valid(arch, shape):
                continue
            label = f"[{mesh_kind}] {arch} x {shape}"
            try:
                r = run_cell(arch, shape, mesh_kind,
                             serve_mode=args.serve_mode, sets=args.set,
                             accum=args.accum, tag=args.tag,
                             out_dir=args.out_dir)
                rf = r["roofline"]
                print(f"{label}: OK compile={r['compile_s']:.1f}s "
                      f"mem/dev={r['memory'].get('per_device_total', 0)/2**30:.2f}GiB "
                      f"compute={rf['compute_s']*1e3:.2f}ms "
                      f"memory={rf['memory_s']*1e3:.2f}ms "
                      f"collective={rf['collective_s']*1e3:.2f}ms "
                      f"dominant={rf['dominant']} "
                      f"roofline_frac={r['roofline_fraction']:.3f}",
                      flush=True)
            except Exception as e:
                failures.append((label, repr(e)))
                print(f"{label}: FAIL {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cell(s) failed: "
                         + "; ".join(l for l, _ in failures))


if __name__ == "__main__":
    main()
