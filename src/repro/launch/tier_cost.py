"""Cost-model-derived tier capacity: one roofline for sim AND live.

A :class:`~repro.core.topology.TierSpec` that names a ``model`` (and
optionally a ``mesh_shape``) no longer hand-sets its simulator speed or
its slot count.  Both are derived here, from the same
:mod:`repro.launch.hlo_cost` trip-count-aware walk that prices the
dry-run's compiled HLO:

* **decode_step_ms** — a synthetic tensor-parallel decode-step HLO for
  the tier's architecture (weight-streaming dots per layer, KV-cache
  read traffic, the production psum collectives: two ``all-reduce``
  per layer plus the embed/logits ``all-gather``) is priced by
  :func:`repro.launch.hlo_cost.analyze_hlo` and turned into a
  :class:`~repro.launch.hlo_analysis.Roofline`; the step time is the
  max of the compute / HBM / interconnect terms.
* **slots** — the requested concurrency clamped to how many KV rows
  actually fit next to the (sharded) parameters in per-device HBM.
* **service_rate_mult** — the simulator's relative speed, defined as
  ``ref_step / step`` against the chain's first cost-modeled tier, so
  the ingress tier's multiplier is exactly 1.0 and the simulator's
  ``edge_service_s / mult`` scaling preserves its calibration point.

Two tensor-parallel schemes coexist deliberately (see
docs/architecture.md "Sharded tiers & the cost model"): this *pricing*
scheme is the production psum layout (row-parallel projections,
all-reduce per layer, everything divided by ``tp`` with head counts
ceil'd), while the *live* sharded endpoint
(:mod:`repro.serving.sharded`) uses an exact weight-gather layout whose
token stream is bit-identical to the unsharded engine.  The psum
scheme is what a deployment at mesh scale would run; the exact scheme
is what lets CPU tests pin parity.

Hardware constants are the TPU-v5e numbers from
:mod:`repro.launch.hlo_analysis` plus the 16 GB HBM budget below.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.launch import hlo_cost
from repro.launch.hlo_analysis import Roofline

HBM_BYTES = 16e9          # TPU v5e: 16 GB HBM per chip
HBM_RESERVE_BYTES = 1e9   # runtime/program/workspace reserve per chip


def _itemsize(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


def _dtype_token(dtype) -> str:
    """HLO dtype token for a numpy/jax dtype (bf16 for 2-byte floats)."""
    size = _itemsize(dtype)
    kind = np.dtype(dtype).kind
    if kind == "i":
        return {1: "s8", 2: "s16", 4: "s32", 8: "s64"}[size]
    return {2: "bf16", 4: "f32", 8: "f64"}[size]


# --------------------------------------------------------------------------
# Per-device dimensions of the psum tensor-parallel decode step
# --------------------------------------------------------------------------


def _tp_dims(cfg, tp: int) -> Dict[str, int]:
    """Local (per-device) dimensions under ``tp``-way tensor parallelism.

    Head counts ceil: with more devices than KV heads the heads are
    replicated across subgroups (each device still holds >= 1), which is
    what a real deployment does — the cost model charges that honestly
    instead of pretending fractional heads.
    """
    lq = -(-cfg.num_heads // tp)              # local query heads
    lkv = -(-cfg.num_kv_heads // tp)          # local kv heads
    return {
        "d": cfg.d_model,                     # activations stay full
        "dl": -(-cfg.d_model // tp),          # embed table slice
        "Qd": lq * cfg.head_dim,
        "KVd": lkv * cfg.head_dim,
        "Fl": -(-cfg.d_ff // tp),
        "Vl": -(-cfg.vocab_size // tp),
        "lq": lq,
        "lkv": lkv,
    }


def params_bytes_per_device(cfg, tp: int) -> float:
    """Weight bytes resident per device under the psum TP layout.

    Matches the synthetic HLO's weight set: per layer q/k/v/o + the
    (swiglu) MLP mats, all column/row-sharded over ``tp`` with head
    counts ceil'd; embed and lm_head sharded; norms replicated.
    """
    t = _tp_dims(cfg, tp)
    d, Qd, KVd, Fl, dl = t["d"], t["Qd"], t["KVd"], t["Fl"], t["dl"]
    per_layer = (d * Qd + 2 * d * KVd + Qd * d     # wq, wk, wv, wo
                 + 2 * d * Fl + Fl * d             # wi, wg, wo(mlp)
                 + 4 * d)                          # norms (replicated)
    head = cfg.vocab_size * dl * (1 if cfg.tie_embeddings else 2) + 2 * d
    return float(cfg.num_layers * per_layer + head) * _itemsize(cfg.param_dtype)


def kv_row_bytes_per_device(cfg, tp: int, max_len: int) -> float:
    """KV-cache bytes one resident request costs per device.

    The cache shards its kv-head axis over the model axis (ceil'd), the
    rolling-window width caps the sequence extent, and the per-position
    ``pos`` ledger is replicated (it is int32 and tiny).
    """
    t = _tp_dims(cfg, tp)
    width = max_len
    if cfg.sliding_window is not None:
        width = min(width, cfg.sliding_window)
    kv = 2 * width * t["lkv"] * cfg.head_dim * _itemsize(cfg.compute_dtype)
    pos = width * 4
    return float(cfg.num_layers * (kv + pos))


# --------------------------------------------------------------------------
# Synthetic decode-step HLO (priced by hlo_cost.analyze_hlo)
# --------------------------------------------------------------------------


def decode_step_hlo(cfg, *, tp: int, batch: int, max_len: int) -> str:
    """One tensor-parallel decode step as HLO text.

    The layer body sits in a ``while`` with ``known_trip_count =
    num_layers`` (exactly what jax's scan-over-layers compiles to), so
    the trip-count-aware walk charges weights and cache reads once per
    layer per step.  Weights are typed constants: free to "compute" but
    charged as operand reads by the consuming dots — the weight-
    streaming traffic that makes small-batch decode memory-bound.
    Collectives carry ``replica_groups=[1,tp]`` so the analyzer prices
    the psum scheme's two per-layer all-reduces and the embed/logits
    all-gathers at the right group size.
    """
    t = _tp_dims(cfg, tp)
    B = int(batch)
    d, dl, Qd, KVd, Fl, Vl = (t["d"], t["dl"], t["Qd"], t["KVd"],
                              t["Fl"], t["Vl"])
    W = max_len if cfg.sliding_window is None else min(max_len,
                                                       cfg.sliding_window)
    A = B * t["lq"]                           # attention rows, all local heads
    V = cfg.vocab_size
    L = cfg.num_layers
    adt = _dtype_token(cfg.compute_dtype)
    wdt = _dtype_token(cfg.param_dtype)

    def ar(name: str, src: str) -> str:
        return (f"  %{name} = {adt}[{B},{d}] all-reduce(%{src}), "
                f"replica_groups=[1,{tp}], to_apply=%red_add")

    body = [
        f"%body (p: (s32[], {adt}[{B},{d}])) -> (s32[], {adt}[{B},{d}]) {{",
        f"  %p = (s32[], {adt}[{B},{d}]) parameter(0)",
        "  %i = s32[] get-tuple-element(%p), index=0",
        f"  %x = {adt}[{B},{d}] get-tuple-element(%p), index=1",
        # attention norm (elementwise, replicated)
        f"  %xn = {adt}[{B},{d}] multiply(%x, %x)",
        # qkv projections against column-sharded weights
        f"  %wq = {wdt}[{d},{Qd}] constant(0)",
        f"  %q = {adt}[{B},{Qd}] dot(%xn, %wq), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
        f"  %wk = {wdt}[{d},{KVd}] constant(0)",
        f"  %k = {adt}[{B},{KVd}] dot(%xn, %wk), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
        f"  %wv = {wdt}[{d},{KVd}] constant(0)",
        f"  %v = {adt}[{B},{KVd}] dot(%xn, %wv), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
        # KV cache: the full local window is streamed from HBM each step
        f"  %kc = {adt}[{B},{W},{KVd}] constant(0)",
        f"  %vc = {adt}[{B},{W},{KVd}] constant(0)",
        "  %z0 = f32[] constant(0)",
        "  %kr = f32[] reduce(%kc, %z0), dimensions={0,1,2}, "
        "to_apply=%red_add",
        "  %vr = f32[] reduce(%vc, %z0), dimensions={0,1,2}, "
        "to_apply=%red_add",
        # scores + values over the cached window (per local head)
        f"  %qh = {adt}[{A},{cfg.head_dim}] reshape(%q)",
        f"  %kt = {adt}[{cfg.head_dim},{W}] reshape(%kc)",
        f"  %sc = f32[{A},{W}] dot(%qh, %kt), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
        f"  %pr = {adt}[{A},{W}] convert(%sc)",
        f"  %vt = {adt}[{W},{cfg.head_dim}] reshape(%vc)",
        f"  %av = {adt}[{A},{cfg.head_dim}] dot(%pr, %vt), "
        "lhs_contracting_dims={1}, rhs_contracting_dims={0}",
        f"  %oi = {adt}[{B},{Qd}] reshape(%av)",
        # o-projection (row-parallel) + psum
        f"  %wo = {wdt}[{Qd},{d}] constant(0)",
        f"  %o = {adt}[{B},{d}] dot(%oi, %wo), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
    ]
    o_out = "o"
    if tp > 1:
        body.append(ar("oar", "o"))
        o_out = "oar"
    body += [
        f"  %r1 = {adt}[{B},{d}] add(%x, %{o_out})",
        f"  %rn = {adt}[{B},{d}] multiply(%r1, %r1)",    # mlp norm
        f"  %wi = {wdt}[{d},{Fl}] constant(0)",
        f"  %gi = {adt}[{B},{Fl}] dot(%rn, %wi), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
        f"  %wg = {wdt}[{d},{Fl}] constant(0)",
        f"  %gg = {adt}[{B},{Fl}] dot(%rn, %wg), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
        f"  %ga = {adt}[{B},{Fl}] multiply(%gi, %gg)",
        f"  %wd = {wdt}[{Fl},{d}] constant(0)",
        f"  %md = {adt}[{B},{d}] dot(%ga, %wd), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
    ]
    m_out = "md"
    if tp > 1:
        body.append(ar("mar", "md"))
        m_out = "mar"
    body += [
        f"  %r2 = {adt}[{B},{d}] add(%r1, %{m_out})",
        "  %one = s32[] constant(1)",
        "  %i2 = s32[] add(%i, %one)",
        f"  ROOT %t = (s32[], {adt}[{B},{d}]) tuple(%i2, %r2)",
        "}",
    ]

    cond = [
        f"%cond (p: (s32[], {adt}[{B},{d}])) -> pred[] {{",
        f"  %p = (s32[], {adt}[{B},{d}]) parameter(0)",
        "  %i = s32[] get-tuple-element(%p), index=0",
        f"  %n = s32[] constant({L})",
        "  ROOT %lt = pred[] compare(%i, %n), direction=LT",
        "}",
    ]

    red = [
        "%red_add (a: f32[], b: f32[]) -> f32[] {",
        "  %a = f32[] parameter(0)",
        "  %b = f32[] parameter(1)",
        "  ROOT %s = f32[] add(%a, %b)",
        "}",
    ]

    entry = [
        f"ENTRY %tier_decode (tok: s32[{B}]) -> f32[{B},{V}] {{",
        f"  %tok = s32[{B}] parameter(0)",
        f"  %emb_t = {wdt}[{V},{dl}] constant(0)",
        f"  %emb = {adt}[{B},{dl}] gather(%emb_t, %tok), "
        "offset_dims={1}, collapsed_slice_dims={0}, start_index_map={0}, "
        f"index_vector_dim=1, slice_sizes={{1,{dl}}}",
    ]
    x0 = "emb"
    if tp > 1:
        entry.append(f"  %embf = {adt}[{B},{d}] all-gather(%emb), "
                     f"replica_groups=[1,{tp}], dimensions={{1}}")
        x0 = "embf"
    entry += [
        "  %c0 = s32[] constant(0)",
        f"  %t0 = (s32[], {adt}[{B},{d}]) tuple(%c0, %{x0})",
        f"  %w = (s32[], {adt}[{B},{d}]) while(%t0), condition=%cond, "
        "body=%body, backend_config={\"known_trip_count\":{\"n\":\"" +
        str(L) + "\"}}",
        f"  %xf = {adt}[{B},{d}] get-tuple-element(%w), index=1",
        f"  %wl = {wdt}[{d},{Vl}] constant(0)",
        f"  %lg = f32[{B},{Vl}] dot(%xf, %wl), lhs_contracting_dims={{1}}, "
        "rhs_contracting_dims={0}",
    ]
    if tp > 1:
        entry.append(f"  ROOT %lgf = f32[{B},{V}] all-gather(%lg), "
                     f"replica_groups=[1,{tp}], dimensions={{1}}")
    else:
        entry[-1] = entry[-1].replace("  %lg =", "  ROOT %lg =").replace(
            f"f32[{B},{Vl}]", f"f32[{B},{V}]", 1)
    entry.append("}")

    return "\n".join(["HloModule tier_decode", ""] + red + [""] + cond
                     + [""] + body + [""] + entry) + "\n"


# --------------------------------------------------------------------------
# Registered single-source formulas (see repro.analysis.registry)
# --------------------------------------------------------------------------


def derived_slot_capacity(requested_slots: int, hbm_bytes: float,
                          params_bytes: float, reserve_bytes: float,
                          kv_row_bytes: float) -> int:
    """The ONE slot-capacity formula for cost-modeled tiers.

    Slots = requested concurrency clamped to the KV rows that fit next
    to the resident (sharded) weights in per-device HBM.  Both the
    simulator's ``_SimTier`` pools and the live tier's endpoint are
    built from the resolved spec, so this must have exactly one home.
    """
    if kv_row_bytes <= 0.0:
        raise ValueError(f"kv_row_bytes must be > 0, got {kv_row_bytes}")
    free_bytes = float(hbm_bytes) - float(params_bytes) - float(reserve_bytes)
    if free_bytes < kv_row_bytes:
        raise ValueError(
            f"model does not fit: {params_bytes / 1e9:.2f} GB params "
            f"+ {reserve_bytes / 1e9:.2f} GB reserve leave "
            f"{free_bytes / 1e9:.2f} GB for KV rows of "
            f"{kv_row_bytes / 1e6:.1f} MB")
    fit = int(free_bytes // kv_row_bytes)
    return max(1, min(int(requested_slots), fit))


def derived_service_rate_mult(ref_step_s: float, step_s: float) -> float:
    """The ONE derived-rate formula: relative speed vs the chain's first
    cost-modeled tier, so the reference tier's multiplier is exactly 1.0
    and the simulator's ``edge_service_s / mult`` calibration holds."""
    if ref_step_s <= 0.0 or step_s <= 0.0:
        raise ValueError(
            f"decode step times must be > 0, got ref={ref_step_s} "
            f"step={step_s}")
    return float(ref_step_s) / float(step_s)


# --------------------------------------------------------------------------
# Tier costing + spec resolution
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TierCost:
    """The derived numbers for one cost-modeled tier."""

    arch: str
    mesh_shape: Tuple[int, ...]
    devices: int
    requested_slots: int
    slots: int                       # requested clamped to the KV fit
    kv_fit_slots: int
    decode_step_s: float             # at batch == slots
    params_bytes_per_device: float
    kv_row_bytes_per_device: float
    roofline: Dict[str, float]       # Roofline.to_dict() of the step

    @property
    def decode_step_ms(self) -> float:
        return self.decode_step_s * 1e3


def tier_cost(arch: str, *, mesh_shape: Optional[Tuple[int, ...]] = None,
              requested_slots: int = 4, max_len: int = 256,
              hbm_bytes: float = HBM_BYTES,
              reserve_bytes: float = HBM_RESERVE_BYTES) -> TierCost:
    """Price one tier: derived slots + decode step time + roofline."""
    from repro import configs
    cfg = configs.get_config(arch)
    if cfg.family != "dense":
        raise ValueError(
            f"tier cost model covers the dense family only, "
            f"{arch!r} is {cfg.family!r}")
    shape = tuple(int(a) for a in (mesh_shape or (1, 1)))
    tp = 1
    for a in shape:
        tp *= a
    pb = params_bytes_per_device(cfg, tp)
    kvb = kv_row_bytes_per_device(cfg, tp, max_len)
    free = hbm_bytes - pb - reserve_bytes
    fit = int(free // kvb) if free >= kvb else 0
    slots = derived_slot_capacity(requested_slots, hbm_bytes, pb,
                                  reserve_bytes, kvb)
    hlo = decode_step_hlo(cfg, tp=tp, batch=slots, max_len=max_len)
    hc = hlo_cost.analyze_hlo(hlo)
    roof = Roofline(hc["flops"], hc["bytes"], hc["collective_wire_bytes"],
                    chips=tp, mxu_flops_per_device=hc["mxu_flops"])
    return TierCost(
        arch=arch, mesh_shape=shape, devices=tp,
        requested_slots=int(requested_slots), slots=slots, kv_fit_slots=fit,
        decode_step_s=roof.step_s,
        params_bytes_per_device=pb, kv_row_bytes_per_device=kvb,
        roofline=roof.to_dict())


def resolve_specs(specs: Sequence, *, hbm_bytes: float = HBM_BYTES,
                  reserve_bytes: float = HBM_RESERVE_BYTES) -> Tuple:
    """Resolve every cost-modeled TierSpec in a chain.

    Cost-modeled specs (``model`` set) get derived ``slots``,
    ``decode_step_ms`` and ``service_rate_mult``; hand-set specs pass
    through untouched (including ``Topology.pair``'s elastic-cloud
    ``service_rate_mult=None`` sentinel, which keeps its positional-
    default meaning).  The rate reference is the first cost-modeled
    tier in chain order, so a cost-modeled ingress runs at multiplier
    1.0 — the simulator's ``edge_service_s`` calibration point.
    """
    costs = [tier_cost(s.model, mesh_shape=s.mesh_shape,
                       requested_slots=s.slots, max_len=s.max_len,
                       hbm_bytes=hbm_bytes, reserve_bytes=reserve_bytes)
             if getattr(s, "model", None) is not None else None
             for s in specs]
    ref = next((c.decode_step_s for c in costs if c is not None), None)
    out = []
    for s, c in zip(specs, costs):
        if c is None:
            out.append(s)
            continue
        mult = derived_service_rate_mult(ref, c.decode_step_s)
        out.append(dataclasses.replace(
            s, slots=c.slots, decode_step_ms=c.decode_step_ms,
            service_rate_mult=mult))
    return tuple(out)
