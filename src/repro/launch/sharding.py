"""Mode-specific logical-axis -> mesh-axis rule tables + spec builders.

Two rule sets per mode (train / serve):

* **param rules** — how parameter (and optimizer/cache state) dimensions
  map to the mesh;
* **act rules**   — how in-graph activation constraints (``shd``) map.

The same logical name can map differently in each set ("embed" is
FSDP-sharded on params but replicated on activations).

Baseline layout (hillclimbed variants live in EXPERIMENTS.md §Perf):

train  = FSDP("data") x TP("model") x DP("pod"):
    params/opt:  embed->data (ZeRO-3 style), heads/ffn/vocab->model
    activations: batch->(pod,data), seq->model between layers (Megatron-
                 style sequence parallelism for the remat boundaries),
                 heads/ffn->model inside blocks
serve  = same weight layout (memory-safe for the 405B/340B archs) with
    batch->(pod,data) and the KV-cache sequence dim -> model
    (flash-decode: each model shard owns a slice of the context).

Divisibility fallbacks happen in ``AxisRules.spec`` (e.g. hymba's 25 heads
simply stay replicated on a 16-way model axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig
from repro.sharding import AxisRules

PyTree = Any

_BATCH = ("pod", "data")          # mesh axes used for the batch dim


def param_rules(mesh: Mesh, mode: str) -> AxisRules:
    """Parameter-dimension rules (also applied to optimizer moments)."""
    fsdp = ("data",) if "data" in mesh.axis_names else ()
    table: Dict[str, Any] = {
        "embed": fsdp,            # ZeRO-3: shard the model dim over data
        "embed_table": "model",
        "vocab_in": fsdp,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "experts": None,          # EP variant flips this to "model"
        "ssm_inner": "model",
        "layers": None,
    }
    if mode == "serve_replicated":
        # Small-model serving: weights replicated over data, TP over model.
        table = dict(table, embed=None, vocab_in=None)
    return AxisRules(mesh, table)


def act_rules(mesh: Mesh, mode: str) -> AxisRules:
    """Activation (``shd``) rules."""
    batch = tuple(a for a in _BATCH if a in mesh.axis_names)
    table: Dict[str, Any] = {
        "batch": batch,
        "seq": "model" if mode == "train" else None,   # Megatron SP boundaries
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "embed": None,
        "vocab": "model",
        "cache_seq": "model",
        "experts": None,
        "ssm_inner": "model",
    }
    return AxisRules(mesh, table)


# ---------------------------------------------------------------------------
# Whole-pytree spec builders (feed jit in_shardings / out_shardings)
# ---------------------------------------------------------------------------


def param_shardings(cfg: ModelConfig, mesh: Mesh, mode: str) -> Dict[str, NamedSharding]:
    """NamedSharding per parameter path, from the ParamSpec logical axes."""
    from repro.models import model_zoo
    rules = param_rules(mesh, mode)
    table = model_zoo.param_table(cfg)
    return {path: rules.sharding(spec.axes, spec.shape)
            for path, spec in table.items()}


def _cache_leaf_spec(key: str, shape: Tuple[int, ...], rules: AxisRules,
                     stacked: bool) -> P:
    """Logical axes of one KV/state cache leaf, by key name.

    Layout (scan mode adds a leading "layers" dim):
      k/v:   (B, W, Hkv, Dh)    pos: (B, W)
      tm_x/cm_x: (B, d)         tm_s: (B, H, D, D)
      h:     (B, I, N)          conv: (B, K-1, I)
    """
    base = {
        "k": ("batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
        "pos": ("batch", "cache_seq"),
        "tm_x": ("batch", None),
        "cm_x": ("batch", None),
        "tm_s": ("batch", "heads", None, None),
        "h": ("batch", "ssm_inner", None),
        "conv": ("batch", None, "ssm_inner"),
    }[key]
    axes = (("layers",) + base) if stacked else base
    axes = axes[:len(shape)]
    return rules.spec(axes, shape)


def cache_shardings(cfg: ModelConfig, cache_abstract: PyTree, mesh: Mesh,
                    mode: str) -> PyTree:
    """NamedSharding pytree matching a cache pytree (scan dict or layer list)."""
    rules = act_rules(mesh, mode)
    # The cache logical table routes "layers" to nothing; batch/cache_seq per
    # the act rules. kv_heads on the cache follows the act rules too, but the
    # cache_seq dim usually wins the "model" axis (listed first).
    stacked = isinstance(cache_abstract, dict)

    def one(tree):
        return {k: NamedSharding(mesh, _cache_leaf_spec(k, v.shape, rules, stacked))
                for k, v in tree.items()}

    if stacked:
        return one(cache_abstract)
    return [one(layer) for layer in cache_abstract]


def batch_shardings(batch_abstract: Dict[str, jax.ShapeDtypeStruct],
                    mesh: Mesh) -> Dict[str, NamedSharding]:
    """Input batches shard their leading (global-batch) dim over (pod, data)."""
    batch = tuple(a for a in _BATCH if a in mesh.axis_names)
    out = {}
    for k, v in batch_abstract.items():
        axes: Tuple[Any, ...] = (batch,) + (None,) * (len(v.shape) - 1)
        # drop if not divisible (long_500k: B=1 stays replicated)
        rules = AxisRules(mesh, {"b": batch})
        out[k] = rules.sharding(("b",) + (None,) * (len(v.shape) - 1), v.shape)
    return out


def opt_state_shardings(param_sh: Dict[str, NamedSharding], mesh: Mesh):
    """Optimizer moments mirror their parameter's sharding; step replicated."""
    from repro.training.optimizer import OptState
    rep = NamedSharding(mesh, P())
    return OptState(step=rep, mu=dict(param_sh), nu=dict(param_sh))


def train_state_shardings(cfg: ModelConfig, mesh: Mesh, *,
                          compression: bool = False):
    """Shardings for the full TrainState pytree."""
    from repro.training.train_loop import TrainState
    psh = param_shardings(cfg, mesh, "train")
    err = dict(psh) if compression else None
    return TrainState(params=psh, opt=opt_state_shardings(psh, mesh), err=err)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
