"""Trip-count-aware cost model over compiled HLO text.

``compiled.cost_analysis()`` counts each op ONCE, ignoring control-flow
multiplicity — useless for scan-over-layers models where >95% of work sits
inside ``while`` bodies. This module re-derives FLOPs / memory traffic /
collective traffic by walking the HLO text and **multiplying loop bodies
by their ``known_trip_count``** (stamped by XLA's while-loop analysis;
jax's ``lax.scan`` always produces statically-counted loops).

Cost rules (mirroring xla::HloCostAnalysis, applied per instruction):

* ``dot``      — 2 x prod(result_shape) x prod(lhs contracting dims) FLOPs.
* elementwise / reduce / rng — 1 FLOP per output (reduce: per input) elem.
* ``fusion``   — FLOPs from the fused computation; BYTES from the fusion
  boundary only (operands + result), which is XLA's memory-traffic model.
* ``while``    — (body + condition) x trip_count.
* ``call``/``conditional`` — sum of called computations.
* collectives  — recorded with their loop multiplier, result bytes and
  replica-group size (converted to operand/wire bytes by the caller).
* ``copy``/``transpose`` at computation level — bytes only.
* free ops (bitcast, tuple, get-tuple-element, parameter, constant,
  broadcast, iota, reshape) — 0.

The result is the per-device cost of one step of the SPMD program — the
numbers the §Roofline terms are built from.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*(\(.*\))?(?:\s*->\s*[^{]+)?\s*\{\s*$")
_COMMENT_RE = re.compile(r"/\*[^*]*\*/")
_PARAM_RE = re.compile(
    r"([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count["\s:{]+n["\s:]+(\d+)')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_FREE_OPS = frozenset((
    "bitcast", "tuple", "get-tuple-element", "parameter", "constant",
    "broadcast", "iota", "reshape", "after-all", "partition-id",
    "replica-id", "opt-barrier", "custom-call", "bitcast-convert",
))

# Ops that make a fusion a "pure dtype/layout cast". XLA:CPU's float
# normalization materializes fp32 copies of every bf16 dot operand (the
# CPU has no native bf16 FMA); the TPU MXU consumes bf16 directly and such
# casts fuse into the dot's operand feed. Pure-cast fusions are therefore
# charged min(input, output) bytes and zero flops — the TPU-roofline view.
_PURE_CAST_OPS = frozenset((
    "parameter", "constant", "convert", "bitcast", "copy", "transpose",
    "reshape", "broadcast", "iota", "bitcast-convert",
))
_COLLECTIVES = frozenset((
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "reduce-scatter-start", "all-to-all-start", "collective-permute-start",
))


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(text: str) -> float:
    total = 0.0
    for _, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _dims_of(type_text: str) -> List[int]:
    m = _SHAPE_RE.search(type_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    type_text: str
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]            # param name -> type text
    instrs: List[Instr]


@dataclasses.dataclass
class Collective:
    kind: str
    result_bytes: float
    group_size: int
    multiplier: float
    op_name: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0           # total (MXU + elementwise)
    mxu_flops: float = 0.0       # dot/convolution only
    bytes: float = 0.0
    transcendentals: float = 0.0

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.flops + o.flops, self.mxu_flops + o.mxu_flops,
                    self.bytes + o.bytes,
                    self.transcendentals + o.transcendentals)

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.mxu_flops * k, self.bytes * k,
                    self.transcendentals * k)


def _operand_list(line: str) -> List[str]:
    """Extract top-level %operand names from ``op(...)`` in the line."""
    i = line.find("(", line.find("=") + 1)
    # find the '(' right after the op name (skip the type which may contain
    # parens for tuples): search after the op match instead
    m = _INSTR_RE.match(line)
    if not m:
        return []
    start = m.end() - 1
    depth, j = 0, start
    while j < len(line):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
        j += 1
    inner = line[start + 1:j]
    return re.findall(r"%([\w.\-]+)", inner)


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = _COMMENT_RE.sub("", raw.rstrip())
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            # instruction lines carry " = " before the first paren; headers
            # never do — that distinguishes them robustly.
            if m and "=" not in line.split("(", 1)[0]:
                params = {}
                if m.group(3):
                    for pname, ptype in _PARAM_RE.findall(m.group(3)):
                        params[pname] = ptype
                cur = Computation(m.group(2), params, [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line.strip())
        if m:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    _operand_list(line.strip()), line.strip()))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return 1


class HloCostModel:
    """Walks the parsed module, scaling loop bodies by trip count."""

    def __init__(self, hlo_text: str, trace: bool = False):
        self.comps, self.entry = parse_module(hlo_text)
        self.collectives: List[Collective] = []
        self._memo: Dict[Tuple, Cost] = {}
        self.trace: Optional[List] = [] if trace else None

    # -- per-instruction flop rules ----------------------------------------
    def _instr_flops(self, ins: Instr, comp: Computation,
                     types: Dict[str, str]) -> float:
        op = ins.op
        if op == "dot":
            out_elems = _shape_elems(ins.type_text)
            contract = 1.0
            mc = _CONTRACT_RE.search(ins.line)
            lhs_t = types.get(ins.operands[0], "") if ins.operands else ""
            dims = _dims_of(lhs_t)
            if mc and dims:
                for d in mc.group(1).split(","):
                    if d != "" and int(d) < len(dims):
                        contract *= dims[int(d)]
            return 2.0 * out_elems * contract
        if op in ("reduce", "reduce-window"):
            in_elems = sum(_shape_elems(types.get(o, ""))
                           for o in ins.operands[:1])
            return in_elems
        if op in ("convolution",):
            return 2.0 * _shape_elems(ins.type_text)   # unused by these models
        if op in _FREE_OPS or op in _COLLECTIVES or op in (
                "while", "conditional", "call", "fusion", "copy", "transpose",
                "dynamic-slice", "dynamic-update-slice", "slice", "concatenate",
                "gather", "scatter", "pad", "reverse", "select-and-scatter",
                "convert", "compare", "select", "rng", "rng-bit-generator"):
            if op in ("compare", "select"):
                return _shape_elems(ins.type_text)
            return 0.0          # convert: fuses into the consumer on TPU
        # elementwise arithmetic (add/multiply/exp/...)
        return _shape_elems(ins.type_text)

    # -- effective bytes ----------------------------------------------------
    # ``eff`` maps value name -> effective buffer bytes: the narrowest dtype
    # the value had upstream of pure casts. XLA:CPU widens every bf16 dot
    # operand to a materialized fp32 copy (no native bf16 FMA); the TPU MXU
    # consumes bf16 directly, so reads are charged at the pre-cast size and
    # the cast copies themselves are free. Tuples carry per-element lists.

    @staticmethod
    def _flat_eff(v) -> float:
        if isinstance(v, list):
            return sum(HloCostModel._flat_eff(x) for x in v)
        return float(v)

    def _eff_of(self, o: str, types: Dict[str, str], eff: Dict) -> float:
        v = eff.get(o)
        if v is None:
            return _shape_bytes(types.get(o, ""))
        return self._flat_eff(v)

    def _instr_bytes(self, ins: Instr, types: Dict[str, str],
                     eff: Optional[Dict] = None) -> float:
        eff = eff if eff is not None else {}
        if ins.op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "after-all", "iota", "partition-id",
                      "replica-id", "opt-barrier", "reshape"):
            return 0.0
        res = _shape_bytes(ins.type_text)
        rd = lambda o: self._eff_of(o, types, eff)
        if ins.op == "convert":
            return 0.0                       # charged at the consumer
        if ins.op in ("dynamic-slice", "gather"):
            # reads only the sliced/gathered window + indices, not the
            # whole operand (embedding tables, per-layer cache slices);
            # window read scaled by the operand's effective dtype
            full = _shape_bytes(types.get(ins.operands[0], "")) if ins.operands else res
            ratio = (rd(ins.operands[0]) / full) if full else 1.0
            idx = sum(rd(o) for o in ins.operands[1:])
            return res * min(ratio, 1.0) + res + idx
        if ins.op in ("scatter", "dynamic-update-slice"):
            upd_i = 2 if ins.op == "scatter" else 1
            upd = (rd(ins.operands[upd_i])
                   if len(ins.operands) > upd_i else res)
            idx = sum(rd(o) for o in ins.operands[1:upd_i])
            return 2.0 * upd + idx
        return sum(rd(o) for o in ins.operands) + res

    def _fusion_operand_bytes(self, ins: Instr, types: Dict[str, str],
                              fcomp: "Computation",
                              eff: Optional[Dict] = None) -> float:
        """Effective fusion traffic: operands consumed only via
        dynamic-slice/gather count as the slice, not the full buffer;
        all reads at effective (pre-cast) dtype."""
        eff = eff if eff is not None else {}
        pnames = list(fcomp.params)
        total = 0.0
        for i, opnd in enumerate(ins.operands):
            full = _shape_bytes(types.get(opnd, ""))
            e = self._eff_of(opnd, types, eff)
            ratio = (e / full) if full else 1.0
            if i < len(pnames):
                p = pnames[i]
                uses = [fi for fi in fcomp.instrs if p in fi.operands]
                if uses and all(fi.op in ("dynamic-slice", "gather")
                                and fi.operands and fi.operands[0] == p
                                for fi in uses):
                    win = sum(_shape_bytes(fi.type_text) for fi in uses)
                    total += min(win * min(ratio, 1.0), e)
                    continue
            total += min(e, full)
        return total + _shape_bytes(ins.type_text)

    # -- computation walk ---------------------------------------------------
    @staticmethod
    def _freeze(v):
        if isinstance(v, list):
            return tuple(HloCostModel._freeze(x) for x in v)
        return round(float(v), 3)

    def comp_cost(self, name: str, inside_fusion: bool = False,
                  param_eff: Optional[Dict] = None) -> Cost:
        digest = (tuple(sorted((k, self._freeze(v))
                               for k, v in param_eff.items()))
                  if param_eff else None)
        key = (name, inside_fusion, digest)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        types: Dict[str, str] = dict(comp.params)
        for ins in comp.instrs:
            types[ins.name] = ins.type_text
        eff: Dict = dict(param_eff) if param_eff else {}
        total = Cost()
        for ins in comp.instrs:
            op = ins.op
            if op == "get-tuple-element":
                m = re.search(r"index=(\d+)", ins.line)
                src = ins.operands[0] if ins.operands else None
                v = eff.get(src)
                if m and isinstance(v, list):
                    idx = int(m.group(1))
                    if idx < len(v):
                        eff[ins.name] = v[idx]
                continue
            if op == "tuple":
                eff[ins.name] = [self._eff_of(o, types, eff)
                                 for o in ins.operands]
                continue
            if op == "convert":
                src = ins.operands[0] if ins.operands else None
                eff[ins.name] = min(_shape_bytes(ins.type_text),
                                    self._eff_of(src, types, eff)
                                    if src else 1e30)
                continue
            if op in ("bitcast", "reshape"):
                if ins.operands and ins.operands[0] in eff:
                    eff[ins.name] = eff[ins.operands[0]]
                continue
            if op == "fusion":
                called = _CALLS_RE.search(ins.line)
                inner = (self.comp_cost(called.group(1), inside_fusion=True)
                         if called else Cost())
                fcomp0 = (self.comps.get(called.group(1)) if called else None)
                pure_cast = (fcomp0 is not None and fcomp0.instrs and
                             all(fi.op in _PURE_CAST_OPS
                                 for fi in fcomp0.instrs))
                if pure_cast:
                    opnd = sum(self._eff_of(o, types, eff)
                               for o in ins.operands)
                    eff[ins.name] = min(opnd, _shape_bytes(ins.type_text))
                    continue                 # cast copies fuse away on TPU
                # slice+cast fusions (per-layer weight/cache slices taken
                # from a fp32-widened stacked buffer, bf16-round-tripped):
                # on TPU this is one bf16 dynamic-slice — charge 2x the
                # narrowest same-size representation inside the fusion.
                slice_cast = (fcomp0 is not None and fcomp0.instrs and
                              all(fi.op in _PURE_CAST_OPS
                                  or fi.op in ("dynamic-slice", "slice")
                                  for fi in fcomp0.instrs))
                if slice_cast and not inside_fusion:
                    res_e = _shape_elems(ins.type_text)
                    cands = [_shape_bytes(fi.type_text)
                             for fi in fcomp0.instrs
                             if fi.op not in ("parameter", "constant")
                             and _shape_elems(fi.type_text) == res_e]
                    cands.append(_shape_bytes(ins.type_text))
                    eff_out = min(cands)
                    eff[ins.name] = eff_out
                    # a slice view: consumers charge their own (effective)
                    # reads; charge the one window read here
                    if self.trace is not None:
                        self.trace.append((eff_out, name, "slice-cast",
                                           ins.name, ins.type_text[:48]))
                    total += Cost(0.0, 0.0, eff_out, 0.0)
                    continue
                if inside_fusion:
                    by = 0.0
                elif fcomp0 is not None:
                    by = self._fusion_operand_bytes(ins, types, fcomp0, eff)
                else:
                    by = self._instr_bytes(ins, types, eff)
                # In-place dynamic-update-slice fusions (cache writes) only
                # touch the updated window, not the whole aliased buffer —
                # on TPU XLA shares the buffer (FusionCanShareBufferHint).
                # Scale bytes and inner elementwise flops to the window.
                dus = None
                for fi in (fcomp0.instrs if fcomp0 is not None else ()):
                    if fi.op == "dynamic-update-slice" and dus is None:
                        dus = (fcomp0, fi)
                    elif fi.op == "scatter" and len(fi.operands) > 2:
                        # scatter(operand, indices, updates): in-place on
                        # TPU; only the updates window moves. A scatter
                        # takes precedence over a carry-plumbing DUS in the
                        # same fusion (scan writing the slice back).
                        dus = (fcomp0, Instr(fi.name, fi.type_text,
                                             "dynamic-update-slice",
                                             [fi.operands[0], fi.operands[2]],
                                             fi.line))
                        break
                if dus is not None:
                    fcomp, fi = dus
                    ftypes = dict(fcomp.params)
                    for x in fcomp.instrs:
                        ftypes[x.name] = x.type_text
                    upd_b = (_shape_bytes(ftypes.get(fi.operands[1], ""))
                             if len(fi.operands) > 1 else 0.0)
                    res_b = _shape_bytes(ins.type_text)
                    frac = min(upd_b / res_b, 1.0) if res_b else 1.0
                    inner = inner.scaled(frac)
                    by = 2.0 * upd_b if not inside_fusion else 0.0
                    # the written buffer keeps its carried effective dtype
                    if ins.operands and ins.operands[0] in eff:
                        eff[ins.name] = eff[ins.operands[0]]
                if self.trace is not None and by > 0:
                    self.trace.append((by, name, ins.op, ins.name,
                                       ins.type_text[:48]))
                total += Cost(inner.flops, inner.mxu_flops, by,
                              inner.transcendentals)
            elif op == "while":
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                trip = 1.0
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trip = float(mt.group(1))
                # loop carries inherit the operand tuple's effective dtypes
                carry_eff = (eff.get(ins.operands[0])
                             if ins.operands else None)
                inner = Cost()
                if body:
                    bp = self.comps.get(body.group(1))
                    peff = ({list(bp.params)[0]: carry_eff}
                            if bp is not None and bp.params
                            and carry_eff is not None else None)
                    inner += self._cost_with_collectives(body.group(1), trip,
                                                         peff)
                if cond:
                    inner += self.comp_cost(cond.group(1))
                total += inner.scaled(trip)
                if carry_eff is not None:
                    eff[ins.name] = carry_eff
            elif op in ("call", "conditional"):
                for cname in _CALLS_RE.findall(ins.line):
                    total += self.comp_cost(cname, inside_fusion)
            elif op in _COLLECTIVES:
                if "-done" in op:
                    continue
                kind = op.replace("-start", "")
                self.collectives.append(Collective(
                    kind, _shape_bytes(ins.type_text), _group_size(ins.line),
                    1.0, name))
                total += Cost(0.0, 0.0, 0.0 if inside_fusion
                              else self._instr_bytes(ins, types, eff))
            else:
                fl = self._instr_flops(ins, comp, types)
                mxu = fl if op in ("dot", "convolution") else 0.0
                by = 0.0 if inside_fusion else self._instr_bytes(ins, types, eff)
                tr = (_shape_elems(ins.type_text)
                      if op in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                                "power", "sine", "cosine", "logistic")
                      else 0.0)
                if self.trace is not None and by > 0:
                    self.trace.append((by, name, ins.op, ins.name,
                                       ins.type_text[:48]))
                total += Cost(fl, mxu, by, tr)
        self._memo[key] = total
        return total

    def _cost_with_collectives(self, name: str, multiplier: float,
                               param_eff: Optional[Dict] = None) -> Cost:
        """comp_cost, but collectives found inside get the loop multiplier."""
        before = len(self.collectives)
        cost = self.comp_cost(name, param_eff=param_eff)
        # comp_cost memoizes; on a memo hit the collectives were already
        # recorded the first time. Scale multipliers only for fresh entries;
        # for memo hits, replay the recorded collectives of that comp.
        fresh = self.collectives[before:]
        if fresh:
            for c in fresh:
                c.multiplier *= multiplier
            self._replay_cache = getattr(self, "_replay_cache", {})
            self._replay_cache[name] = [dataclasses.replace(c, multiplier=1.0)
                                        for c in fresh]
        else:
            cache = getattr(self, "_replay_cache", {}).get(name, [])
            for c in cache:
                self.collectives.append(
                    dataclasses.replace(c, multiplier=multiplier))
        return cost

    # -- public API ----------------------------------------------------------
    def analyze(self) -> Dict[str, float]:
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0}
        self.collectives.clear()
        cost = self.comp_cost(self.entry)
        coll_operand = {k: 0.0 for k in ("all-gather", "all-reduce",
                                         "reduce-scatter", "all-to-all",
                                         "collective-permute")}
        wire = 0.0
        for c in self.collectives:
            R, n, mult = c.result_bytes, c.group_size, c.multiplier
            if c.kind == "all-gather":
                coll_operand[c.kind] += mult * R / n
                wire += mult * R * (n - 1) / n
            elif c.kind == "all-reduce":
                coll_operand[c.kind] += mult * R
                wire += mult * 2.0 * R * (n - 1) / n
            elif c.kind == "reduce-scatter":
                coll_operand[c.kind] += mult * R * n
                wire += mult * R * (n - 1)
            elif c.kind == "all-to-all":
                coll_operand[c.kind] += mult * R
                wire += mult * R * (n - 1) / n
            else:
                coll_operand[c.kind] += mult * R
                wire += mult * R
        return {
            "flops": cost.flops,
            "mxu_flops": cost.mxu_flops,
            "vpu_flops": cost.flops - cost.mxu_flops,
            "bytes": cost.bytes,
            "transcendentals": cost.transcendentals,
            "collective_operand_bytes": coll_operand,
            "collective_operand_total": sum(coll_operand.values()),
            "collective_wire_bytes": wire,
            "num_collectives": len(self.collectives),
        }


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    return HloCostModel(hlo_text).analyze()
