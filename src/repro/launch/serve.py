"""Continuum serving driver (``python -m repro.launch.serve``).

Boots the continuum through the ``repro.platform.Continuum`` facade —
either the classic weak-edge/strong-cloud pair, or (with
``--device-slots``) a 3-tier device/edge/cloud chain — deploys one or
more (smoke-size) model endpoints via the replication controller, pushes
a ramped open-loop request stream through the ingress gateway, and
reports how the traffic policy reacted per tier — a live, CPU-runnable
version of the paper's testbed experiment, served by the
continuous-batching scheduler (``--scheduler wave`` keeps the legacy
run-to-completion drain; ``--max-steps-per-tick`` lets long requests
stay slot-resident across ticks).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --rounds 30 --rps-low 2 --rps-high 12 --policy auto
    PYTHONPATH=src python -m repro.launch.serve --device-slots 1 \
        --rounds 20 --policy auto
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.core import offload
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.models import model_zoo
from repro.platform import (Continuum, LinkSpec, Request, TierConfig,
                            TierSpec, Topology)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=list(configs.ARCHS))
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--rps-low", type=float, default=1.0)
    ap.add_argument("--rps-high", type=float, default=8.0)
    ap.add_argument("--edge-slots", type=int, default=2)
    ap.add_argument("--cloud-slots", type=int, default=16)
    ap.add_argument("--device-slots", type=int, default=0,
                    help="> 0 adds an on-device ingress tier in front of "
                         "the edge (3-tier device/edge/cloud chain)")
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--policy", default="auto",
                    help="traffic policy: 0..100 | auto | auto+net | "
                         "auto+hedge | auto+migrate (modifiers compose, "
                         "e.g. auto+net+migrate)")
    ap.add_argument("--net-aware", action="store_true",
                    help="shorthand for --policy auto+net")
    ap.add_argument("--scheduler", default="continuous",
                    choices=("continuous", "wave"),
                    help="continuous-batching decode loop (default) or the "
                         "legacy run-to-completion wave drain")
    ap.add_argument("--max-steps-per-tick", type=int, default=0,
                    help="> 0 caps decode steps per tick so long requests "
                         "stay slot-resident across ticks (continuous "
                         "scheduler only)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = model_zoo.init(jax.random.PRNGKey(args.seed), cfg)

    policy = "auto+net" if args.net_aware else args.policy
    sched_kw = dict(scheduler=args.scheduler,
                    max_steps_per_tick=(args.max_steps_per_tick
                                        if args.max_steps_per_tick > 0
                                        else None))
    if args.device_slots > 0:
        topo = Topology(
            tiers=(TierSpec("device", slots=args.device_slots, max_len=64),
                   TierSpec("edge", slots=args.edge_slots, max_len=64,
                            extra_latency_s=0.005),
                   TierSpec("cloud", slots=args.cloud_slots, max_len=64,
                            extra_latency_s=0.02)),
            links=(LinkSpec(rtt_s=0.005, bandwidth_Bps=50e6),
                   LinkSpec(rtt_s=0.04, bandwidth_Bps=100e6)))
        cc = Continuum.from_topology(
            topo, policy=policy, offload_cfg=offload.OffloadConfig(),
            seed=args.seed, **sched_kw)
    else:
        cc = Continuum(
            edge=TierConfig(slots=args.edge_slots, max_len=64),
            cloud=TierConfig(slots=args.cloud_slots, max_len=64,
                             extra_latency_s=0.02),
            policy=policy, offload_cfg=offload.OffloadConfig(),
            seed=args.seed, **sched_kw)
    spec = FunctionSpec(name=args.arch, arch=args.arch, revision=1,
                        autoscaling=AutoscalingPolicy())
    cc.deploy(spec, cfg, params)

    rng = np.random.default_rng(args.seed)
    rid = 0
    names = [t.name for t in cc.tiers]
    for rnd in range(args.rounds):
        frac = min(rnd / max(args.rounds * 0.5, 1), 1.0)
        rps = args.rps_low + (args.rps_high - args.rps_low) * frac
        n = rng.poisson(rps)
        for _ in range(n):
            toks = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            cc.submit(args.arch, Request(rid=rid, tokens=toks,
                                         max_new=args.max_new))
            rid += 1
        rec = cc.tick()
        per_tier = " ".join(f"{nm}={rec['tiers'][nm]:3d}" for nm in names)
        backlog = sum(rec["backlog"].values())
        mig = (f" migrated={rec['migrated']:2d}"
               if rec["migrations_fired"] or rec["migrated"] else "")
        print(f"round={rnd:3d} rps={rps:5.1f} queued={n:3d} {per_tier} "
              f"steps={rec['steps']:3d} inflight={rec['inflight']:2d} "
              f"backlog={backlog:3d} R_t={rec['R']:5.1f}%{mig}")
    drained = cc.drain()           # finish slot-resident stragglers

    totals = {nm: sum(r["tiers"][nm] for r in cc.log) for nm in names}
    total = sum(totals.values())
    per_tier = " ".join(f"{nm}={n}" for nm, n in totals.items())
    off = total - totals[names[0]]
    if args.scheduler == "wave":
        waves = sum(r["waves"] for r in cc.log)
        rate = f"reqs_per_wave={total / max(waves, 1):.1f}"
    else:
        steps = sum(r["steps"] for r in cc.log)
        rate = (f"tokens_per_decode_step="
                f"{total * args.max_new / max(steps, 1):.1f}")
    print(f"\nserved {per_tier} "
          f"offload_frac={off / max(total, 1):.2f} {rate} "
          f"drain_ticks={drained} "
          f"spilled={sum(r['spilled'] for r in cc.log)} "
          f"rejected={sum(r['rejected'] for r in cc.log)} "
          f"migrated={int(cc.metrics.counter('migrations_completed'))} "
          f"hedges_open={cc.hedges_open}")


if __name__ == "__main__":
    main()
