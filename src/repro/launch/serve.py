"""Two-tier serving driver (``python -m repro.launch.serve``).

Boots the Edge-Cloud continuum through the ``repro.platform.Continuum``
facade with a weak edge tier and a strong cloud tier, deploys one or more
(smoke-size) model endpoints via the replication controller, pushes a
ramped open-loop request stream through the edge gateway, and reports how
the traffic policy reacted — a live, CPU-runnable version of the paper's
testbed experiment, served by the batched wave scheduler.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --rounds 30 --rps-low 2 --rps-high 12 --policy auto
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.core import offload
from repro.core.replication import AutoscalingPolicy, FunctionSpec
from repro.models import model_zoo
from repro.platform import Continuum, Request, TierConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    choices=list(configs.ARCHS))
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--rps-low", type=float, default=1.0)
    ap.add_argument("--rps-high", type=float, default=8.0)
    ap.add_argument("--edge-slots", type=int, default=2)
    ap.add_argument("--cloud-slots", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--policy", default="auto",
                    help="traffic policy: 0..100 | auto | auto+net | "
                         "auto+hedge")
    ap.add_argument("--net-aware", action="store_true",
                    help="shorthand for --policy auto+net")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    params = model_zoo.init(jax.random.PRNGKey(args.seed), cfg)

    policy = "auto+net" if args.net_aware else args.policy
    cc = Continuum(
        edge=TierConfig(slots=args.edge_slots, max_len=64),
        cloud=TierConfig(slots=args.cloud_slots, max_len=64,
                         extra_latency_s=0.02),
        policy=policy, offload_cfg=offload.OffloadConfig(),
        seed=args.seed)
    spec = FunctionSpec(name=args.arch, arch=args.arch, revision=1,
                        autoscaling=AutoscalingPolicy())
    cc.deploy(spec, cfg, params)

    rng = np.random.default_rng(args.seed)
    rid = 0
    for rnd in range(args.rounds):
        frac = min(rnd / max(args.rounds * 0.5, 1), 1.0)
        rps = args.rps_low + (args.rps_high - args.rps_low) * frac
        n = rng.poisson(rps)
        for _ in range(n):
            toks = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            cc.submit(args.arch, Request(rid=rid, tokens=toks,
                                         max_new=args.max_new))
            rid += 1
        rec = cc.tick()
        print(f"round={rnd:3d} rps={rps:5.1f} queued={n:3d} "
              f"edge={rec['edge']:3d} cloud={rec['cloud']:3d} "
              f"waves={rec['waves']:2d} R_t={rec['R']:5.1f}%")

    total_edge = sum(r["edge"] for r in cc.log)
    total_cloud = sum(r["cloud"] for r in cc.log)
    waves = sum(r["waves"] for r in cc.log)
    print(f"\nserved edge={total_edge} cloud={total_cloud} "
          f"offload_frac={total_cloud / max(total_edge + total_cloud, 1):.2f} "
          f"reqs_per_wave={(total_edge + total_cloud) / max(waves, 1):.1f}")


if __name__ == "__main__":
    main()
