"""Fault-tolerant training driver (``python -m repro.launch.train``).

The production entry point: builds the mesh (real devices; the dry-run's
512 placeholder devices are NOT forced here), installs the train-mode
sharding rules, and runs the checkpoint/restart loop. On this container it
runs the smoke configs on the 1-device mesh; on a pod the same code path
sees the real topology.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance contract (exercised by tests/test_fault_tolerance.py):
  * atomic checkpoints every --ckpt-every steps (tmp dir + rename);
  * on start, auto-resume from the newest complete checkpoint —
    crash/preempt at any point loses at most ckpt-every steps;
  * the data stream is seekable: resumed runs consume the identical
    token sequence (bit-exact loss continuity);
  * elastic restore: the checkpoint is mesh-agnostic, so a job restarted
    on a different device count reshards transparently.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import configs
from repro import sharding as shlib
from repro.launch import sharding as rules_lib
from repro.launch.mesh import make_local_mesh
from repro.training import data
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import LoopConfig, TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.use_pallas:
        cfg = dataclasses.replace(cfg, use_pallas=True)

    from repro.training import compression
    tcfg = TrainConfig(
        opt=OptimizerConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                            total_steps=args.steps),
        accum_steps=args.accum,
        compression=compression.CompressionConfig(enabled=args.compress_grads))
    lcfg = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every)
    dcfg = data.DataConfig(seed=args.seed, batch=args.batch, seq_len=args.seq)

    trainer = Trainer(cfg, tcfg, lcfg,
                      lambda start: data.stream(cfg, dcfg, start),
                      seed=args.seed)
    if trainer.start_step:
        print(f"resumed from step {trainer.start_step}")
    out = trainer.run()
    hist = out["history"]
    print(f"steps={len(hist)} first_loss={hist[0]['loss']:.4f} "
          f"last_loss={hist[-1]['loss']:.4f} "
          f"straggler_ratio={out['straggler_ratio']:.2f}")


if __name__ == "__main__":
    main()
