"""Edge-to-Cloud offloading controller — Eqs (1)-(4) of the paper.

This is the paper's primary algorithmic contribution, implemented as a pure,
vectorized JAX state machine so it can run under ``jit``/``vmap``/``lax.scan``
and, in the beyond-paper configuration, *inside* the jitted serving step.

Paper semantics (Simion et al., 2024, §3.3.2):

    Eq (1)  r_l(t)  = p95(X_l(t)) / p50(X_l(t))
    Eq (2)  r_l'(t) = sum_k c_decay^k * r_l(t-k) / sum_k c_decay^k,  k in [0, c_t]
    Eq (3)  r_t(t)  = 0                                if r_l' < c_soft
                      100                              if r_l' > c_hard
                      100*(r_l'-c_soft)/(c_hard-c_soft) otherwise
    Eq (4)  R_t(t)  = R_t(t-1)*c_in + r_t(t)*(1-c_in),  R_t(0) = 0

All state is carried per *function* (the serverless unit); arrays have a
leading ``F`` (num_functions) axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import quantile


def padded_rows(n: int) -> int:
    """Rows every batched controller call pads to: the next power of two.

    Power-of-two padding bounds jit recompiles to O(log F) as fleets grow
    (each live ``deploy`` adds a function).  A numerics caveat rides on
    the compiled shape: XLA:CPU scalarizes the single-row (1, W)
    compilation and contracts Eq (4)'s multiply-add into an FMA there,
    which multi-row compilations don't do — so an F=1 fleet's trajectory
    (pinned by the seed goldens) can differ by 1 ulp from the same
    function as row 0 of a stacked batch.  All multi-row shapes are
    mutually bit-identical, and F=1 single-boundary loops compile at
    (1, W) on both the per-boundary and the batched path, so
    vectorized-vs-legacy bit-identity holds at every F (the
    F in {1, 3, 257} golden test).
    """
    return 1 << (max(int(n), 1) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    """Controller constants (names follow the paper).

    Defaults were chosen to reproduce the qualitative behaviour of the
    paper's ``auto`` policy on the simulator: offload engages under ramped
    overload and disengages when the edge drains.
    """

    c_decay: float = 0.8      # exponential decay of past ratios, Eq (2)
    c_t: int = 10             # history window length (steps), Eq (2)
    c_soft: float = 1.25      # soft limit of the p95/p50 ratio, Eq (3)
    c_hard: float = 2.5       # hard limit of the p95/p50 ratio, Eq (3)
    c_in: float = 0.6         # inertia factor, Eq (4)
    # --- beyond-paper extension (§4.2 of the paper lists this as missing):
    # when True, the controller caps the offloaded fraction by the fraction
    # the edge->cloud link can actually absorb, avoiding the paper's
    # "offloading makes it worse when the network is the bottleneck" regime.
    net_aware: bool = False
    link_bytes_per_s: float = 100e6   # paper's observed 100 MB/s ceiling
    req_bytes: float = 1e6            # avg request+response payload
    # requests/s the controller assumes as current demand when net_aware
    # (supplied per update call; this is only the fallback).
    demand_rps: float = 100.0

    def decay_weights(self) -> jnp.ndarray:
        """w_k = c_decay^k / sum_j c_decay^j for k = 0..c_t (newest first)."""
        k = jnp.arange(self.c_t + 1, dtype=jnp.float32)
        w = jnp.power(jnp.float32(self.c_decay), k)
        return w / jnp.sum(w)


@jax.tree_util.register_pytree_node_class
class OffloadState:
    """Per-function controller state (a pytree of arrays).

    Attributes:
      ratios:  (F, c_t+1) ring buffer of past r_l values, element ``head``
               is the most recent.
      head:    () int32 ring-buffer write position — or (F,) int32 when the
               state was built with :meth:`init_rows` (batched controllers
               carry one head per row so boundaries that skip an interval
               stay frozen independently).
      filled:  (F,) int32 number of valid entries (for warm-up masking).
      R:       (F,) float32 smoothed traffic percentage, Eq (4).
    """

    def __init__(self, ratios, head, filled, R):
        self.ratios = ratios
        self.head = head
        self.filled = filled
        self.R = R

    @staticmethod
    def init(num_functions: int, cfg: OffloadConfig) -> "OffloadState":
        return OffloadState(
            ratios=jnp.ones((num_functions, cfg.c_t + 1), jnp.float32),
            head=jnp.zeros((), jnp.int32),
            filled=jnp.zeros((num_functions,), jnp.int32),
            R=jnp.zeros((num_functions,), jnp.float32),  # R_t(0) = 0
        )

    @staticmethod
    def init_rows(num_rows: int, cfg: OffloadConfig) -> "OffloadState":
        """Per-row-head variant for the batched rows kernels."""
        return OffloadState(
            ratios=jnp.ones((num_rows, cfg.c_t + 1), jnp.float32),
            head=jnp.zeros((num_rows,), jnp.int32),
            filled=jnp.zeros((num_rows,), jnp.int32),
            R=jnp.zeros((num_rows,), jnp.float32),
        )

    # --- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.ratios, self.head, self.filled, self.R), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def tail_ratio(p95: jnp.ndarray, p50: jnp.ndarray) -> jnp.ndarray:
    """Eq (1) core: ``p95/p50`` floored at 1.0.

    A tail cannot be faster than the median; the floor also guards the
    ``p50 == 0`` and all-NaN corners.  Both Eq-(1) front ends — the raw
    latency window and the histogram sketch — MUST share this expression
    or their controller trajectories diverge at the corners.
    """
    ratio = p95 / jnp.maximum(p50, 1e-9)
    ratio = jnp.where(jnp.isfinite(ratio), ratio, 1.0)
    return jnp.maximum(ratio, 1.0)


def latency_ratio(latencies: jnp.ndarray, valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq (1): tail-to-median ratio per function.

    Args:
      latencies: (F, W) window of recent request latencies (seconds).
      valid: optional (F, W) bool mask of real observations.

    Returns:
      (F,) float32 ``p95/p50`` with a floor of 1.0 (a tail cannot be faster
      than the median; guards the p50==0 corner).
    """
    lat = jnp.asarray(latencies, jnp.float32)
    if valid is not None:
        # Masked percentile: replace invalid with NaN and use nanpercentile.
        lat = jnp.where(valid, lat, jnp.nan)
        p95 = jnp.nanpercentile(lat, 95.0, axis=-1)
        p50 = jnp.nanpercentile(lat, 50.0, axis=-1)
    else:
        p95 = jnp.percentile(lat, 95.0, axis=-1)
        p50 = jnp.percentile(lat, 50.0, axis=-1)
    return tail_ratio(p95, p50)


def latency_ratio_from_sketch(hist: quantile.Histogram) -> jnp.ndarray:
    """Eq (1) from the on-device histogram sketch (production path)."""
    p95, p50 = quantile.quantile_fast(hist, (0.95, 0.50))
    return tail_ratio(p95, p50)


def _decayed_ratio(state: OffloadState, cfg: OffloadConfig) -> jnp.ndarray:
    """Eq (2): exponentially decayed weighted sum over the ring buffer.

    Handles both state layouts: the classic shared scalar ``head`` and the
    per-row ``head`` of batched states (:meth:`OffloadState.init_rows`).
    """
    n = cfg.c_t + 1
    # Order the ring newest-first: index (head - k) mod n.
    k = jnp.arange(n, dtype=jnp.int32)
    if jnp.ndim(state.head):
        idx = jnp.mod(state.head[:, None] - k[None, :], n)
        ordered = jnp.take_along_axis(state.ratios, idx, axis=1)
    else:
        idx = jnp.mod(state.head - k, n)
        ordered = state.ratios[:, idx]                  # (F, c_t+1) newest first
    w = cfg.decay_weights()                             # (c_t+1,)
    # Warm-up: only the first ``filled`` entries are real; renormalize.
    mask = (k[None, :] < jnp.maximum(state.filled[:, None], 1)).astype(jnp.float32)
    wm = w[None, :] * mask
    return jnp.sum(ordered * wm, axis=-1) / jnp.maximum(jnp.sum(wm, axis=-1), 1e-9)


def target_percentage(r_prime: jnp.ndarray, cfg: OffloadConfig) -> jnp.ndarray:
    """Eq (3): piecewise-linear map from decayed ratio to traffic percent."""
    span = max(cfg.c_hard - cfg.c_soft, 1e-9)
    lin = 100.0 * (r_prime - cfg.c_soft) / span
    return jnp.clip(lin, 0.0, 100.0)


def offload_update(
    state: OffloadState,
    latencies: jnp.ndarray,
    cfg: OffloadConfig,
    valid: jnp.ndarray | None = None,
    demand_rps: jnp.ndarray | None = None,
) -> Tuple[OffloadState, jnp.ndarray]:
    """One controller step: Eqs (1), (2), (3), (4) in order.

    Args:
      state: controller state.
      latencies: (F, W) latest latency window per function.
      cfg: controller constants.
      valid: optional (F, W) observation mask.
      demand_rps: optional (F,) current request rate, used by the
        net-aware extension.

    Returns:
      (new_state, R): R is the (F,) percentage of traffic to send cloud-ward.
    """
    r_l = latency_ratio(latencies, valid)               # Eq (1)
    state = push_ratio(state, r_l)
    return _finish_update(state, cfg, demand_rps)


def offload_update_from_sketch(
    state: OffloadState,
    hist: quantile.Histogram,
    cfg: OffloadConfig,
    demand_rps: jnp.ndarray | None = None,
) -> Tuple[OffloadState, jnp.ndarray]:
    """Controller step reading Eq (1) from the histogram sketch."""
    r_l = latency_ratio_from_sketch(hist)
    state = push_ratio(state, r_l)
    return _finish_update(state, cfg, demand_rps)


def push_ratio(state: OffloadState, r_l: jnp.ndarray) -> OffloadState:
    """Advance the ring buffer with a fresh Eq-(1) observation (both the
    scalar-head and the per-row-head state layouts)."""
    n = state.ratios.shape[-1]
    head = jnp.mod(state.head + 1, n)
    if jnp.ndim(head):
        # One write per row at column head[r]: a where-mask, not a
        # scatter — XLA:CPU serializes scatters (~10x slower at F=4096).
        col = jnp.arange(n, dtype=head.dtype)[None, :]
        ratios = jnp.where(col == head[:, None], r_l[:, None], state.ratios)
    else:
        ratios = state.ratios.at[:, head].set(r_l)
    filled = jnp.minimum(state.filled + 1, n)
    return OffloadState(ratios, head, filled, state.R)


def _finish_update(state, cfg, demand_rps):
    r_prime = _decayed_ratio(state, cfg)                # Eq (2)
    r_t = target_percentage(r_prime, cfg)               # Eq (3)
    R = state.R * cfg.c_in + r_t * (1.0 - cfg.c_in)     # Eq (4)
    if cfg.net_aware:
        rps = demand_rps if demand_rps is not None else jnp.full_like(R, cfg.demand_rps)
        # Max fraction of demand the link can carry without saturating.
        cap = 100.0 * cfg.link_bytes_per_s / jnp.maximum(rps * cfg.req_bytes, 1e-9)
        R = jnp.minimum(R, jnp.clip(cap, 0.0, 100.0))
    new_state = OffloadState(state.ratios, state.head, state.filled, R)
    return new_state, R


def _finish_rows(
    state: OffloadState,
    r_l: jnp.ndarray,
    active: jnp.ndarray,
    link_x100: jnp.ndarray,
    req_bytes: jnp.ndarray,
    net_mask: jnp.ndarray,
    demand_rps: jnp.ndarray,
    cfg: OffloadConfig,
) -> Tuple[OffloadState, jnp.ndarray]:
    """Eqs (2)-(4) over a stack of boundary rows with per-row net caps.

    ``active`` freezes rows whose boundary scraped no observations this
    interval (the batched analogue of the per-boundary ``val.any()`` skip);
    frozen rows keep their ring buffer, head, and R_t untouched.  The
    net-aware cap is per-row data (``link_x100 = 100 * link_bytes_per_s``
    pre-rounded to float32 on the host, ``net_mask`` selecting the rows
    whose policy is net-aware), so boundaries with different links batch
    into one compilation.
    """
    new = push_ratio(state, r_l)
    r_prime = _decayed_ratio(new, cfg)                  # Eq (2)
    r_t = target_percentage(r_prime, cfg)               # Eq (3)
    R = state.R * cfg.c_in + r_t * (1.0 - cfg.c_in)     # Eq (4)
    cap = link_x100 / jnp.maximum(demand_rps * req_bytes, 1e-9)
    R = jnp.where(net_mask, jnp.minimum(R, jnp.clip(cap, 0.0, 100.0)), R)
    ratios = jnp.where(active[:, None], new.ratios, state.ratios)
    head = jnp.where(active, new.head, state.head)
    filled = jnp.where(active, new.filled, state.filled)
    R = jnp.where(active, R, state.R)
    return OffloadState(ratios, head, filled, R), R


def offload_update_rows(
    state: OffloadState,
    latencies: jnp.ndarray,
    valid: jnp.ndarray,
    active: jnp.ndarray,
    link_x100: jnp.ndarray,
    req_bytes: jnp.ndarray,
    net_mask: jnp.ndarray,
    demand_rps: jnp.ndarray,
    cfg: OffloadConfig,
) -> Tuple[OffloadState, jnp.ndarray]:
    """One controller step over stacked boundary rows (exact Eq-(1) path).

    The fleet-scale form of :func:`offload_update`: every (boundary,
    function) pair is one row of a single (P, W) tensor — P padded to
    :func:`padded_rows` — and the whole control plane advances in one
    jitted call.  Row-local math makes this bit-identical to running each
    boundary separately.

    Args:
      state: per-row-head state (:meth:`OffloadState.init_rows`, P rows).
      latencies, valid: (P, W) stacked windows (padding rows all-invalid).
      active: (P,) bool — rows allowed to advance this interval.
      link_x100, req_bytes, net_mask, demand_rps: (P,) per-row net-cap
        inputs (see :func:`_finish_rows`).
      cfg: structural controller constants (static under jit).
    """
    r_l = latency_ratio(latencies, valid)               # Eq (1)
    return _finish_rows(state, r_l, active, link_x100, req_bytes,
                        net_mask, demand_rps, cfg)


def offload_update_rows_stream(
    state: OffloadState,
    hist: quantile.Histogram,
    sample_rows: jnp.ndarray,
    sample_vals: jnp.ndarray,
    sample_valid: jnp.ndarray,
    sketch_decay: jnp.ndarray,
    active: jnp.ndarray,
    link_x100: jnp.ndarray,
    req_bytes: jnp.ndarray,
    net_mask: jnp.ndarray,
    demand_rps: jnp.ndarray,
    cfg: OffloadConfig,
) -> Tuple[OffloadState, quantile.Histogram, jnp.ndarray]:
    """Streaming controller step: sketch ingest + Eqs (1)-(4), one call.

    The O(F log W) sort inside the exact Eq-(1) percentile is the scaling
    wall at 10k functions; this path never builds or sorts a window.
    Fresh latency observations are scattered into the per-row decayed
    log-bucket histogram (:func:`repro.core.quantile.ingest`, O(S + P*B))
    and Eq (1) reads p95/p50 from the sketch with the documented
    one-bucket error bound.  R_t is therefore *approximate* relative to
    the exact path — opt in via ``ControlLoop(eq1="sketch")``.
    """
    hist = quantile.ingest(hist, sample_rows, sample_vals,
                           valid=sample_valid, decay=sketch_decay)
    r_l = latency_ratio_from_sketch(hist)               # Eq (1), sketched
    state, R = _finish_rows(state, r_l, active, link_x100, req_bytes,
                            net_mask, demand_rps, cfg)
    return state, hist, R


# Module-level jitted entry points: one compilation per (row-count, window,
# cfg) triple — callers pad rows with ``padded_rows`` so fleet growth costs
# O(log F) compiles, and per-link capacities arrive as data (no closure to
# rebuild when a fault resizes a link).
offload_update_rows_jit = functools.partial(
    jax.jit, static_argnames=("cfg",))(offload_update_rows)
offload_update_rows_stream_jit = functools.partial(
    jax.jit, static_argnames=("cfg",))(offload_update_rows_stream)


def scan_controller(
    cfg: OffloadConfig,
    latency_windows: jnp.ndarray,
    valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Run the controller over a (T, F, W) latency trace with ``lax.scan``.

    Returns the (T, F) trajectory of R_t — used by tests and benchmarks.
    """
    T, F, _ = latency_windows.shape
    state0 = OffloadState.init(F, cfg)

    def step(state, inp):
        if valid is None:
            lat = inp
            state, R = offload_update(state, lat, cfg)
        else:
            lat, v = inp
            state, R = offload_update(state, lat, cfg, valid=v)
        return state, R

    xs = latency_windows if valid is None else (latency_windows, valid)
    _, Rs = jax.lax.scan(step, state0, xs)
    return Rs
