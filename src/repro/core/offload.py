"""Edge-to-Cloud offloading controller — Eqs (1)-(4) of the paper.

This is the paper's primary algorithmic contribution, implemented as a pure,
vectorized JAX state machine so it can run under ``jit``/``vmap``/``lax.scan``
and, in the beyond-paper configuration, *inside* the jitted serving step.

Paper semantics (Simion et al., 2024, §3.3.2):

    Eq (1)  r_l(t)  = p95(X_l(t)) / p50(X_l(t))
    Eq (2)  r_l'(t) = sum_k c_decay^k * r_l(t-k) / sum_k c_decay^k,  k in [0, c_t]
    Eq (3)  r_t(t)  = 0                                if r_l' < c_soft
                      100                              if r_l' > c_hard
                      100*(r_l'-c_soft)/(c_hard-c_soft) otherwise
    Eq (4)  R_t(t)  = R_t(t-1)*c_in + r_t(t)*(1-c_in),  R_t(0) = 0

All state is carried per *function* (the serverless unit); arrays have a
leading ``F`` (num_functions) axis.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import quantile


@dataclasses.dataclass(frozen=True)
class OffloadConfig:
    """Controller constants (names follow the paper).

    Defaults were chosen to reproduce the qualitative behaviour of the
    paper's ``auto`` policy on the simulator: offload engages under ramped
    overload and disengages when the edge drains.
    """

    c_decay: float = 0.8      # exponential decay of past ratios, Eq (2)
    c_t: int = 10             # history window length (steps), Eq (2)
    c_soft: float = 1.25      # soft limit of the p95/p50 ratio, Eq (3)
    c_hard: float = 2.5       # hard limit of the p95/p50 ratio, Eq (3)
    c_in: float = 0.6         # inertia factor, Eq (4)
    # --- beyond-paper extension (§4.2 of the paper lists this as missing):
    # when True, the controller caps the offloaded fraction by the fraction
    # the edge->cloud link can actually absorb, avoiding the paper's
    # "offloading makes it worse when the network is the bottleneck" regime.
    net_aware: bool = False
    link_bytes_per_s: float = 100e6   # paper's observed 100 MB/s ceiling
    req_bytes: float = 1e6            # avg request+response payload
    # requests/s the controller assumes as current demand when net_aware
    # (supplied per update call; this is only the fallback).
    demand_rps: float = 100.0

    def decay_weights(self) -> jnp.ndarray:
        """w_k = c_decay^k / sum_j c_decay^j for k = 0..c_t (newest first)."""
        k = jnp.arange(self.c_t + 1, dtype=jnp.float32)
        w = jnp.power(jnp.float32(self.c_decay), k)
        return w / jnp.sum(w)


@jax.tree_util.register_pytree_node_class
class OffloadState:
    """Per-function controller state (a pytree of arrays).

    Attributes:
      ratios:  (F, c_t+1) ring buffer of past r_l values, element ``head``
               is the most recent.
      head:    () int32 ring-buffer write position.
      filled:  (F,) int32 number of valid entries (for warm-up masking).
      R:       (F,) float32 smoothed traffic percentage, Eq (4).
    """

    def __init__(self, ratios, head, filled, R):
        self.ratios = ratios
        self.head = head
        self.filled = filled
        self.R = R

    @staticmethod
    def init(num_functions: int, cfg: OffloadConfig) -> "OffloadState":
        return OffloadState(
            ratios=jnp.ones((num_functions, cfg.c_t + 1), jnp.float32),
            head=jnp.zeros((), jnp.int32),
            filled=jnp.zeros((num_functions,), jnp.int32),
            R=jnp.zeros((num_functions,), jnp.float32),  # R_t(0) = 0
        )

    # --- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.ratios, self.head, self.filled, self.R), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def tail_ratio(p95: jnp.ndarray, p50: jnp.ndarray) -> jnp.ndarray:
    """Eq (1) core: ``p95/p50`` floored at 1.0.

    A tail cannot be faster than the median; the floor also guards the
    ``p50 == 0`` and all-NaN corners.  Both Eq-(1) front ends — the raw
    latency window and the histogram sketch — MUST share this expression
    or their controller trajectories diverge at the corners.
    """
    ratio = p95 / jnp.maximum(p50, 1e-9)
    ratio = jnp.where(jnp.isfinite(ratio), ratio, 1.0)
    return jnp.maximum(ratio, 1.0)


def latency_ratio(latencies: jnp.ndarray, valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq (1): tail-to-median ratio per function.

    Args:
      latencies: (F, W) window of recent request latencies (seconds).
      valid: optional (F, W) bool mask of real observations.

    Returns:
      (F,) float32 ``p95/p50`` with a floor of 1.0 (a tail cannot be faster
      than the median; guards the p50==0 corner).
    """
    lat = jnp.asarray(latencies, jnp.float32)
    if valid is not None:
        # Masked percentile: replace invalid with NaN and use nanpercentile.
        lat = jnp.where(valid, lat, jnp.nan)
        p95 = jnp.nanpercentile(lat, 95.0, axis=-1)
        p50 = jnp.nanpercentile(lat, 50.0, axis=-1)
    else:
        p95 = jnp.percentile(lat, 95.0, axis=-1)
        p50 = jnp.percentile(lat, 50.0, axis=-1)
    return tail_ratio(p95, p50)


def latency_ratio_from_sketch(hist: quantile.Histogram) -> jnp.ndarray:
    """Eq (1) from the on-device histogram sketch (production path)."""
    p95 = quantile.quantile(hist, 0.95)
    p50 = quantile.quantile(hist, 0.50)
    return tail_ratio(p95, p50)


def _decayed_ratio(state: OffloadState, cfg: OffloadConfig) -> jnp.ndarray:
    """Eq (2): exponentially decayed weighted sum over the ring buffer."""
    n = cfg.c_t + 1
    # Order the ring newest-first: index (head - k) mod n.
    k = jnp.arange(n, dtype=jnp.int32)
    idx = jnp.mod(state.head - k, n)
    ordered = state.ratios[:, idx]                      # (F, c_t+1) newest first
    w = cfg.decay_weights()                             # (c_t+1,)
    # Warm-up: only the first ``filled`` entries are real; renormalize.
    mask = (k[None, :] < jnp.maximum(state.filled[:, None], 1)).astype(jnp.float32)
    wm = w[None, :] * mask
    return jnp.sum(ordered * wm, axis=-1) / jnp.maximum(jnp.sum(wm, axis=-1), 1e-9)


def target_percentage(r_prime: jnp.ndarray, cfg: OffloadConfig) -> jnp.ndarray:
    """Eq (3): piecewise-linear map from decayed ratio to traffic percent."""
    span = max(cfg.c_hard - cfg.c_soft, 1e-9)
    lin = 100.0 * (r_prime - cfg.c_soft) / span
    return jnp.clip(lin, 0.0, 100.0)


def offload_update(
    state: OffloadState,
    latencies: jnp.ndarray,
    cfg: OffloadConfig,
    valid: jnp.ndarray | None = None,
    demand_rps: jnp.ndarray | None = None,
) -> Tuple[OffloadState, jnp.ndarray]:
    """One controller step: Eqs (1), (2), (3), (4) in order.

    Args:
      state: controller state.
      latencies: (F, W) latest latency window per function.
      cfg: controller constants.
      valid: optional (F, W) observation mask.
      demand_rps: optional (F,) current request rate, used by the
        net-aware extension.

    Returns:
      (new_state, R): R is the (F,) percentage of traffic to send cloud-ward.
    """
    r_l = latency_ratio(latencies, valid)               # Eq (1)
    state = push_ratio(state, r_l)
    return _finish_update(state, cfg, demand_rps)


def offload_update_from_sketch(
    state: OffloadState,
    hist: quantile.Histogram,
    cfg: OffloadConfig,
    demand_rps: jnp.ndarray | None = None,
) -> Tuple[OffloadState, jnp.ndarray]:
    """Controller step reading Eq (1) from the histogram sketch."""
    r_l = latency_ratio_from_sketch(hist)
    state = push_ratio(state, r_l)
    return _finish_update(state, cfg, demand_rps)


def push_ratio(state: OffloadState, r_l: jnp.ndarray) -> OffloadState:
    """Advance the ring buffer with a fresh Eq-(1) observation."""
    n = state.ratios.shape[-1]
    head = jnp.mod(state.head + 1, n)
    ratios = state.ratios.at[:, head].set(r_l)
    filled = jnp.minimum(state.filled + 1, n)
    return OffloadState(ratios, head, filled, state.R)


def _finish_update(state, cfg, demand_rps):
    r_prime = _decayed_ratio(state, cfg)                # Eq (2)
    r_t = target_percentage(r_prime, cfg)               # Eq (3)
    R = state.R * cfg.c_in + r_t * (1.0 - cfg.c_in)     # Eq (4)
    if cfg.net_aware:
        rps = demand_rps if demand_rps is not None else jnp.full_like(R, cfg.demand_rps)
        # Max fraction of demand the link can carry without saturating.
        cap = 100.0 * cfg.link_bytes_per_s / jnp.maximum(rps * cfg.req_bytes, 1e-9)
        R = jnp.minimum(R, jnp.clip(cap, 0.0, 100.0))
    new_state = OffloadState(state.ratios, state.head, state.filled, R)
    return new_state, R


def scan_controller(
    cfg: OffloadConfig,
    latency_windows: jnp.ndarray,
    valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Run the controller over a (T, F, W) latency trace with ``lax.scan``.

    Returns the (T, F) trajectory of R_t — used by tests and benchmarks.
    """
    T, F, _ = latency_windows.shape
    state0 = OffloadState.init(F, cfg)

    def step(state, inp):
        if valid is None:
            lat = inp
            state, R = offload_update(state, lat, cfg)
        else:
            lat, v = inp
            state, R = offload_update(state, lat, cfg, valid=v)
        return state, R

    xs = latency_windows if valid is None else (latency_windows, valid)
    _, Rs = jax.lax.scan(step, state0, xs)
    return Rs
