"""Cloud-to-Edge replication with selective field merge (paper §3.3.1).

The paper's Knative Edge controller mirrors Knative Service definitions from
the cloud cluster into each edge cluster. The naive mirror triggers a
reconcile feedback loop (edge controller reacts to its own writes); the
paper's fix is a *selective* merge: copy only the cloud-owned subset of
fields, preserve the edge-local state and non-owned annotations, and write
only when the merged definition actually differs.

Here a "Knative Service" becomes a :class:`FunctionSpec` — a deployable model
endpoint (architecture config + revision + autoscaling bounds). The merge is
a pure function, which turns the paper's anti-feedback-loop argument into two
testable invariants:

  idempotence:      merge(merge(e, c), c) == merge(e, c)
  edge-ownership:   merge(e, c) preserves every edge-owned field of e

Weight bytes ride the checkpoint layer (``training/checkpoint.py``); this
module is the control-plane object model.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Mapping, Optional, Tuple

EDGE_ANNOTATION_PREFIX = "edge.repro.dev/"


@dataclasses.dataclass(frozen=True)
class AutoscalingPolicy:
    """Knative KPA-shaped bounds, per function."""
    min_scale: int = 0                 # 0 => scale-to-zero allowed
    max_scale: int = 4
    target_concurrency: float = 4.0    # requests in flight per instance
    panic_threshold: float = 2.0       # panic if short-window load > this x target
    scale_to_zero_grace_s: float = 30.0


@dataclasses.dataclass(frozen=True)
class FunctionSpec:
    """Cloud-owned definition of a serverless function (model endpoint)."""
    name: str
    arch: str                          # key into repro.configs registry
    revision: int = 1
    checkpoint_ref: str = ""           # content address of the weights
    autoscaling: AutoscalingPolicy = dataclasses.field(default_factory=AutoscalingPolicy)
    env: Mapping[str, str] = dataclasses.field(default_factory=dict)
    # annotations are split by ownership: cloud writes plain keys, the edge
    # runtime writes keys under EDGE_ANNOTATION_PREFIX.
    annotations: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def spec_hash(self) -> str:
        """Stable content hash of the cloud-owned fields only."""
        payload = {
            "name": self.name,
            "arch": self.arch,
            "revision": self.revision,
            "checkpoint_ref": self.checkpoint_ref,
            "autoscaling": dataclasses.asdict(self.autoscaling),
            "env": dict(sorted(self.env.items())),
            "annotations": {k: v for k, v in sorted(self.annotations.items())
                            if not k.startswith(EDGE_ANNOTATION_PREFIX)},
        }
        blob = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass(frozen=True)
class EdgeServiceState:
    """The edge cluster's view of a function: replicated spec + edge-owned state."""
    spec: FunctionSpec
    # --- edge-owned, never overwritten by replication -----------------
    ready_instances: int = 0
    traffic_pct_to_cloud: float = 0.0      # written by the offload controller
    last_seen_revision: int = 0
    edge_annotations: Mapping[str, str] = dataclasses.field(default_factory=dict)
    status: str = "Unknown"                # Ready | NotReady | Unknown

    def with_spec(self, spec: FunctionSpec) -> "EdgeServiceState":
        return dataclasses.replace(self, spec=spec,
                                   last_seen_revision=spec.revision)


def merge(edge: EdgeServiceState, cloud: FunctionSpec) -> Tuple[EdgeServiceState, bool]:
    """Selective-field merge (paper §3.3.1).

    Copies the current edge definition and overwrites only the cloud-owned
    subset of fields; edge-owned state and ``edge.repro.dev/`` annotations
    persist. Returns ``(new_state, changed)`` — ``changed`` is False when
    the merged spec hash equals the current one, in which case the caller
    must NOT redeploy (this break in the write cycle is what kills the
    feedback loop).
    """
    # Preserve edge-prefixed annotations from the *edge* copy, take the rest
    # from the cloud definition.
    edge_ann = {k: v for k, v in edge.spec.annotations.items()
                if k.startswith(EDGE_ANNOTATION_PREFIX)}
    cloud_ann = {k: v for k, v in cloud.annotations.items()
                 if not k.startswith(EDGE_ANNOTATION_PREFIX)}
    merged_spec = dataclasses.replace(
        cloud, annotations={**cloud_ann, **edge_ann})
    changed = merged_spec.spec_hash() != edge.spec.spec_hash()
    if not changed:
        return edge, False
    return edge.with_spec(merged_spec), True


class ReplicationController:
    """Watches a cloud registry of FunctionSpecs and reconciles edge state.

    A deliberately small, deterministic reconciler: one ``reconcile`` call
    folds the current cloud view into the edge view and reports which
    functions actually redeployed. ``writes`` counts edge deployments — the
    paper's feedback-loop bug would show up as ``writes`` growing without
    cloud-side changes; tests pin it to zero in steady state.
    """

    def __init__(self) -> None:
        self.edge: Dict[str, EdgeServiceState] = {}
        self.writes = 0
        self.reconciles = 0

    def reconcile(self, cloud_view: Mapping[str, FunctionSpec]) -> Dict[str, bool]:
        self.reconciles += 1
        out: Dict[str, bool] = {}
        # Create/update
        for name, spec in cloud_view.items():
            cur = self.edge.get(name)
            if cur is None:
                self.edge[name] = EdgeServiceState(spec=spec,
                                                   last_seen_revision=spec.revision)
                self.writes += 1
                out[name] = True
                continue
            merged, changed = merge(cur, spec)
            if changed:
                self.edge[name] = merged
                self.writes += 1
            out[name] = changed
        # Garbage-collect deleted functions.
        for name in list(self.edge):
            if name not in cloud_view:
                del self.edge[name]
                self.writes += 1
                out[name] = True
        return out

    def set_edge_state(self, name: str, **fields: Any) -> None:
        """Edge-runtime writes (offload pct, readiness) — never replicated."""
        self.edge[name] = dataclasses.replace(self.edge[name], **fields)

    def get(self, name: str) -> Optional[EdgeServiceState]:
        return self.edge.get(name)
