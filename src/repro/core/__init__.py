"""Core paper technique: the Policy/ControlLoop control plane, offloading
controller (Eqs 1-4), quantile sketch, router, cloud->edge replication,
autoscaler, and the evaluation simulator."""
