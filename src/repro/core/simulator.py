"""Discrete-event simulator of the continuum testbed (§4 of the paper).

Reproduces the paper's experimental apparatus — 4 Raspberry-Pi-class edge
instances, an elastic cloud tier, a shared 100 MB/s edge->cloud link, a
ramped open-loop request generator — so that Table 2 (successful responses
per traffic policy) and Figure 2 (latency / CPU / memory / network time
series) can be regenerated deterministically on this machine.

The apparatus is no longer hardwired to two tiers: pass any
:class:`~repro.core.topology.Topology` (an ordered chain of N tiers joined
by N-1 links) and the same event loop runs it — per-tier service pools and
bounded queues, per-link FIFO pipes, per-tier latency registries feeding
one controller *boundary* each, and (with ``waterfall=True``) tier-by-tier
overflow spill down the chain.  The default (no topology) is the paper's
edge/cloud pair built from :class:`SimConfig`, which is bit-identical to
the historical two-tier simulator: same RNG draw sequence, same event
order, same R_t trajectory.

Crucially the ``auto`` policy exercises the *real* controller from
``repro.core.offload`` (the same jitted code the live serving tier runs),
not a reimplementation: the simulator is the calibration harness for the
paper's Eqs (1)-(4).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.cache import pages_needed
from repro.core import offload
from repro.core.metrics import MetricsRegistry
from repro.core.policy import AutoOffload, ControlLoop, Policy, PolicySpec
from repro.core.topology import LinkSpec, TierSpec, Topology
from repro.core.workloads import PROFILES, WorkloadProfile
from repro.workloads.faults import FaultSchedule, LinkState
from repro.workloads.trace import ArrivalProcess, RampedPoisson, Trace


@dataclasses.dataclass(frozen=True)
class SimConfig:
    duration_s: float = 600.0
    low_rps: float = 2.0
    high_rps: float = 16.0
    ramp_start_s: float = 60.0
    ramp_end_s: float = 240.0
    edge_instances: int = 4            # the paper's 4x Raspberry Pi 3B+
    edge_slots_per_instance: int = 1
    cloud_slots: int = 64
    link_bandwidth_Bps: float = 100e6  # paper: "maximum of 100MB/s"
    link_rtt_s: float = 0.04
    timeout_s: float = 10.0
    control_interval_s: float = 1.0    # Prometheus scrape cadence
    metric_interval_s: float = 5.0
    window: int = 64                   # latency window fed to Eq (1)
    mem_baseline_mb: float = 180.0
    # Knative queue-proxy semantics: per-instance request queue is bounded;
    # overflow is rejected immediately (503). Fast rejections are *part of*
    # the latency distribution Prometheus scrapes — they are what keeps
    # Eq (1) bimodal (and hence alive) under deep overload.
    queue_depth_per_slot: int = 8
    reject_latency_s: float = 0.005
    seed: int = 0

    def default_topology(self) -> Topology:
        """The paper's two-tier apparatus as a Topology (waterfall off:
        edge overflow 503s, exactly the seed semantics)."""
        return Topology(
            tiers=(TierSpec("edge",
                            slots=self.edge_instances
                            * self.edge_slots_per_instance,
                            queue_depth_per_slot=self.queue_depth_per_slot),
                   TierSpec("cloud", slots=self.cloud_slots,
                            queue_depth_per_slot=None)),
            links=(LinkSpec(rtt_s=self.link_rtt_s,
                            bandwidth_Bps=self.link_bandwidth_Bps),),
            waterfall=False)


@dataclasses.dataclass
class SimResult:
    policy: str
    workload: str
    successes: int
    failures: int
    times: np.ndarray              # (T,) metric timestamps
    latency_avg: np.ndarray        # (T,) mean completed latency per interval
    cpu_util: np.ndarray           # (T,) ingress-tier busy fraction
    mem_mb: np.ndarray             # (T,) ingress-tier resident memory
    net_MBps: np.ndarray           # (T,) ingress link egress
    offload_pct: np.ndarray        # (T,) ingress boundary controller output
    # (L, T) egress per link, chain order; row 0 duplicates net_MBps (the
    # headline field kept for golden-trajectory compatibility).  Deep rows
    # are what show link saturation past the first boundary in N-tier runs.
    net_links_MBps: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0, 0)))
    # per-tier successful completions, in chain order
    tier_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # requests that overflowed a tier and were spilled down the chain
    spilled: int = 0
    # mid-stream migrations (policies with a migrate_threshold): fired =
    # in-service requests shipped down-chain; aborted = destination full
    # at landing, resumed at the source instead — never lost
    migrations_fired: int = 0
    migrations_completed: int = 0
    migrations_aborted: int = 0
    # fault injection: requests submitted overall (for the conservation
    # identity successes + failures == submitted), requests replayed off a
    # crashed tier, fault events applied
    submitted: int = 0
    replayed: int = 0
    faults_applied: int = 0

    def summary(self) -> Dict[str, float]:
        out = {
            "successes": self.successes,
            "failures": self.failures,
            "latency_avg": float(np.nanmean(self.latency_avg)),
            "cpu_peak": float(self.cpu_util.max(initial=0.0)),
            "net_peak_MBps": float(self.net_MBps.max(initial=0.0)),
        }
        for l in range(1, self.net_links_MBps.shape[0]):
            out[f"net_peak_MBps_link{l}"] = float(
                self.net_links_MBps[l].max(initial=0.0))
        for name, n in self.tier_counts.items():
            out[f"served_{name}"] = n
        if self.spilled:
            out["spilled"] = self.spilled
        if self.migrations_fired:
            out["migrations_fired"] = self.migrations_fired
            out["migrations_completed"] = self.migrations_completed
            out["migrations_aborted"] = self.migrations_aborted
        if self.faults_applied:
            out["faults_applied"] = self.faults_applied
            out["replayed"] = self.replayed
        return out


# Event kinds, ordered for deterministic tie-breaking (ties never reach the
# kind field — the monotone sequence number breaks them first).
_ARRIVAL, _DONE, _CONTROL, _METRIC, _MIGRATE, _FAULT = range(6)


def _service_sample(rng: np.random.Generator, mean: float, cv: float) -> float:
    """Lognormal service time with given mean and coefficient of variation."""
    sigma2 = np.log(1.0 + cv * cv)
    mu = np.log(mean) - 0.5 * sigma2
    return float(rng.lognormal(mu, np.sqrt(sigma2)))


def _tier_service_mean(prof: WorkloadProfile, topo: Topology, i: int) -> float:
    """Resolve tier i's mean service time from the workload profile.

    An explicit ``service_rate_mult`` scales relative to the profile's
    edge speed; ``None`` means positional defaults — ingress runs at edge
    speed, the deepest tier at cloud speed, intermediates interpolate
    geometrically.  A cost-modeled spec (``model`` set) must arrive
    *resolved*: its derived multiplier replaces the sentinel, so the
    positional-default branch below stays reserved for hand-set chains
    (``Topology.pair``'s elastic cloud keeps its seed meaning) and can
    never silently mask a missing cost resolution.
    """
    spec = topo.tiers[i]
    if spec.model is not None and spec.service_rate_mult is None:
        raise ValueError(
            f"tier {spec.name!r} declares a cost model ({spec.model}) but "
            f"is unresolved; build the chain via Topology.costed(...) or "
            f"call .resolve_costs() before simulating")
    if spec.service_rate_mult is not None:
        return prof.edge_service_s / spec.service_rate_mult
    if i == 0:
        return prof.edge_service_s
    last = len(topo.tiers) - 1
    if i == last:
        return prof.cloud_service_s
    frac = i / last
    return float(prof.edge_service_s
                 * (prof.cloud_service_s / prof.edge_service_s) ** frac)


class _SimTier:
    """Mutable per-tier state inside one run.

    A tier whose spec declares ``page_size`` carries the same page
    ledger the live paged endpoint keeps: every resident request holds
    the pages its (prompt_len, max_new) extent reserves — the one shared
    formula, :func:`repro.cache.pages_needed` — and admission requires
    both a slot and the pages.  Dense tiers keep ``page_need == 0``
    everywhere, so their math (and the event/RNG sequence) is untouched.
    """

    def __init__(self, spec: TierSpec, service_mean: float):
        self.spec = spec
        self.service_mean = service_mean
        self.busy = 0
        # (arrival_time, size) where size = (prompt_len, max_new) for
        # trace-driven arrivals, None otherwise
        self.queue: Deque[Tuple[float, Optional[Tuple[int, int]]]] = deque()
        self.served = 0
        self.pages_total = getattr(spec, "total_pages", 0) or 0
        self.pages_used = 0

    @property
    def queue_cap(self) -> Optional[int]:
        if self.spec.queue_depth_per_slot is None:
            return None
        return self.spec.slots * self.spec.queue_depth_per_slot

    def page_need(self, size: Optional[Tuple[int, int]]) -> int:
        """Pages a request of ``size`` reserves here (0 on dense tiers;
        a size-less request conservatively reserves a full row — with
        the default pool of ``slots`` full rows that makes the page gate
        coincide exactly with the slot gate)."""
        if getattr(self.spec, "page_size", None) is None:
            return 0
        if size is None:
            return self.spec.pages_per_row
        return pages_needed(size[0], max(size[1], 1),
                            self.spec.page_size, self.spec.max_len)

    def can_serve(self, size: Optional[Tuple[int, int]]) -> bool:
        """Slot AND page availability (dense tiers: 0 + 0 <= 0)."""
        return (self.busy < self.spec.slots
                and self.pages_used + self.page_need(size)
                <= self.pages_total)


class ContinuumSimulator:
    """One workload, one policy, one run."""

    def __init__(self, workload: str, policy: PolicySpec,
                 cfg: SimConfig = SimConfig(),
                 offload_cfg: Optional[offload.OffloadConfig] = None,
                 topology: Optional[Topology] = None,
                 trace: Optional[Union[ArrivalProcess, Trace]] = None,
                 faults: Optional[FaultSchedule] = None,
                 eq1: str = "window", sketch=None):
        if workload not in PROFILES:
            raise ValueError(f"unknown workload {workload!r}")
        self.profile: WorkloadProfile = PROFILES[workload]
        self.cfg = cfg
        self.policy = policy
        self.topology = topology or cfg.default_topology()
        # Arrivals come from repro.workloads in either form: an
        # inline-draw ArrivalProcess (the default is the historical ramp,
        # bit-identical draws) or a materialized Trace (per-request
        # times/payloads replayed verbatim; the simulator is a
        # single-function apparatus, so the trace's fn column only sets
        # per-request payload bytes here).
        self.trace: Optional[Trace] = None
        if trace is None:
            self.arrivals: Optional[ArrivalProcess] = RampedPoisson(
                cfg.low_rps, cfg.high_rps, cfg.ramp_start_s, cfg.ramp_end_s)
        elif isinstance(trace, Trace):
            self.arrivals = None
            self.trace = trace
        elif isinstance(trace, ArrivalProcess):
            self.arrivals = trace
        else:
            raise TypeError(f"trace must be an ArrivalProcess or Trace, "
                            f"got {type(trace).__name__}")
        self.faults = faults
        if faults is not None:
            faults.validate(self.topology.num_tiers)
        self.rng = np.random.default_rng(cfg.seed)
        # One latency registry per non-terminal tier: registry b feeds
        # controller boundary b.  (The deepest tier's latencies are not fed
        # to Eq (1): the paper's strategy "uses the request latency metrics
        # of all the functions running at the Edge".)
        cap = max(cfg.window * 4, 256)
        n_bounds = max(self.topology.num_tiers - 1, 1)
        self.tier_metrics = [MetricsRegistry([workload], capacity=cap)
                             for _ in range(n_bounds)]
        self.metrics = self.tier_metrics[0]
        # The same Policy/ControlLoop objects the live runtime drives —
        # the simulator is the calibration harness, not a reimplementation.
        # Each boundary parses the policy against ITS link's capacity, so
        # auto+net caps offload by the link actually being crossed.
        base_cfg = offload_cfg or offload.OffloadConfig()
        links = (self.topology.links
                 or (LinkSpec(rtt_s=cfg.link_rtt_s,
                              bandwidth_Bps=cfg.link_bandwidth_Bps),))
        boundary_policies = [
            Policy.parse(policy, offload_cfg=base_cfg,
                         link_bytes_per_s=links[min(b, len(links) - 1)]
                         .bandwidth_Bps,
                         req_bytes=self.profile.payload_bytes)
            for b in range(max(self.topology.num_tiers - 1, 1))]
        self.policy_obj = boundary_policies[0]
        self.offload_cfg = (self.policy_obj.cfg
                            if isinstance(self.policy_obj, AutoOffload)
                            else base_cfg)
        self.control = ControlLoop(self.policy_obj, 1, window=cfg.window,
                                   control_interval_s=cfg.control_interval_s,
                                   num_tiers=self.topology.num_tiers,
                                   boundary_policies=boundary_policies,
                                   eq1=eq1, sketch=sketch)

    # ------------------------------------------------------------------
    def _rate(self, t: float) -> float:
        """Inline-draw arrival rate (consolidated in repro.workloads:
        the default RampedPoisson computes the historical ramp with the
        identical float expressions, so draws are bit-identical)."""
        return self.arrivals.rate(t)

    def _choose_tier(self, u: float, R_cur: np.ndarray) -> int:
        """Pick a tier from one uniform draw and the per-boundary R_t.

        Single-draw waterfall: cross boundary b iff ``u*100 < R_t[b]``,
        then rescale u to the conditional uniform for the next boundary.
        For two tiers this is exactly the historical coin flip
        ``u * 100 < pct`` (bit-identical draw and comparison).
        """
        j, v = 0, u
        for b in range(len(R_cur)):
            pct = float(R_cur[b])
            if v * 100.0 < pct:
                j += 1
                v = v * 100.0 / pct
            else:
                break
        return j

    def run(self) -> SimResult:
        cfg, prof, topo = self.cfg, self.profile, self.topology
        N = topo.num_tiers
        last = N - 1
        events: List[Tuple[float, int, int, tuple]] = []
        seq = itertools.count()

        def push(t: float, kind: int, payload: tuple = ()):
            heapq.heappush(events, (t, next(seq), kind, payload))

        # --- state ----------------------------------------------------
        tiers = [_SimTier(spec, _tier_service_mean(prof, topo, i))
                 for i, spec in enumerate(topo.tiers)]
        # Fault overlay: links are crossed through their mutable LinkState
        # (identity multipliers while healthy — the float math is
        # unchanged), and crashed tiers forward traffic but cannot serve.
        link_state = [LinkState(l) for l in topo.links]
        tier_up = [True] * N
        submitted = replayed = faults_applied = 0
        link_free_at = [0.0] * len(topo.links)
        link_bytes = [0.0] * len(topo.links)
        # Per-boundary R_t for the tier chooser: exactly N-1 rows (empty
        # for a single-tier chain — everything stays at the ingress;
        # ControlLoop keeps one boundary row even then, which routing
        # must not see).
        R_cur = np.array(self.control.R_all[:N - 1, 0], np.float64)
        successes = failures = spilled = 0
        # In-service bookkeeping for mid-stream migration: every started
        # service gets a token; migrating a request deletes its token so
        # the already-queued _DONE event is recognized as stale when it
        # pops.  (Policies without a migrate_threshold never delete, so
        # their event trace — and RNG draw sequence — is unchanged.)
        svc_seq = itertools.count()
        # tok -> (j, arr, t_done, pages_held, size)
        svc_live: Dict[int, Tuple[int, float, float, int,
                                  Optional[Tuple[int, int]]]] = {}
        mig_fired = mig_completed = mig_aborted = mig_transit = 0
        # Demand per boundary this interval: boundary b sees the requests
        # that reached tier b (routing or spill) — what its net-aware cap
        # divides the link capacity by.
        n_bounds = self.control.num_boundaries
        arrivals_in_interval = [0] * n_bounds
        completed_lat: List[float] = []
        busy_integral = 0.0
        last_busy_t = 0.0
        ingress_slots = max(tiers[0].spec.slots, 1)

        ts, lat_s, cpu_s, mem_s, net_s, off_s = ([] for _ in range(6))
        net_links: List[List[float]] = [[] for _ in topo.links]

        def note_busy(t: float):
            nonlocal busy_integral, last_busy_t
            busy_integral += tiers[0].busy / ingress_slots * (t - last_busy_t)
            last_busy_t = t

        # --- seed events ------------------------------------------------
        if self.trace is not None:
            # materialized trace: event i chains event i+1 at trace.t[i+1]
            if len(self.trace):
                push(float(self.trace.t[0]), _ARRIVAL, (0,))
            duration = self.trace.duration_s
        else:
            push(self.rng.exponential(1.0 / self._rate(0.0)), _ARRIVAL)
            duration = cfg.duration_s
        push(cfg.control_interval_s, _CONTROL)
        push(cfg.metric_interval_s, _METRIC)
        if self.faults is not None:
            self.faults.reset()
            for ev in self.faults:
                push(ev.t, _FAULT, (ev,))

        def start_service(j: int, ready: float, arr: float,
                          size=None):
            tier = tiers[j]
            if j == 0:
                note_busy(ready)
            tier.busy += 1
            pages = tier.page_need(size)
            tier.pages_used += pages
            svc = _service_sample(self.rng, tier.service_mean, prof.cv)
            tok = next(svc_seq)
            svc_live[tok] = (j, arr, ready + svc, pages, size)
            push(ready + svc, _DONE, (j, arr, tok))

        def resume_service(j: int, t: float, arr: float, remaining: float,
                           size=None):
            """Restart a migrated request with its *remaining* work (no
            fresh service sample — migration moves the request, it does
            not restart it)."""
            tier = tiers[j]
            if j == 0:
                note_busy(t)
            tier.busy += 1
            pages = tier.page_need(size)
            tier.pages_used += pages
            tok = next(svc_seq)
            svc_live[tok] = (j, arr, t + remaining, pages, size)
            push(t + remaining, _DONE, (j, arr, tok))

        def cross_link(l: int, ready: float,
                       nbytes: Optional[float] = None) -> float:
            """Serialize one payload over link l (FIFO pipe model:
            saturation shows up as link_free_at running ahead of time).
            The fault overlay's degraded bandwidth/RTT apply here; a
            materialized trace's per-request payload overrides the
            profile's for the arrival hop walk."""
            nb = prof.payload_bytes if nbytes is None else nbytes
            xfer = nb / link_state[l].bandwidth_Bps
            start = max(ready, link_free_at[l])
            link_free_at[l] = start + xfer
            link_bytes[l] += nb
            return link_free_at[l] + link_state[l].rtt_s

        def route_target(j: int) -> Optional[int]:
            """Resolve an assigned tier against the fault state: crashed
            tiers forward but cannot serve, a partitioned link cuts off
            everything past it.  Prefer the shallowest serviceable tier
            at or past the assignment, else the deepest one before it;
            None when nothing can serve (the request 503s)."""
            if self.faults is None:
                return j
            reach = 0
            for l in range(N - 1):
                if not link_state[l].up:
                    break
                reach = l + 1
            up = [i for i in range(reach + 1) if tier_up[i]]
            if not up:
                return None
            for i in up:
                if i >= j:
                    return i
            return up[-1]

        def backfill(j: int, t: float):
            """A slot freed (completion or migration): admit the next
            queued request, dropping timed-out waiters."""
            nonlocal failures
            tier = tiers[j]
            while tier.queue:
                qarr, qsize = tier.queue.popleft()
                if t - qarr > cfg.timeout_s:
                    failures += 1
                    if j < last:
                        self.tier_metrics[j].record_latency(
                            prof.name, t - qarr)
                    continue
                if not tier.can_serve(qsize):
                    # freed capacity doesn't cover the head request's
                    # page reservation: it keeps its place in line
                    tier.queue.appendleft((qarr, qsize))
                    break
                start_service(j, t, qarr, qsize)
                break

        def fire_migrations(t: float):
            """Mid-stream migration, the simulator's in-service transfer:
            every boundary whose policy crossed its migrate_threshold
            ships ceil(in_service * R_t/100) requests (longest remaining
            service first) over its link; the request resumes down-chain
            with its remaining work scaled by the service-speed ratio.
            The payload serializes over the link's FIFO pipe, so
            migration egress shows up in ``net_links_MBps`` like any
            other crossing."""
            nonlocal mig_fired, mig_transit
            for b in range(N - 1):
                pol = self.control.policies[b]
                thr = pol.migrate_threshold
                if thr is None or float(R_cur[b]) < thr:
                    continue
                if not (link_state[b].up and tier_up[b + 1]):
                    continue       # no migrating into a partition/crash
                in_svc = [(tok, rec) for tok, rec in svc_live.items()
                          if rec[0] == b]
                n_mig = min(len(in_svc),
                            int(np.ceil(len(in_svc) * float(R_cur[b])
                                        / 100.0)))
                if n_mig <= 0:
                    continue
                # longest remaining service first (most slot-hungry);
                # token order breaks ties deterministically
                in_svc.sort(key=lambda e: (-(e[1][2] - t), e[0]))
                for tok, (j, arr, t_done, pages, size) in in_svc[:n_mig]:
                    del svc_live[tok]          # the queued _DONE is stale
                    if j == 0:
                        note_busy(t)
                    tiers[j].busy -= 1
                    tiers[j].pages_used -= pages
                    mig_fired += 1
                    mig_transit += 1
                    if b + 1 < n_bounds:
                        arrivals_in_interval[b + 1] += 1
                    push(cross_link(b, t), _MIGRATE,
                         (b + 1, arr, t_done - t, j, size))
                    backfill(j, t)             # the freed slot backfills

        def admit(j: int, ready: float, arr: float, size=None):
            """Hand a request to tier j; overflow spills down the chain
            (waterfall) or rejects, per the topology.  Paged tiers gate
            on pages AND a slot (memory actually reserved), mirroring
            ``Tier.admission_budget``."""
            nonlocal failures, spilled
            tier = tiers[j]
            cap = tier.queue_cap
            if tier_up[j] and tier.can_serve(size):
                start_service(j, ready, arr, size)
            elif tier_up[j] and (cap is None or len(tier.queue) < cap):
                tier.queue.append((arr, size))
            elif topo.waterfall and j < last and link_state[j].up:
                spilled += 1
                if j + 1 < n_bounds:
                    arrivals_in_interval[j + 1] += 1
                admit(j + 1, cross_link(j, ready), arr, size)
            else:
                # queue-proxy overflow: immediate 503
                failures += 1
                if j < last:
                    self.tier_metrics[j].record_latency(
                        prof.name, cfg.reject_latency_s)

        while events:
            t, _, kind, payload = heapq.heappop(events)
            if t > duration:
                break

            if kind == _ARRIVAL:
                submitted += 1
                j = self._choose_tier(self.rng.uniform(), R_cur)
                arr_bytes = (float(self.trace.payload_bytes[payload[0]])
                             if payload else None)
                size = None
                if payload:
                    i = payload[0]
                    size = (max(int(self.trace.prompt_len[i]), 1),
                            max(int(self.trace.max_new[i]), 1))
                jt = route_target(j)
                if jt is None:
                    # every serviceable tier is unreachable: fast 503,
                    # visible to Eq (1) like any queue-proxy reject
                    failures += 1
                    self.tier_metrics[0].record_latency(
                        prof.name, cfg.reject_latency_s)
                else:
                    j = jt
                    for b in range(min(j + 1, n_bounds)):
                        arrivals_in_interval[b] += 1
                    ready = t
                    for l in range(j):
                        ready = cross_link(l, ready, arr_bytes)
                    admit(j, ready, t, size)
                if payload:            # materialized trace: chain next row
                    i = payload[0]
                    if i + 1 < len(self.trace):
                        push(float(self.trace.t[i + 1]), _ARRIVAL, (i + 1,))
                else:
                    push(t + self.rng.exponential(1.0 / self._rate(t)),
                         _ARRIVAL)

            elif kind == _DONE:
                j, arr, tok = payload
                if tok not in svc_live:
                    continue       # stale: the request migrated mid-service
                rec = svc_live.pop(tok)
                tier = tiers[j]
                if j == 0:
                    note_busy(t)
                tier.busy -= 1
                tier.pages_used -= rec[3]
                lat = t - arr
                # Prometheus sees every completed request's latency,
                # successful or not; only the success *counter* is gated.
                if j < last:
                    self.tier_metrics[j].record_latency(prof.name, lat)
                if lat <= cfg.timeout_s:
                    successes += 1
                    tier.served += 1
                    completed_lat.append(lat)
                else:
                    failures += 1
                backfill(j, t)

            elif kind == _CONTROL:
                # One shared scrape-and-update cycle (ControlLoop) per
                # boundary: tier b's latency windows + its in-flight
                # queue-age mixing + demand RPS — the same code path the
                # live continuum ticks.
                qages = []
                for b in range(self.control.num_boundaries):
                    bq = tiers[b].queue if b < len(tiers) else ()
                    qages.append([[t - qarr for qarr, _qsize in bq]])
                if self.control.eq1 == "sketch":
                    samples = [self.tier_metrics[b].drain_fresh()
                               for b in range(self.control.num_boundaries)]
                    R_all = self.control.step_stream(
                        samples, queue_ages=qages,
                        arrivals=[[c] for c in arrivals_in_interval])
                else:
                    lats, valids = [], []
                    for b in range(self.control.num_boundaries):
                        lat, valid = self.tier_metrics[b].latency_windows(
                            cfg.window)
                        lats.append(lat)
                        valids.append(valid)
                    R_all = self.control.step_tiers(
                        lats, valids, queue_ages=qages,
                        arrivals=[[c] for c in arrivals_in_interval])
                R_cur = np.array(R_all[:N - 1, 0], np.float64)
                push(t + cfg.control_interval_s, _CONTROL)
                arrivals_in_interval = [0] * n_bounds
                # Mid-stream migration (policies with a migrate_threshold
                # only): fresh R_t may now warrant moving in-service work
                fire_migrations(t)

            elif kind == _MIGRATE:
                # A migrated request's state landed at its destination.
                dst, arr, remaining, src, size = payload
                mig_transit -= 1
                if not (link_state[dst - 1].up and tier_up[dst]):
                    # partitioned mid-transfer (or target crashed): the
                    # state never arrives — ABORT back to the source
                    if tier_up[src] and tiers[src].can_serve(size):
                        mig_aborted += 1
                        resume_service(src, t, arr, remaining, size)
                    elif tier_up[src]:
                        # source momentarily full: retry the abort
                        mig_transit += 1
                        push(t + cfg.control_interval_s, _MIGRATE, payload)
                    else:
                        # both ends gone: accounted, never silent
                        mig_aborted += 1
                        failures += 1
                elif tiers[dst].can_serve(size):
                    # remaining *work* is invariant; the time to finish it
                    # scales with the destination's service speed
                    mig_completed += 1
                    resume_service(dst, t, arr,
                                   remaining * tiers[dst].service_mean
                                   / tiers[src].service_mean, size)
                elif tier_up[src] and tiers[src].can_serve(size):
                    # destination full: ABORT — resume at the source
                    mig_aborted += 1
                    resume_service(src, t, arr, remaining, size)
                else:
                    # both ends full: the landed state waits and retries
                    # next control interval — remaining work preserved,
                    # bounded queues untouched, never silently dropped
                    # (a request stuck past the timeout still fails on
                    # completion, like any late finisher)
                    mig_transit += 1
                    push(t + cfg.control_interval_s, _MIGRATE, payload)

            elif kind == _FAULT:
                (ev,) = payload
                faults_applied += 1
                if ev.kind in ("degrade_link", "partition_link",
                               "restore_link"):
                    ls = link_state[ev.target]
                    ls.apply(ev)
                    # a net-aware boundary re-caps against the new link
                    pol = self.control.policies[
                        min(ev.target, len(self.control.policies) - 1)]
                    if isinstance(pol, AutoOffload):
                        pol.set_link_capacity(ls.effective_capacity())
                elif ev.kind == "crash_tier":
                    i = ev.target
                    tier_up[i] = False
                    if i == 0:
                        note_busy(t)
                    # every resident service and queued request is lost
                    # with the tier's state — collect, then replay each
                    # at a reachable serviceable tier (fresh service
                    # sample: the work restarts) or count it failed.
                    resident = [(tok, rec) for tok, rec in svc_live.items()
                                if rec[0] == i]
                    lost = []
                    for tok, (_, arr, _t_done, _pg, rsize) in resident:
                        del svc_live[tok]   # its queued _DONE is now stale
                        lost.append((arr, rsize))
                    tiers[i].busy = 0
                    tiers[i].pages_used = 0
                    lost += list(tiers[i].queue)
                    tiers[i].queue.clear()
                    for arr, lsize in lost:
                        alt = route_target(i)
                        if alt is None:
                            failures += 1
                            continue
                        replayed += 1
                        ready = t
                        for l in range(min(i, alt), max(i, alt)):
                            ready = cross_link(l, ready)
                        admit(alt, ready, arr, lsize)
                else:          # restore_tier: the pool comes back idle
                    tier_up[ev.target] = True

            elif kind == _METRIC:
                note_busy(t)
                ts.append(t)
                lat_s.append(float(np.mean(completed_lat))
                             if completed_lat else np.nan)
                completed_lat.clear()
                cpu_s.append(busy_integral / cfg.metric_interval_s)
                busy_integral = 0.0
                active = tiers[0].busy + len(tiers[0].queue)
                mem_s.append(cfg.mem_baseline_mb + active * prof.mem_mb)
                for l in range(len(link_bytes)):
                    net_links[l].append(
                        link_bytes[l] / cfg.metric_interval_s / 1e6)
                    link_bytes[l] = 0.0
                net_s.append(net_links[0][-1] if net_links else 0.0)
                off_s.append(float(R_cur[0]) if len(R_cur) else 0.0)
                push(t + cfg.metric_interval_s, _METRIC)

        # Drain: everything still queued, in service, or in a migration
        # transfer at the end never completed.  A transit cut off by the
        # horizon is an aborted migration (terminally, fired ==
        # completed + aborted — nothing stays "open" past the run).
        failures += sum(len(tr.queue) + tr.busy for tr in tiers)
        failures += mig_transit
        mig_aborted += mig_transit

        return SimResult(
            policy=str(self.policy), workload=prof.name,
            successes=successes, failures=failures,
            times=np.asarray(ts), latency_avg=np.asarray(lat_s),
            cpu_util=np.asarray(cpu_s), mem_mb=np.asarray(mem_s),
            net_MBps=np.asarray(net_s), offload_pct=np.asarray(off_s),
            net_links_MBps=np.asarray(net_links),
            tier_counts={tr.spec.name: tr.served for tr in tiers},
            spilled=spilled,
            migrations_fired=mig_fired,
            migrations_completed=mig_completed,
            migrations_aborted=mig_aborted,
            submitted=submitted, replayed=replayed,
            faults_applied=faults_applied)


def run_policy_sweep(workload: str,
                     policies=(0.0, 25.0, 50.0, 75.0, 100.0, "auto"),
                     cfg: SimConfig = SimConfig(),
                     topology: Optional[Topology] = None
                     ) -> Dict[str, SimResult]:
    """The paper's Table 2 row for one workload."""
    out: Dict[str, SimResult] = {}
    for p in policies:
        out[str(p)] = ContinuumSimulator(workload, p, cfg,
                                         topology=topology).run()
    return out
